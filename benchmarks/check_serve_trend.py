"""CI trend gate for the serve benchmark rows.

Compares a freshly-measured ``--bench serve`` JSON payload against the
committed ``BENCH_serve.json`` baseline and fails (exit 1) when the serving
hot path regresses. This gate — not per-run asserts inside ``bench_serve``
— owns the serve latency contracts:

* **trend**: every ``serve/*`` row present in both files must not regress
  by more than ``--max-regress`` (default 25%) in ``us_per_call``;
* **coverage**: every baseline row must still be emitted by the fresh run
  (a silently dropped row would freeze its trend forever);
* **single-stage cache contract** (was an assert in ``bench_serve``):
  ``vani`` hit ≤ 1.25× cold — the single-stage engine bypasses the rep
  cache, so hit and cold do identical work and a sustained gap means
  cache bookkeeping crept back onto the hot path;
* **two-stage cache contract**: ``mari`` hit ≥ 1.5× faster than cold —
  the bench's deep user tower makes stage 1 the dominant cold cost, so a
  hit that fails to clear 1.5× means the cache (or the device-resident
  dispatch path behind it) stopped paying for itself;
* **observability**: ``serve/<mode>/breakdown`` rows (the per-phase
  pack/dispatch/device/unpack profile) must be present for every mode, as
  must the ``serve/<mode>/latency_p50``/``latency_p99`` histogram rows
  (the repro.obs percentile surface);
* **obs overhead**: the mari obs-on-vs-off probe (``modes.mari.obs``) must
  show tracing costing no more than ``--obs-tol`` in qps (default 1.5x) —
  the tracer is a bounded ring behind one leaf lock, and this gate keeps
  it cheap enough to turn on under load.

Usage (what CI runs):

    python -m benchmarks.run --bench serve --json BENCH_serve_fresh.json
    python -m benchmarks.check_serve_trend \
        --baseline BENCH_serve.json --fresh BENCH_serve_fresh.json

Faster-than-baseline rows are reported but never gate: improvements are
committed by regenerating ``BENCH_serve.json``, which resets the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

MODES = ("vani", "uoi", "mari")


def _rows(payload: dict) -> dict[str, dict]:
    return {r["name"]: r for r in payload.get("rows", [])
            if r["name"].startswith("serve/")}


def _mode_latency(payload: dict, mode: str) -> tuple[float, float]:
    m = payload["serve"]["modes"][mode]
    return float(m["cold_ms"]), float(m["hit_ms"])


def check(baseline: dict, fresh: dict, max_regress: float,
          obs_tol: float = 1.5) -> list[str]:
    """Return the list of failure messages (empty == gate passes)."""
    failures: list[str] = []
    base_rows, fresh_rows = _rows(baseline), _rows(fresh)

    # -- coverage: every baseline row must still exist ----------------------
    for name in sorted(set(base_rows) - set(fresh_rows)):
        failures.append(f"missing row: {name} (in baseline, not in fresh)")

    # -- trend: per-row regression gate -------------------------------------
    print(f"{'row':44s} {'base_us':>10s} {'fresh_us':>10s} {'delta':>8s}")
    for name in sorted(set(base_rows) & set(fresh_rows)):
        b = float(base_rows[name]["us_per_call"])
        f = float(fresh_rows[name]["us_per_call"])
        delta = (f - b) / b if b else 0.0
        mark = ""
        if delta > max_regress:
            mark = "  << REGRESSION"
            failures.append(
                f"regression: {name} {b:.1f}us -> {f:.1f}us "
                f"({delta:+.0%} > {max_regress:.0%} budget)")
        print(f"{name:44s} {b:10.1f} {f:10.1f} {delta:+7.0%}{mark}")

    # -- latency contracts on the FRESH run ---------------------------------
    try:
        cold, hit = _mode_latency(fresh, "vani")
        if hit > cold * 1.25:
            failures.append(
                f"vani cache contract: hit {hit:.3f}ms > 1.25x cold "
                f"{cold:.3f}ms — single-stage bookkeeping on the hot path")
        cold, hit = _mode_latency(fresh, "mari")
        if cold < hit * 1.5:
            failures.append(
                f"mari cache contract: cold {cold:.3f}ms < 1.5x hit "
                f"{hit:.3f}ms — rep-cache hit no longer pays for itself")
    except KeyError as e:
        failures.append(f"fresh payload missing serve mode summary: {e}")

    # -- observability: breakdown + latency-percentile rows present ---------
    for mode in MODES:
        if f"serve/{mode}/breakdown" not in fresh_rows:
            failures.append(f"missing breakdown row: serve/{mode}/breakdown")
        for pct in ("latency_p50", "latency_p99"):
            if f"serve/{mode}/{pct}" not in fresh_rows:
                failures.append(f"missing histogram row: serve/{mode}/{pct}")
        lat = fresh.get("serve", {}).get("modes", {}) \
            .get(mode, {}).get("latency")
        if not lat or lat.get("request_ms", {}).get("p99") is None:
            failures.append(
                f"{mode}: no request-latency histogram snapshot in payload "
                f"(modes.{mode}.latency.request_ms.p99)")

    # -- obs overhead: tracing-on qps within obs_tol of tracing-off ---------
    obs = fresh.get("serve", {}).get("modes", {}).get("mari", {}).get("obs")
    if not obs:
        failures.append("missing obs overhead probe (modes.mari.obs)")
    else:
        print(f"# mari: trace-on qps ratio {obs['ratio']}x "
              f"(on={obs['qps_trace_on']} off={obs['qps_trace_off']} qps, "
              f"{obs['events']} events)")
        if obs["ratio"] < 1.0 / obs_tol:
            failures.append(
                f"obs overhead: trace-on qps {obs['qps_trace_on']} < "
                f"trace-off {obs['qps_trace_off']} / {obs_tol:g} — tracing "
                f"too expensive to leave on under load")

    # informational (not gated: on-vs-off qps is asserted lossless in-bench
    # and tracked by the per-row trend above)
    for mode in MODES:
        q = fresh.get("serve", {}).get("modes", {}).get(mode, {}).get("qps")
        if q:
            print(f"# {mode}: coalesce speedup {q['speedup']}x "
                  f"(on={q['coalesce_on']} off={q['coalesce_off']} qps)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_serve.json",
                    help="committed serve bench JSON (the trend baseline)")
    ap.add_argument("--fresh", default="BENCH_serve_fresh.json",
                    help="serve bench JSON from this run")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="per-row us_per_call regression budget "
                         "(0.25 = fail beyond +25%%)")
    ap.add_argument("--obs-tol", type=float, default=1.5,
                    help="max allowed qps factor lost to tracing "
                         "(1.5 = trace-on must keep >= 1/1.5 of the "
                         "trace-off qps)")
    args = ap.parse_args()
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures = check(baseline, fresh, args.max_regress, args.obs_tol)
    if failures:
        print(f"\nFAIL: {len(failures)} serve trend violation(s)")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nOK: serve rows within trend budget, contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
