"""Memory-hierarchy benchmark: hit rate and latency vs universe size.

The question this answers: with a FIXED device budget (hot LRU slots +
device rep tables) and the default host-RAM cold budget, how far does the
hierarchical memory tier (``MemPlan``: cold arena + async promotion + bulk
warming) carry the serving hit rate as the user universe grows — up to
U=1M distinct users under the Zipf(1.1) popularity law production
rep-caches live on?

Per universe point it builds a fresh two-stage engine
(``mem__cold_tier=True``, device-resident hot tier), bulk-``warm``s the
Zipf head straight into the cold arena (capped by the arena's byte-budget
capacity), then serves a Zipf-sampled request stream and reports, per
request class:

* ``hot``       — hot-LRU hit (device-resident stage-2 fast path),
* ``cold``      — hot miss served from one cold-arena read (no stage-1
  recompute, re-stacking stage-2 path),
* ``recompute`` — full miss paying stage 1,

plus the combined hit rate (hot + cold over all requests), demotion /
promotion counters, and arena occupancy. A subset of every class is also
scored against a cache-off engine — bit-identity is part of the payload
and the ``check_mem_trend`` gate, not a footnote.

  python -m benchmarks.memtier --json BENCH_mem.json        # full sweep
  python -m benchmarks.memtier --smoke --json BENCH_mem_fresh.json  # CI

``--smoke`` runs the smallest universe point only (shared row names with
the committed baseline, so the trend gate can compare) with a shorter
stream. The acceptance numbers (U=1M at >= 0.9 combined hit rate, cold
strictly cheaper than recompute, bit-identical scores) live in the
committed ``BENCH_mem.json`` and are asserted by
``benchmarks.check_mem_trend`` against BOTH files.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.load import Workload, _quiesced_gc, sample_users, zipf_cdf

ZIPF_S = 1.1           # the harness's production-shaped popularity law
TARGET_MASS = 0.92     # warm the head up to this CDF mass (capacity-capped)
FULL_UNIVERSES = (10_000, 100_000, 1_000_000)
SMOKE_UNIVERSES = (10_000,)


def _build(seed: int = 0):
    import jax
    from repro.graph.executor import init_graph_params
    from repro.models.ranking import (PaperRankingConfig,
                                      build_paper_ranking_model)
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
    params = init_graph_params(graph, jax.random.PRNGKey(seed))
    return graph, params


def _plan(cold_bytes: int | None):
    from repro.serve import ServePlan
    ev = dict(batch__hedging=False, batch__linger_ms=0.0,
              cache__max_cached_users=4096,
              cache__device_resident=True, cache__device_slots=256,
              mem__cold_tier=True, mem__warm_batch=4096)
    if cold_bytes is not None:
        ev["mem__cold_bytes"] = cold_bytes
    return ServePlan.preset("paper").evolve(**ev)


def _warm_head(eng, wl: Workload, universe: int, cdf: np.ndarray) -> int:
    """Warm the Zipf head into the cold arena: up to TARGET_MASS of the
    popularity mass, capped by the arena's slot capacity (discovered from
    the first row, so probe with one user first)."""
    eng.warm([(0, wl.ufeeds[0])])
    capacity = eng.mem_stats()["cold"]["capacity"]
    k_cover = int(np.searchsorted(cdf, TARGET_MASS, side="left")) + 1
    k = min(universe, capacity, k_cover)
    if k > 1:
        eng.warm([(u, wl.ufeeds[u % len(wl.ufeeds)]) for u in range(1, k)])
    return k


def _class_stats(lat_us: list[float]) -> dict:
    if not lat_us:
        return {"n": 0, "p50_us": None, "p99_us": None}
    a = np.asarray(lat_us)
    return {"n": len(a),
            "p50_us": round(float(np.percentile(a, 50)), 1),
            "p99_us": round(float(np.percentile(a, 99)), 1)}


def run_point(graph, params, universe: int, requests: int, B: int,
              pool: int, cold_bytes: int | None, seed: int = 0,
              identity_engine=None, identity_n: int = 0) -> dict:
    from repro.serve import ServingEngine
    wl = Workload(graph, B, pool, seed=seed)
    eng = ServingEngine(graph, params, plan=_plan(cold_bytes))
    try:
        cdf = zipf_cdf(universe, ZIPF_S)
        t0 = time.perf_counter()
        warmed = _warm_head(eng, wl, universe, cdf)
        warm_s = time.perf_counter() - t0

        rng = np.random.default_rng(seed + 7)
        uids = sample_users(cdf, requests, rng)
        # compile + first-touch outside the timed stream
        eng.score(wl.req(int(uids[0])))

        lats: dict[str, list[float]] = {"hot": [], "cold": [],
                                        "recompute": []}
        identity = []          # (request, fresh scores) for the bit check
        with _quiesced_gc():
            for i, uid in enumerate(uids):
                req = wl.req(int(uid))
                t = time.perf_counter()
                res = eng.score(req)
                us = (time.perf_counter() - t) * 1e6
                cls = ("hot" if res.user_cache_hit
                       else "cold" if res.cold_hit else "recompute")
                lats[cls].append(us)
                if identity_engine is not None and len(identity) < identity_n:
                    identity.append((req, res.scores, cls))
        eng.flush_promotions()

        bit_identical = None
        if identity_engine is not None:
            bit_identical = True
            for req, scores, _ in identity:
                ref = identity_engine.score(req)
                if not np.array_equal(scores, ref.scores):
                    bit_identical = False
                    break

        n_hit = len(lats["hot"]) + len(lats["cold"])
        ms = eng.mem_stats()
        point = {
            "universe": universe,
            "requests": requests,
            "warmed": warmed,
            "warm_s": round(warm_s, 2),
            "capacity": ms["cold"]["capacity"],
            "cold_users": ms["cold"]["users"],
            "cold_bytes_used": ms["cold"]["bytes"],
            "hit_rate": round(n_hit / requests, 4),
            "demotions": ms["demotions"],
            "promotions": ms["promote"]["promotions"],
            "hot": _class_stats(lats["hot"]),
            "cold": _class_stats(lats["cold"]),
            "recompute": _class_stats(lats["recompute"]),
        }
        if bit_identical is not None:
            point["bit_identical"] = bit_identical
            point["identity_checked"] = len(identity)
            point["identity_classes"] = sorted({c for _, _, c in identity})
        return point
    finally:
        eng.close()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smallest universe only, short stream")
    ap.add_argument("--json", default=None, help="write payload here")
    ap.add_argument("--requests", type=int, default=None,
                    help="stream length per point (default 3000; smoke 600)")
    ap.add_argument("--candidates", type=int, default=128)
    ap.add_argument("--pool", type=int, default=64,
                    help="distinct user-feed tensors reused across the "
                         "universe (identity is per uid — see load.py)")
    ap.add_argument("--cold-bytes", type=int, default=None,
                    help="override the arena budget (default: MemPlan's)")
    ap.add_argument("--identity-n", type=int, default=48,
                    help="requests double-scored on a cache-off engine "
                         "for the bit-identity check (first point only)")
    args = ap.parse_args()

    universes = SMOKE_UNIVERSES if args.smoke else FULL_UNIVERSES
    requests = args.requests or (600 if args.smoke else 3000)

    graph, params = _build()
    # the bit-identity reference: no caches at all, every request is a
    # full recompute of the exact same executable family
    from repro.serve import ServePlan, ServingEngine
    ref = ServingEngine(graph, params, plan=ServePlan.preset("paper").evolve(
        cache__cache_user_reps=False, batch__hedging=False,
        batch__linger_ms=0.0))

    rows = []
    points = {}
    try:
        for i, universe in enumerate(universes):
            t0 = time.perf_counter()
            point = run_point(
                graph, params, universe, requests, args.candidates,
                args.pool, args.cold_bytes, seed=i,
                identity_engine=ref if i == 0 else None,
                identity_n=args.identity_n)
            point["wall_s"] = round(time.perf_counter() - t0, 1)
            points[str(universe)] = point
            for cls in ("hot", "cold", "recompute"):
                st = point[cls]
                if st["p50_us"] is not None:
                    rows.append({"name": f"memtier/U{universe}/{cls}",
                                 "us_per_call": st["p50_us"],
                                 "derived": st["n"]})
            print(f"[memtier] U={universe}: hit_rate={point['hit_rate']} "
                  f"warmed={point['warmed']} "
                  f"hot={point['hot']['p50_us']}us "
                  f"cold={point['cold']['p50_us']}us "
                  f"recompute={point['recompute']['p50_us']}us "
                  f"({point['wall_s']}s)")
    finally:
        ref.close()

    payload = {
        "bench": "memtier",
        "smoke": bool(args.smoke),
        "config": {
            "zipf_s": ZIPF_S,
            "target_mass": TARGET_MASS,
            "requests": requests,
            "candidates": args.candidates,
            "pool": args.pool,
            "cold_bytes": args.cold_bytes,
            "max_cached_users": 4096,
            "device_slots": 256,
        },
        "rows": rows,
        "memtier": {"points": points},
    }
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"[memtier] wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
