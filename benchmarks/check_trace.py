"""CI validator for Chrome trace-event files written by ``repro.obs``.

Checks that a trace produced by ``--trace`` (launch/serve, benchmarks/load,
dist/runner, examples/serve_ranking) is something Perfetto will actually
load and that the span structure obeys the tracer's contract:

* the payload is well-formed Chrome trace JSON: a ``traceEvents`` list
  whose entries all carry ``name``/``ph``/``pid``/``tid`` and a numeric,
  non-negative ``ts`` (µs, rebased so the earliest event is 0);
* complete events (``ph: X``) have a non-negative ``dur`` — a negative
  duration means a clock went backwards through the span helpers;
* begin/end events (``ph: B``/``E``) are BALANCED per (pid, tid): every
  group opened on a synthetic track is closed by its collect, in order —
  an orphaned ``B`` is a group that never collected (or an exception path
  that skipped the ``end``);
* required tracks exist: at least one ``thread_name`` metadata record
  (real threads are named) and, when any group spans are present, at
  least one synthetic ``group:N`` track;
* optionally (``--require``), named events occur somewhere in the trace —
  CI passes ``--require cache_hit submit`` to prove the smoke run
  exercised the cache and admission paths, not just an idle loop.

Exit 0 = valid; exit 1 prints every violation.

    python -m benchmarks.check_trace trace.json --require cache_hit submit
"""
from __future__ import annotations

import argparse
import json
import sys

_PHASES = {"X", "B", "E", "i", "M"}


def validate(payload: dict, require: list[str] | None = None) -> list[str]:
    """Return the list of violations (empty == the trace is valid)."""
    errors: list[str] = []
    if not isinstance(payload, dict) or \
            not isinstance(payload.get("traceEvents"), list):
        return ["payload is not a Chrome trace object with a "
                "traceEvents list"]
    events = payload["traceEvents"]
    if not events:
        errors.append("traceEvents is empty")

    open_stacks: dict[tuple, list[str]] = {}
    thread_names = 0
    group_tracks: set[tuple] = set()
    seen_names: set[str] = set()
    for i, e in enumerate(events):
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                errors.append(f"event {i}: missing {field!r}: {e}")
                break
        else:
            ph = e["ph"]
            seen_names.add(e["name"])
            if ph not in _PHASES:
                errors.append(f"event {i}: unknown phase {ph!r}")
                continue
            if ph != "M":
                ts = e.get("ts")
                if not isinstance(ts, (int, float)) or ts < 0:
                    errors.append(f"event {i} ({e['name']}): bad ts {ts!r}")
            if ph == "X":
                dur = e.get("dur")
                if not isinstance(dur, (int, float)) or dur < 0:
                    errors.append(
                        f"event {i} ({e['name']}): X event with bad "
                        f"dur {dur!r}")
            elif ph == "B":
                open_stacks.setdefault((e["pid"], e["tid"]),
                                       []).append(e["name"])
            elif ph == "E":
                stack = open_stacks.get((e["pid"], e["tid"]))
                if not stack:
                    errors.append(
                        f"event {i} ({e['name']}): E without open B on "
                        f"pid={e['pid']} tid={e['tid']}")
                else:
                    stack.pop()
            elif ph == "M":
                if e["name"] == "thread_name":
                    thread_names += 1
                    tname = (e.get("args") or {}).get("name", "")
                    if tname.startswith("group:"):
                        group_tracks.add((e["pid"], e["tid"]))

    for (pid, tid), stack in open_stacks.items():
        if stack:
            errors.append(
                f"unbalanced spans on pid={pid} tid={tid}: "
                f"{len(stack)} B event(s) never closed ({stack})")
    if thread_names == 0:
        errors.append("no thread_name metadata — tracks are unnamed")
    if "group" in seen_names and not group_tracks:
        errors.append("group spans present but no synthetic group:N track")
    for name in require or []:
        if name not in seen_names:
            errors.append(f"required event {name!r} absent from the trace")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--require", nargs="*", default=None, metavar="EVENT",
                    help="event names that must appear at least once")
    args = ap.parse_args()
    try:
        with open(args.trace) as fh:
            payload = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read {args.trace}: {e}")
        return 1
    errors = validate(payload, args.require)
    if errors:
        print(f"FAIL: {len(errors)} trace violation(s) in {args.trace}")
        for msg in errors:
            print(f"  - {msg}")
        return 1
    n = len(payload["traceEvents"])
    pids = len({e["pid"] for e in payload["traceEvents"]})
    print(f"OK: {args.trace} valid ({n} events, {pids} process(es))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
