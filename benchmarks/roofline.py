"""§Roofline: derive the three roofline terms per (arch × shape × mesh) from
the dry-run artifacts (experiments/dryrun_results.json) and compute the
MODEL_FLOPS / HLO_FLOPs usefulness ratio.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# parameter counts (total / active) computed from the configs
_PARAM_CACHE: dict[str, tuple[float, float]] = {}


def _lm_params(arch: str) -> tuple[float, float]:
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    from repro import configs as cfgreg
    cfg = cfgreg.get_config(arch).CONFIG
    L, D, hd = cfg.n_layers, cfg.d_model, cfg.hd
    attn = L * (D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd
                + cfg.n_heads * hd * D)
    if cfg.is_moe:
        ffn_total = L * cfg.moe_experts * 3 * D * cfg.d_ff
        ffn_active = L * cfg.moe_top_k * 3 * D * cfg.d_ff
        router = L * D * cfg.moe_experts
    else:
        ffn_total = ffn_active = L * 3 * D * cfg.d_ff
        router = 0
    embed = 2 * cfg.vocab_padded * D
    total = attn + ffn_total + router + embed
    active = attn + ffn_active + router + embed
    _PARAM_CACHE[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape: str, kind: str, devices: int) -> float | None:
    """Analytic 'useful' FLOPs per device for the cell, or None if n/a."""
    from repro import configs as cfgreg
    mod = cfgreg.get_config(arch)
    spec = mod.SHAPES[shape]
    if mod.FAMILY == "lm":
        total, active = _lm_params(arch)
        # non-embedding matmul params dominate; use active for MoE
        n = active
        if kind == "train":
            tokens = spec["seq"] * spec["global_batch"]
            return 6 * n * tokens / devices
        if kind == "prefill":
            tokens = spec["seq"] * spec["global_batch"]
            return 2 * n * tokens / devices
        # decode: one token per sequence
        return 2 * n * spec["global_batch"] / devices
    if mod.FAMILY == "recsys":
        from repro.graph.ir import infer_shapes
        graph, _ = mod.BUILD()
        shapes = infer_shapes(graph)
        B = spec["batch"]
        train = spec["kind"] == "train"
        fl = 0.0
        for node in graph.topo_order():
            if node.op == "dense":
                din = shapes[node.inputs[0]][-1]
                # serving: user-side denses run at batch 1 (UOI/MaRI)
                from repro.core.gca import run_gca, Color
                fl += 2 * B * din * node.attrs["units"]
        if train:
            fl *= 3
        return fl / devices
    if mod.FAMILY == "gnn":
        cfg = mod.CONFIG
        H, R = cfg.d_hidden, cfg.n_rbf
        if spec["mode"] == "molecule":
            E = spec["batch"] * spec["n_edges"]
            N = spec["batch"] * spec["n_nodes"]
        elif spec["mode"] == "sampled":
            bn = spec["batch_nodes"]
            n, N, E = bn, bn, 0
            for f in spec["fanout"]:
                n *= f
                N += n
                E += n
        else:
            N, E = spec["n_nodes"], spec["n_edges"]
        per_inter = 2 * E * (R * H + H * H + H) + 2 * E * H * H \
            + 2 * N * 2 * H * H
        fl = cfg.n_interactions * per_inter + 2 * N * (H * H + H * cfg.n_out)
        return 3 * fl / devices  # train
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="experiments/dryrun_results.json")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    args = ap.parse_args()
    recs = json.load(open(args.results))
    rows = []
    for r in recs:
        if "roofline" not in r:
            continue
        if args.mesh != "both" and r["mesh"] != args.mesh:
            continue
        rf = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], r["kind"], r["devices"])
        hlo = r["cost"]["flops_per_device"]
        ratio = (mf / hlo) if (mf and hlo) else float("nan")
        dom = rf["bottleneck"].replace("_s", "")
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound else 0.0
        rows.append((r["arch"], r["shape"], r["mesh"], r["kind"],
                     rf["compute_s"], rf["memory_s"], rf["collective_s"],
                     dom, frac, ratio))
    rows.sort()
    hdr = ("arch", "shape", "mesh", "kind", "compute_s", "memory_s",
           "collective_s", "bottleneck", "roofline_frac", "useful_flops_ratio")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for row in rows:
        print("| {} | {} | {} | {} | {:.4f} | {:.4f} | {:.4f} | {} | "
              "{:.3f} | {:.2f} |".format(*row))


if __name__ == "__main__":
    main()
