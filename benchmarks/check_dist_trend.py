"""CI trend gate for the distributed-serving benchmark rows.

Compares a freshly-measured ``--bench dist`` JSON payload against the
committed ``BENCH_dist.json`` baseline and fails (exit 1) when the sharded
stage-2 path regresses. Mirrors ``check_serve_trend``; this gate — not
per-run asserts inside ``bench_dist`` — owns the dist contracts:

* **trend**: every ``dist/*`` qps row present in both files must not
  regress by more than ``--max-regress`` (default 60%) in ``us_per_call``.
  The budget is deliberately generous: each row is a subprocess with its
  own forced host-device world, so CI runners add fork/compile jitter the
  single-process serve rows never see;
* **coverage**: every baseline row must still be emitted by the fresh run
  (a silently dropped shard count would freeze its trend forever);
* **bit-identity**: every fresh qps row must carry
  ``bit_identical=True`` in its derived string — the worker verifies
  sharded scores against a process-local engine, and a row that stops
  verifying is a correctness failure, not a perf one;
* **observability**: every fresh qps row must have a sibling
  ``.../breakdown`` row (per-phase pack/dispatch/device/unpack means from
  the worker's ``StageProfiler``), so a qps regression is attributable to
  a phase without rerunning.

Usage (what CI runs):

    python -m benchmarks.run --bench dist --json BENCH_dist_fresh.json
    python -m benchmarks.check_dist_trend \
        --baseline BENCH_dist.json --fresh BENCH_dist_fresh.json

Faster-than-baseline rows are reported but never gate: improvements are
committed by regenerating ``BENCH_dist.json``, which resets the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows(payload: dict, *, breakdown: bool) -> dict[str, dict]:
    return {r["name"]: r for r in payload.get("rows", [])
            if r["name"].startswith("dist/")
            and r["name"].endswith("/breakdown") == breakdown}


def check(baseline: dict, fresh: dict, max_regress: float) -> list[str]:
    """Return the list of failure messages (empty == gate passes)."""
    failures: list[str] = []
    base_rows = _rows(baseline, breakdown=False)
    fresh_rows = _rows(fresh, breakdown=False)

    # -- coverage: every baseline qps row must still exist ------------------
    for name in sorted(set(base_rows) - set(fresh_rows)):
        failures.append(f"missing row: {name} (in baseline, not in fresh)")

    # -- trend: per-row regression gate -------------------------------------
    print(f"{'row':44s} {'base_us':>10s} {'fresh_us':>10s} {'delta':>8s}")
    for name in sorted(set(base_rows) & set(fresh_rows)):
        b = float(base_rows[name]["us_per_call"])
        f = float(fresh_rows[name]["us_per_call"])
        delta = (f - b) / b if b else 0.0
        mark = ""
        if delta > max_regress:
            mark = "  << REGRESSION"
            failures.append(
                f"regression: {name} {b:.1f}us -> {f:.1f}us "
                f"({delta:+.0%} > {max_regress:.0%} budget)")
        print(f"{name:44s} {b:10.1f} {f:10.1f} {delta:+7.0%}{mark}")

    # -- bit-identity + breakdown sibling on the FRESH run -------------------
    fresh_bd = _rows(fresh, breakdown=True)
    for name in sorted(fresh_rows):
        if "bit_identical=True" not in fresh_rows[name].get("derived", ""):
            failures.append(
                f"bit-identity: {name} no longer verifies against the "
                f"process-local engine "
                f"(derived={fresh_rows[name].get('derived')!r})")
        if f"{name}/breakdown" not in fresh_bd:
            failures.append(f"missing breakdown row: {name}/breakdown")

    for name in sorted(fresh_bd):
        print(f"# {name}: {fresh_bd[name].get('derived', '')}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_dist.json",
                    help="committed dist bench JSON (the trend baseline)")
    ap.add_argument("--fresh", default="BENCH_dist_fresh.json",
                    help="dist bench JSON from this run")
    ap.add_argument("--max-regress", type=float, default=0.60,
                    help="per-row us_per_call regression budget "
                         "(0.60 = fail beyond +60%%; generous because each "
                         "row forks its own device world)")
    args = ap.parse_args()
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures = check(baseline, fresh, args.max_regress)
    if failures:
        print(f"\nFAIL: {len(failures)} dist trend violation(s)")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nOK: dist rows within trend budget, identity + breakdown hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
