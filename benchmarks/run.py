"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. CPU wall-clock stands in for
the paper's GPU timings (speedup RATIOS are the reproduced quantity; the
dims are scaled by --scale to keep CPU runtimes sane — ratios are
dimension-homogeneous so scaling preserves them to first order).

  python -m benchmarks.run                 # all tables
  python -m benchmarks.run --bench table2  # one table
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.common import timeit
from repro.core.mari import (mari_flops, matmul_mari, matmul_mari_fragmented,
                             matmul_vanilla, vanilla_flops)

_JSON_ROWS: list[dict] = []       # machine-readable mirror of the CSV rows
_JSON_EXTRA: dict = {}            # structured per-bench payloads (serve)


def _row(name: str, us: float, derived: str, plan=None, preset=None):
    """Emit one CSV row (+ JSON mirror). ``plan`` is the ``ServePlan`` that
    produced an engine-backed row — recorded verbatim in the JSON output so
    every bench row carries its exact serving config (provenance).
    ``preset`` labels the named preset the plan was derived from."""
    print(f"{name},{us:.1f},{derived}", flush=True)
    row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    if plan is not None:
        row["preset"] = preset if preset is not None else plan.preset_name()
        row["plan"] = plan.to_dict()
    _JSON_ROWS.append(row)


def _mk(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _time_pair(B, Du, Dr, d, iters=5):
    """Wall-time vanilla vs MaRI matmul at the given dims."""
    ks = jax.random.split(jax.random.PRNGKey(B + Du + Dr + d), 4)
    xu, xr = _mk(ks[0], 1, Du), _mk(ks[1], B, Dr)
    wu, wr = _mk(ks[2], Du, d), _mk(ks[3], Dr, d)
    x_tiled = jnp.concatenate([jnp.broadcast_to(xu, (B, Du)), xr], -1)
    w = jnp.concatenate([wu, wr], 0)
    f_van = jax.jit(matmul_vanilla)
    f_mari = jax.jit(matmul_mari)
    t_van = timeit(lambda: f_van(x_tiled, w), iters=iters)
    t_mari = timeit(lambda: f_mari(xu, xr, wu, wr), iters=iters)
    return t_van, t_mari


# ---------------------------------------------------------------------------
# Table 2 / Figure 3: MatMul_MaRI vs vanilla across B, D_user, D_rest, D_hid
# ---------------------------------------------------------------------------

def bench_table2(scale: float = 0.25, iters: int = 5):
    s = lambda x: max(16, int(x * scale))
    # varying B (D_user=4000, D_item=D_cross=1000, D_hidden=512)
    for B in [100, 500, 1000, 2000]:
        Du, Dr, d = s(4000), s(2000), s(512)
        tv, tm = _time_pair(B, Du, Dr, d, iters)
        fs = vanilla_flops(B, Du + Dr, d) / mari_flops(B, Du, Dr, d)
        _row(f"table2/varyB/B={B}", tm["mean_us"],
             f"time_speedup={tv['mean_us'] / tm['mean_us']:.2f}x;"
             f"flops_speedup={fs:.2f}x")
    # varying D_user (B=2000, D_rest=1000, D_hidden=512)
    for Du0 in [500, 1000, 2000, 4000, 8000]:
        B, Du, Dr, d = 2000, s(Du0), s(1000), s(512)
        tv, tm = _time_pair(B, Du, Dr, d, iters)
        fs = vanilla_flops(B, Du + Dr, d) / mari_flops(B, Du, Dr, d)
        _row(f"table2/varyDu/Du={Du0}", tm["mean_us"],
             f"time_speedup={tv['mean_us'] / tm['mean_us']:.2f}x;"
             f"flops_speedup={fs:.2f}x")
    # varying D_item/cross (B=2000, D_user=4000, D_hidden=512)
    for Dr0 in [500, 1000, 2000, 5000]:
        B, Du, Dr, d = 2000, s(4000), s(Dr0), s(512)
        tv, tm = _time_pair(B, Du, Dr, d, iters)
        fs = vanilla_flops(B, Du + Dr, d) / mari_flops(B, Du, Dr, d)
        _row(f"table2/varyDrest/Drest={Dr0}", tm["mean_us"],
             f"time_speedup={tv['mean_us'] / tm['mean_us']:.2f}x;"
             f"flops_speedup={fs:.2f}x")
    # varying D_hidden (B=2000, D_user=4000, D_item=1000)
    for d0 in [128, 512, 1024, 2048]:
        B, Du, Dr, d = 2000, s(4000), s(1000), s(d0)
        tv, tm = _time_pair(B, Du, Dr, d, iters)
        fs = vanilla_flops(B, Du + Dr, d) / mari_flops(B, Du, Dr, d)
        _row(f"table2/varyDhid/Dhid={d0}", tm["mean_us"],
             f"time_speedup={tv['mean_us'] / tm['mean_us']:.2f}x;"
             f"flops_speedup={fs:.2f}x")


# ---------------------------------------------------------------------------
# Table 3 / Figure 4: fragmented MaRI degradation vs chunk size (§2.4)
# ---------------------------------------------------------------------------

def bench_table3(scale: float = 0.25, iters: int = 5):
    B = 2000
    s = lambda x: max(16, int(x * scale))
    Du, Di, d = s(4000), s(1000), s(256)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xu, xi = _mk(ks[0], 1, Du), _mk(ks[1], B, Di)
    wu, wi = _mk(ks[2], Du, d), _mk(ks[3], Di, d)
    x_tiled = jnp.concatenate([jnp.broadcast_to(xu, (B, Du)), xi], -1)
    w = jnp.concatenate([wu, wi], 0)
    f_van = jax.jit(matmul_vanilla)
    f_neat = jax.jit(matmul_mari)
    t_van = timeit(lambda: f_van(x_tiled, w), iters=iters)["mean_us"]
    t_neat = timeit(lambda: f_neat(xu, xi, wu, wi), iters=iters)["mean_us"]
    _row("table3/original", t_van, "baseline=vanilla_matmul")
    _row("table3/neat_mari", t_neat,
         f"vs_original={100 * (t_neat - t_van) / t_van:+.1f}%")

    for chunk0 in [50, 100, 200, 400, 800]:
        chunk = max(4, int(chunk0 * scale))
        # interleave user/item chunks (the industrial fragmented layout)
        segs, off_u, off_i = [], 0, 0
        turn = 0
        while off_u < Du or off_i < Di:
            if (turn % 2 == 0 and off_u < Du) or off_i >= Di:
                wdt = min(chunk, Du - off_u)
                segs.append((xu[:, off_u:off_u + wdt],
                             wu[off_u:off_u + wdt]))
                off_u += wdt
            else:
                wdt = min(chunk, Di - off_i)
                segs.append((xi[:, off_i:off_i + wdt],
                             wi[off_i:off_i + wdt]))
                off_i += wdt
            turn += 1
        f_frag = jax.jit(lambda *flat: matmul_mari_fragmented(
            list(zip(flat[::2], flat[1::2]))))
        flat = [a for seg in segs for a in seg]
        t_frag = timeit(lambda: f_frag(*flat), iters=iters)["mean_us"]
        _row(f"table3/fragmented/chunk={chunk0}", t_frag,
             f"n_chunks={len(segs)};"
             f"vs_original={100 * (t_frag - t_van) / t_van:+.1f}%;"
             f"vs_neat={100 * (t_frag - t_neat) / t_neat:+.1f}%")


# ---------------------------------------------------------------------------
# Table 1: end-to-end ranking model — VanI vs UOI vs MaRI avg/p99
# ---------------------------------------------------------------------------

def bench_table1(iters: int = 30):
    from repro.core import apply_mari
    from repro.data.features import make_recsys_feeds
    from repro.graph.executor import Executor, init_graph_params
    from repro.models.ranking import (PaperRankingConfig,
                                      build_paper_ranking_model)

    cfg = PaperRankingConfig().scaled(0.12)
    graph, cfg = build_paper_ranking_model(cfg)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    B = 2048
    feeds = make_recsys_feeds(graph, B, jax.random.PRNGKey(1))

    results = {}
    for mode in ("vani", "uoi", "mari"):
        if mode == "mari":
            g2, p2, _ = apply_mari(graph, params)
            step = jax.jit(Executor(g2, "uoi").run)
            args = (p2, feeds)
        else:
            step = jax.jit(Executor(graph, mode).run)
            args = (params, feeds)
        t = timeit(lambda: step(*args), warmup=3, iters=iters)
        results[mode] = t
        _row(f"table1/{mode}", t["mean_us"], f"p99_us={t['p99_us']:.1f}")
    avg = results["uoi"]["mean_us"] / results["mari"]["mean_us"]
    p99 = results["uoi"]["p99_us"] / results["mari"]["p99_us"]
    _row("table1/speedup_mari_vs_uoi", results["mari"]["mean_us"],
         f"avg={avg:.2f}x;p99={p99:.2f}x (paper: 1.32x/1.26x)")
    lat = 100 * (results["uoi"]["mean_us"] - results["mari"]["mean_us"]) \
        / results["uoi"]["mean_us"]
    _row("table1/stage_latency_change", results["mari"]["mean_us"],
         f"coarse_ranking_latency={-lat:.2f}% (paper: -2.24%)")


# ---------------------------------------------------------------------------
# Two-stage serving: vanilla/uoi/mari latency, cold vs user-cache-hit
# ---------------------------------------------------------------------------

def bench_serve(scale: float = 0.12, B: int = 2000, iters: int = 15,
                qps_users: int = 8, qps_passes: int = 9, qps_B: int = 256):
    """End-to-end ServingEngine latency + throughput on paper_ranking.

    Latency rows (per-request, candidate pool B):
      cold = new (user, feature_version) each request (stage 1 must run);
      hit  = repeat user (stage 1 skipped from the representation cache).
    Throughput rows (``serve/<mode>/qps``): a burst of ``qps_users``
    concurrent users, each with a ``qps_B``-candidate pool, scored
    sequentially (coalesce=off) vs through the async CoalescingBatcher
    (coalesce=on — cross-user chunks packed into shared stage-2 buckets).
    The two row families deliberately probe different regimes: latency
    rows use one big pool (B) that nearly fills ``max_batch`` by itself;
    qps rows use per-user pools small enough that several users' chunks
    share one stage-2 bucket — the cross-user batching the coalescer
    exists for (with pools ~= max_batch there is nothing to merge, only
    batcher overhead to pay).
    Breakdown rows (``serve/<mode>/breakdown``): the engine's per-phase
    stage profiler (pack/dispatch/device/unpack + stage1) over the latency
    loop, mean µs per phase per engine call.
    Emits CSV rows and a structured payload for --json.
    """
    import dataclasses

    import numpy as np
    from repro.data.features import make_recsys_feeds
    from repro.graph.executor import init_graph_params
    from repro.models.ranking import (PaperRankingConfig,
                                      build_paper_ranking_model)
    from repro.serve import (CoalescingBatcher, ServePlan, ServeRequest,
                             ServingEngine)

    cfg = PaperRankingConfig().scaled(scale)
    # Two-stage modes run the industrial regime the cache exists for: a
    # deep user tower (~140MB of stage-1 weights, ~10ms batch-1 on CPU)
    # that a cache hit skips entirely. vani keeps the thin tower — the
    # single-stage engine re-runs the user side across all B candidate
    # rows, so a deep tower there would measure nothing but GEMM time.
    heavy_cfg = dataclasses.replace(cfg,
                                    user_tower_widths=(4096, 4096, 4096))
    graphs = {}
    for name, c in (("thin", cfg), ("heavy", heavy_cfg)):
        g, _ = build_paper_ranking_model(c)
        graphs[name] = (g, init_graph_params(g, jax.random.PRNGKey(0)))
    graph = graphs["thin"][0]                  # identical inputs both graphs
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    feeds = make_recsys_feeds(graph, B, jax.random.PRNGKey(1))
    ufeeds = {k: v for k, v in feeds.items() if k in user_in}
    cand = {k: v for k, v in feeds.items() if k not in user_in}

    # rows are keyed by plan preset: each mode IS a preset's paradigm
    # (vanilla/uoi/paper), evolved with the bench's row budget and hedging
    # off — duplicate executions on this shared CPU would contaminate the
    # latency/throughput rows the trajectory tracks. Two-stage modes turn
    # the device-resident rep tier on (the dispatch-overhead fight this
    # bench referees). The exact plan rides along in every JSON row
    # (provenance — incl. ``cache.device_resident``).
    presets = {"vani": "vanilla", "uoi": "uoi", "mari": "paper"}
    modes = {}
    for mode in ("vani", "uoi", "mari"):
        plan = ServePlan.preset(presets[mode]).evolve(
            batch__max_batch=4096, batch__hedging=False)
        if mode != "vani":
            plan = plan.evolve(cache__device_resident=True)
        graph, params = graphs["thin" if mode == "vani" else "heavy"]
        eng = ServingEngine(graph, params, plan=plan)
        req = lambda uid, ver=0: ServeRequest(
            user_id=uid, user_feeds=ufeeds, candidate_feeds=cand,
            feature_version=ver)
        eng.score(req(-1))                      # compile both stages
        eng.score(req(0))                       # warm user 0's rep cache
        # the latency-contract asserts that used to live here (vani hit ≤
        # 1.25× cold) moved to benchmarks/check_serve_trend.py — the CI
        # trend gate owns ALL latency contracts now, against both the
        # committed baseline and the fresh rows.
        # atomic snapshot+reset: discards the warmup phases in one lock
        # acquisition, so the breakdown covers exactly the timed loop
        eng.profiler.snapshot(reset=True)
        cold, hit = [], []
        for it in range(iters):
            cold.append(eng.score(req(it + 1, ver=it)).latency_ms)
            hit.append(eng.score(req(0)).latency_ms)
        cold_ms = float(np.median(cold))
        hit_ms = float(np.median(hit))
        breakdown = eng.profiler.snapshot()
        modes[mode] = {
            "cold_ms": round(cold_ms, 3), "hit_ms": round(hit_ms, 3),
            "two_stage": eng.two_stage,
            "device_resident": eng.device_resident,
            "stage2_compilations": eng.stage2_compilations,
            "breakdown": breakdown,
            "preset": presets[mode],
            "plan": plan.to_dict(),
        }
        _row(f"serve/{mode}/cold", cold_ms * 1e3,
             f"B={B};two_stage={eng.two_stage};preset={presets[mode]}",
             plan=plan, preset=presets[mode])
        _row(f"serve/{mode}/hit", hit_ms * 1e3,
             f"B={B};hit_speedup={cold_ms / hit_ms:.2f}x",
             plan=plan, preset=presets[mode])
        # per-phase dispatch-path breakdown: mean µs per engine call of
        # each hot-path phase over the latency loop (us_per_call = their
        # sum, i.e. profiled wall per call minus unprofiled slack)
        phase_us = {p: breakdown[p]["mean_us"]
                    for p in ("pack", "dispatch", "device", "unpack")}
        _row(f"serve/{mode}/breakdown", sum(phase_us.values()),
             ";".join(f"{p}={u:.1f}us" for p, u in phase_us.items())
             + f";stage1={breakdown['stage1']['mean_us']:.1f}us"
             + f";device_resident={eng.device_resident}",
             plan=plan, preset=presets[mode])

        # -- throughput: cross-user coalescing on vs off. Passes are
        # interleaved (off, on, off, on, ...) so machine-load drift lands on
        # both sides instead of whichever ran second; medians per side. ----
        import time as _time
        candq = {k: v[:qps_B] for k, v in cand.items()}
        reqq = lambda uid: ServeRequest(
            user_id=uid, user_feeds=ufeeds, candidate_feeds=candq)
        burst = [reqq(uid) for uid in range(qps_users)]
        for r in burst:                         # warm every user's rep cache
            eng.score(r)
        seq_ref = [eng.score(r) for r in burst]
        walls_off, walls_on = [], []
        with CoalescingBatcher(eng, linger_ms=1.0) as batcher:
            co_ref = batcher.score_many(burst)  # compile coalesced shapes
            # window the latency histograms to the timed passes: a compile
            # landing in an 80-sample p99 would pin the latency_p99 row
            # below to compile-time noise
            batcher.request_latency.reset()
            batcher.queue_wait.reset()
            for _ in range(qps_passes):
                t0 = _time.perf_counter()
                for r in burst:
                    eng.score(r)
                walls_off.append(_time.perf_counter() - t0)
                t0 = _time.perf_counter()
                batcher.score_many(burst)
                walls_on.append(_time.perf_counter() - t0)
        qps_off = qps_users / float(np.median(walls_off))
        qps_on = qps_users / float(np.median(walls_on))
        for s, c in zip(seq_ref, co_ref):       # lossless sanity
            assert np.array_equal(s.scores, c.scores), \
                "coalescing changed scores"
        modes[mode]["qps"] = {
            "coalesce_off": round(qps_off, 1), "coalesce_on": round(qps_on, 1),
            "users": qps_users, "B": qps_B,
            "speedup": round(qps_on / qps_off, 3),
        }
        _row(f"serve/{mode}/qps/coalesce=off", 1e6 / qps_off,
             f"B={qps_B};users={qps_users};qps={qps_off:.1f}",
             plan=plan, preset=presets[mode])
        _row(f"serve/{mode}/qps/coalesce=on", 1e6 / qps_on,
             f"B={qps_B};users={qps_users};qps={qps_on:.1f};"
             f"vs_off={qps_on / qps_off:.2f}x",
             plan=plan, preset=presets[mode])

        # -- latency distribution (repro.obs histograms): every request the
        # qps loop pushed through the batcher, p50/p99 without retaining
        # samples — the same numbers RankingService.stats() reports. ------
        lat_snap = batcher.request_latency.snapshot()
        qw_snap = batcher.queue_wait.snapshot()
        modes[mode]["latency"] = {"request_ms": lat_snap,
                                  "queue_wait_ms": qw_snap}
        _row(f"serve/{mode}/latency_p50", lat_snap["p50"] * 1e3,
             f"B={qps_B};n={lat_snap['count']};p90={lat_snap['p90']:.2f}ms",
             plan=plan, preset=presets[mode])
        _row(f"serve/{mode}/latency_p99", lat_snap["p99"] * 1e3,
             f"B={qps_B};queue_wait_p99={qw_snap['p99']:.3f}ms",
             plan=plan, preset=presets[mode])

        # -- observability overhead (mari only): the SAME burst through a
        # second engine built with ObsPlan.trace on, passes interleaved
        # with a plain engine so machine drift lands on both sides. The
        # trend gate bounds the ratio: tracing must stay cheap enough to
        # leave on under load. ---------------------------------------------
        if mode == "mari":
            obs_eng = ServingEngine(graph, params,
                                    plan=plan.evolve(obs__trace=True))
            for r in burst:
                obs_eng.score(r)
            w_off, w_obs = [], []
            with CoalescingBatcher(eng, linger_ms=1.0) as b_off, \
                    CoalescingBatcher(obs_eng, linger_ms=1.0) as b_on:
                b_off.score_many(burst)         # warm both batchers
                b_on.score_many(burst)
                for _ in range(qps_passes):
                    t0 = _time.perf_counter()
                    b_off.score_many(burst)
                    w_off.append(_time.perf_counter() - t0)
                    t0 = _time.perf_counter()
                    b_on.score_many(burst)
                    w_obs.append(_time.perf_counter() - t0)
            qps_plain = qps_users / float(np.median(w_off))
            qps_obs = qps_users / float(np.median(w_obs))
            modes[mode]["obs"] = {
                "qps_trace_off": round(qps_plain, 1),
                "qps_trace_on": round(qps_obs, 1),
                "ratio": round(qps_obs / qps_plain, 3),
                "events": len(obs_eng.tracer),
            }
            _row(f"serve/{mode}/qps/trace=on", 1e6 / qps_obs,
                 f"B={qps_B};users={qps_users};qps={qps_obs:.1f};"
                 f"vs_trace_off={qps_obs / qps_plain:.2f}x;"
                 f"events={len(obs_eng.tracer)}",
                 plan=plan, preset=presets[mode])
            obs_eng.close()
        eng.close()
    _JSON_EXTRA["serve"] = {"config": "paper_ranking", "scale": scale,
                            "B": B, "iters": iters, "modes": modes}


# ---------------------------------------------------------------------------
# Distributed serving: shards-vs-qps (single- and multi-process stage 2)
# ---------------------------------------------------------------------------

def bench_dist(shards=(1, 2, 4), pool: int = 2000, users: int = 4,
               passes: int = 5, scale: float = 0.05, modes: str = "mari",
               two_process: bool = True):
    """Candidate-axis sharded stage 2 at increasing shard counts.

    Each row runs in a subprocess (``repro.dist.runner``) so every shard
    count gets its own forced host-device world; the final row exercises
    the REAL multi-process path (2 ``jax.distributed`` workers). On one
    physical CPU the forced devices share cores, so qps-vs-shards mostly
    reports sharding overhead, not speedup — the row the trajectory
    tracks is that overhead staying flat. Scores per run are verified
    bit-identical against the process-local engine (--verify).
    """
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")

    def run(n_proc: int, dev_per_proc: int) -> list[dict]:
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                             + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "repro.dist.runner",
               "--spawn", str(n_proc),
               "--devices-per-process", str(dev_per_proc),
               "--bench", "--verify", "--modes", modes,
               "--pool", str(pool), "--users", str(users),
               "--passes", str(passes), "--scale", str(scale),
               "--max-batch", "1024", "--min-bucket", "128"]
        p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=900)
        if p.returncode != 0:
            raise RuntimeError(f"dist bench worker failed:\n{p.stderr[-2000:]}")
        return [json.loads(line) for line in p.stdout.strip().splitlines()
                if line.startswith("{") and "qps" in line]

    def breakdown_row(prefix: str, r: dict) -> None:
        # sibling row to serve/<mode>/breakdown: per-phase mean µs per
        # engine call over the worker's timed passes, so per-shard qps
        # stays attributable to pack/dispatch/device/unpack
        bd = r.get("breakdown")
        if not bd:
            return
        phase_us = {p: bd[p]["mean_us"]
                    for p in ("pack", "dispatch", "device", "unpack")}
        _row(f"{prefix}/breakdown", sum(phase_us.values()),
             ";".join(f"{p}={u:.1f}us" for p, u in phase_us.items())
             + f";stage1={bd['stage1']['mean_us']:.1f}us")

    records = []
    for n in shards:
        for r in run(1, n):
            records.append(r)
            name = f"dist/{r['mode']}/shards={r['shards']}"
            _row(name, 1e6 / r["qps"],
                 f"procs=1;pool={r['pool']};users={r['users']};"
                 f"qps={r['qps']};bit_identical={r.get('bit_identical')}")
            breakdown_row(name, r)
    if two_process:
        nproc_dev = max(max(shards) // 2, 1)
        for r in run(2, nproc_dev):
            records.append(r)
            name = f"dist/{r['mode']}/shards={r['shards']}/procs=2"
            _row(name, 1e6 / r["qps"],
                 f"procs=2;pool={r['pool']};users={r['users']};"
                 f"qps={r['qps']};bit_identical={r.get('bit_identical')}")
            breakdown_row(name, r)
    _JSON_EXTRA["dist"] = {"config": "paper_ranking", "scale": scale,
                           "pool": pool, "users": users, "passes": passes,
                           "records": records}


# ---------------------------------------------------------------------------
# Gather-aware attention: stage-2 peak memory + latency, gather on vs off
# ---------------------------------------------------------------------------

def bench_attn(B: int = 2000, users: int = 8, iters: int = 5):
    """Reparam-DIN stage 2 with the attention-side gather fused vs
    materialized.

    Both engines run the identical row-wise executable family on a
    ``users``-slot rep table and a B-candidate coalesced batch (Pallas in
    interpret mode on CPU — wall-clock is interpreter-dominated; the row
    the trajectory tracks is ``peak_bytes``). gather=off gathers the
    boundary ``T``/``u_part``/keys tables to row-wise blocks — peak temp
    memory carries the (B, L, D, h) tensor — while gather=on indexes the
    stacked tables inside ``kernels.gather_einsum``, so peak memory scales
    with U·L·D·h + B·d instead of B·L·D·h. Peak bytes come from
    ``jit(...).lower().compile().memory_analysis()`` on the actual stage-2
    executable.
    """
    import numpy as np
    from repro.common import next_pow2
    from repro.data.features import make_recsys_feeds
    from repro.graph.executor import init_graph_params
    from repro.models.recsys import build_din
    from repro.serve import ServePlan, ServeRequest, ServingEngine

    graph, _ = build_din(embed_dim=8, seq_len=24, attn_mlp=(16, 8),
                         mlp=(24, 12), item_vocab=4096)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    bucket = next_pow2(B)
    cand = {k: v for k, v in
            make_recsys_feeds(graph, bucket, jax.random.PRNGKey(99)).items()
            if k not in user_in}
    # engine-identical index layout: contiguous user slots, padded tail rows
    # reuse the last real slot
    uidx = np.full((bucket,), users - 1, np.int32)
    uidx[:B] = np.repeat(np.arange(users), -(-B // users))[:B]
    uidx = jnp.asarray(uidx)

    results = {}
    outs = {}
    plans = {}
    for gather in (False, True):
        plans[gather] = ServePlan.preset("tpu").evolve(
            kernel__kernel_gather=False, kernel__gather_attention=gather,
            batch__max_batch=4096, batch__hedging=False)
        eng = ServingEngine(graph, params, plan=plans[gather])
        reps = []
        for uid in range(users):
            feeds = make_recsys_feeds(graph, 1, jax.random.PRNGKey(uid + 1))
            reps.append(eng._user_reps(ServeRequest(
                uid, {k: v for k, v in feeds.items() if k in user_in},
                {}))[0])
        table = {k: jnp.concatenate([r[k] for r in reps], axis=0)
                 for k in reps[0]}
        # AOT-compile once and reuse the executable for memory stats,
        # timing, AND outputs (calling eng._stage2 again would re-trace and
        # re-compile — jit's dispatch cache doesn't see the AOT result)
        compiled = eng._stage2.lower(eng._params_s2, table, uidx,
                                     cand).compile()
        try:
            peak = int(compiled.memory_analysis().temp_size_in_bytes)
        except Exception:       # backend without buffer stats
            peak = -1
        t = timeit(lambda: compiled(eng._params_s2, table, uidx, cand),
                   warmup=1, iters=iters)
        outs[gather] = np.concatenate(
            [np.asarray(v) for v in compiled(
                eng._params_s2, table, uidx, cand).values()], axis=-1)
        results[gather] = {"us_per_call": round(t["mean_us"], 1),
                           "peak_bytes": peak}
        eng.close()
    # the two memory profiles must score identically
    assert np.allclose(outs[False], outs[True], rtol=1e-5, atol=1e-5), \
        "gather-aware attention changed scores"
    off_peak = results[False]["peak_bytes"]
    on_peak = results[True]["peak_bytes"]
    # ratio is None (JSON null) when the backend reported no buffer stats —
    # a NaN would serialize as invalid JSON and -1 would fake a win
    ratio = on_peak / off_peak if off_peak > 0 and on_peak >= 0 else None
    if ratio is not None:
        # THE contract this bench guards: gather-on stage-2 peak live bytes
        # must not scale with B*L*D*h (<= 0.5x the materializing path)
        assert ratio <= 0.5, (
            f"gather-on peak {on_peak}B > 0.5x gather-off {off_peak}B — "
            f"the attention gather is materializing again")
    for gather in (False, True):
        r = results[gather]
        _row(f"attn/din_reparam/gather={'on' if gather else 'off'}",
             r["us_per_call"],
             f"B={B};users={users};bucket={bucket};"
             f"peak_bytes={r['peak_bytes']}"
             + (f";peak_ratio={ratio:.3f}x"
                if gather and ratio is not None else ""),
             plan=plans[gather])
        results[gather]["plan"] = plans[gather].to_dict()
    _JSON_EXTRA["attn"] = {"config": "din_reparam", "B": B, "users": users,
                           "bucket": bucket,
                           "gather_off": results[False],
                           "gather_on": results[True],
                           "peak_ratio": (round(ratio, 4)
                                          if ratio is not None else None)}


# ---------------------------------------------------------------------------
# Appendix B.1: UOI vs VanI cross-attention (K/V projected once vs B times)
# ---------------------------------------------------------------------------

def bench_uoi_attention(iters: int = 10):
    from repro.nn.attention import cross_attention
    d, L = 64, 256
    for B in [128, 512, 2048]:
        ks = jax.random.split(jax.random.PRNGKey(B), 3)
        q = _mk(ks[0], B, 1, d)
        k1 = _mk(ks[1], 1, L, d)
        v1 = _mk(ks[2], 1, L, d)
        kB = jnp.broadcast_to(k1, (B, L, d)) + 0.0   # materialized tile
        vB = jnp.broadcast_to(v1, (B, L, d)) + 0.0
        wk, wv = _mk(ks[0], d, d), _mk(ks[1], d, d)

        @jax.jit
        def attn(q, k, v):
            return cross_attention(q, k @ wk, v @ wv)

        tv = timeit(lambda: attn(q, kB, vB), iters=iters)["mean_us"]
        tu = timeit(lambda: attn(q, k1, v1), iters=iters)["mean_us"]
        flops_ratio = (B + 2 * L) / (B * (1 + 2 * L))
        _row(f"appendixB1/uoi_vs_vani/B={B}", tu,
             f"time_speedup={tv / tu:.2f}x;flops_ratio={flops_ratio:.4f}")


BENCHES = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "serve": bench_serve,
    "dist": bench_dist,
    "attn": bench_attn,
    "uoi": bench_uoi_attention,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", choices=list(BENCHES) + ["all"], default="all")
    ap.add_argument("--scale", type=float, default=0.25,
                    help="dimension scale for CPU-feasible timings")
    ap.add_argument("--serve-scale", type=float, default=0.12,
                    help="paper_ranking scale for the serve bench (kept "
                         "separate: the serve bench times a full engine, not "
                         "one matmul)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write machine-readable results (e.g. "
                         "BENCH_serve.json) for perf-trajectory tracking")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.bench in ("table2", "all"):
        bench_table2(args.scale)
    if args.bench in ("table3", "all"):
        bench_table3(args.scale)
    if args.bench in ("table1", "all"):
        bench_table1()
    if args.bench in ("serve", "all"):
        bench_serve(args.serve_scale)
    if args.bench == "dist":
        # not in "all": forced-device subprocess worlds are heavyweight and
        # CI runs this as its own artifact step (BENCH_dist.json)
        bench_dist()
    if args.bench == "attn":
        # not in "all": interpret-mode Pallas at a 2048-row bucket is slow
        # on CPU; CI runs this as its own artifact step (BENCH_attn.json)
        bench_attn()
    if args.bench in ("uoi", "all"):
        bench_uoi_attention()
    if args.json:
        payload = {"bench": args.bench, "rows": _JSON_ROWS, **_JSON_EXTRA}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
