"""Closed-loop Zipf load harness for the serving stack.

Drives the real ``RankingService`` (engine + continuous-dispatch
``CoalescingBatcher`` + admission control, all wired ``from_plan``) with
the workload shape the batcher exists for: a large user universe under a
Zipf popularity law (a hot head that lives in the rep caches, a cold tail
that pays stage 1), Poisson open-loop arrivals at swept offered loads,
and a deadline-class slice riding the priority queue.

Per preset it runs TWO variants of the same plan — ``continuous`` (the
two-phase overlapped dispatch loop) and ``lockstep``
(``batch.continuous=False``) — and reports, per offered-load point:
achieved qps, p50/p95/p99 latency, and the admission counters
(shed/degrade, by SLO class). Saturation qps per variant comes from a
closed-loop probe (``--workers`` synchronous submitters, no pacing).
The two curves answer the PR's question directly: does overlapping
group k+1's host work under group k's device time buy tail latency and
saturation throughput, at identical offered load and identical scores?

  python -m benchmarks.load --json BENCH_load.json          # full curves
  python -m benchmarks.load --smoke --json BENCH_smoke.json # CI gate
  python -m benchmarks.load --check --json BENCH_load.json  # + acceptance

``--smoke`` shrinks the universe/durations and asserts the harness
contracts (achieved tracks offered at low load, curve monotone-ish,
deadline class never shed at low load). ``--check`` additionally asserts
the PR's acceptance: continuous p99 <= lockstep p99 at the fixed
sub-saturation point and continuous saturation >= lockstep (within
``--tol`` measurement slack on this shared-CPU box).

``--chaos`` switches the harness into the self-healing acceptance run:
one scenario served under a deterministic fault schedule (device-tier
write faults, stage-2 dispatch faults, injected result corruption, and
one worker-thread kill), asserting that every submitted future resolves
(zero hung), every SUCCESSFUL response is bit-identical to a fault-free
reference, availability stays above ``--chaos-floor``, and the circuit
breaker demonstrably restores the device-resident fast path
(open -> ... -> closed, via ``RankingService.stats()`` counters).
"""
from __future__ import annotations

import argparse
import gc
import json
import threading
import time
from concurrent.futures import wait as _wait_futures
from contextlib import contextmanager

import numpy as np

VARIANTS = ("continuous", "lockstep")


@contextmanager
def _quiesced_gc():
    """Collect before, disable during, re-enable after a timed segment —
    a CPython GC pause mid-window is tens of ms of phantom tail latency
    attributed to whichever variant happened to be measuring."""
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        gc.enable()


# ---------------------------------------------------------------------------
# Zipf user universe
# ---------------------------------------------------------------------------

def zipf_cdf(universe: int, s: float) -> np.ndarray:
    """CDF of a bounded Zipf(s) law over user ids 0..universe-1 (id = rank:
    small ids are the hot head)."""
    w = 1.0 / np.arange(1, universe + 1, dtype=np.float64) ** s
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    return cdf


def sample_users(cdf: np.ndarray, n: int, rng: np.random.Generator
                 ) -> np.ndarray:
    return np.searchsorted(cdf, rng.random(n), side="left").astype(np.int64)


# ---------------------------------------------------------------------------
# Workload: one service, two scenarios per preset (continuous / lockstep)
# ---------------------------------------------------------------------------

class Workload:
    """Request factory over a Zipf universe.

    ``pool`` distinct user-feed tensors are reused across the universe
    (uid -> pool slot uid % pool): feature VALUES repeat, but every uid is
    its own cache/device-slot identity — what the rep tier actually keys
    on — so cache-hit and slot-recycling behavior is that of ``universe``
    users at the memory cost of ``pool``.
    """

    def __init__(self, graph, B: int, pool: int, seed: int = 0):
        from repro.data.features import make_recsys_feeds
        self.B = B
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        import jax
        feeds = make_recsys_feeds(graph, 1024, jax.random.PRNGKey(seed + 1))
        self.cand_full = {k: v for k, v in feeds.items() if k not in user_in}
        self.cand = {k: v[:B] for k, v in self.cand_full.items()}
        self.ufeeds = []
        for i in range(pool):
            f = make_recsys_feeds(graph, 1, jax.random.PRNGKey(seed + 100 + i))
            self.ufeeds.append({k: v for k, v in f.items() if k in user_in})

    def req(self, uid: int, rows: int | None = None):
        from repro.serve import ServeRequest
        cand = (self.cand if rows is None
                else {k: v[:rows] for k, v in self.cand_full.items()})
        return ServeRequest(user_id=int(uid),
                            user_feeds=self.ufeeds[uid % len(self.ufeeds)],
                            candidate_feeds=cand)


def build_plan(preset: str, variant: str, args):
    """One serving plan per (preset, variant): identical engine shape, only
    ``batch.continuous`` differs — the comparison isolates the loop."""
    from repro.serve import ServePlan
    plan = ServePlan.preset(preset).evolve(
        batch__max_batch=args.max_batch, batch__min_bucket=args.B,
        batch__hedging=False, batch__linger_ms=args.linger_ms,
        batch__admission=True, batch__shed_queue_depth=args.shed_depth,
        batch__degrade_queue_depth=args.degrade_depth,
        batch__degrade_frac=0.5, batch__deadline_headroom_ms=0.25,
        cache__device_resident=True, cache__device_slots=args.device_slots)
    if variant == "lockstep":
        plan = plan.evolve(batch__continuous=False)
    if getattr(args, "trace", None):
        # ring-buffer tracing: bounded memory even over long sweeps, and
        # the retained window is the newest (most loaded) segment
        plan = plan.evolve(obs__trace=True)
    return plan


def warm(svc, scenario: str, wl: Workload, max_batch: int) -> None:
    """Compile every stage-2 bucket the run can touch (pow2 sizes from B up
    to max_batch; degraded pools land back in the B bucket via min_bucket)
    and the coalesced path, so no compile lands inside a timed point."""
    rows = wl.B
    while rows <= max_batch:
        svc.score(scenario, wl.req(0, rows=rows))
        rows *= 2
    svc.score_many([(scenario, wl.req(1)), (scenario, wl.req(2)),
                    (scenario, wl.req(3))])
    # compile the copy-on-write table writer too: a cold user arriving
    # while a launch is in flight forks the table generation, and that
    # path must not pay its jit compile inside a timed window. Driven
    # through the two-phase API directly (the batcher is idle here).
    eng = svc.engine(scenario)
    if getattr(eng, "device_store", None) is not None \
            and hasattr(eng, "begin_coalesced"):
        h1 = eng.begin_coalesced([wl.req(10_000_019)])
        h2 = eng.begin_coalesced([wl.req(10_000_033)])  # cold under flight
        eng.collect(h1)
        eng.collect(h2)


# ---------------------------------------------------------------------------
# Load loops
# ---------------------------------------------------------------------------

def _counters(svc, scenario: str) -> dict:
    s = svc.stats()["scenarios"][scenario]
    return {k: s[k] for k in ("shed_best_effort", "shed_deadline",
                              "degraded_requests", "pipeline_forks")}


def closed_loop_saturation(svc, scenario: str, wl: Workload,
                           ring: np.ndarray, duration: float,
                           workers: int) -> dict:
    """Max sustainable throughput: ``workers`` synchronous submitters with
    zero think time — the queue always holds ~``workers`` requests, so the
    dispatch loop is never starved and never admission-limited
    (``workers`` < degrade threshold)."""
    from repro.serve import SLO_BEST_EFFORT, AdmissionError
    stop_at = time.perf_counter() + duration
    lock = threading.Lock()
    done = [0]
    lats: list[float] = []

    def run(wid: int) -> None:
        i = wid * 7919          # decorrelate the per-thread uid streams
        local: list[float] = []
        n = 0
        while time.perf_counter() < stop_at:
            uid = int(ring[i % len(ring)])
            i += 1
            t0 = time.perf_counter()
            try:
                svc.submit(scenario, wl.req(uid),
                           slo=SLO_BEST_EFFORT).result()
            except AdmissionError:
                continue
            local.append((time.perf_counter() - t0) * 1e3)
            n += 1
        with lock:
            done[0] += n
            lats.extend(local)

    with _quiesced_gc():
        t_start = time.perf_counter()
        threads = [threading.Thread(target=run, args=(w,))
                   for w in range(workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t_start
    return {"qps": round(done[0] / elapsed, 1), "completed": done[0],
            "workers": workers, "duration_s": round(elapsed, 3),
            "p50_ms": round(float(np.percentile(lats, 50)), 3) if lats else None}


def open_loop_segment(svc, scenario: str, wl: Workload, ring: np.ndarray,
                      offered_qps: float, duration: float,
                      rng: np.random.Generator, deadline_frac: float,
                      deadline_ms: float, phase: int = 0) -> dict:
    """One measurement segment: Poisson arrivals at ``offered_qps`` for
    ``duration`` seconds, a ``deadline_frac`` slice submitted with the
    deadline SLO. Latency is submit-to-future-resolution (queue wait
    included — the number an upstream caller sees). Segments are short so
    the two variants can interleave them and sample the same machine-noise
    distribution; ``aggregate_point`` merges a variant's segments."""
    from repro.serve import SLO_BEST_EFFORT, SLO_DEADLINE
    lock = threading.Lock()
    recs: list[tuple[float, float, bool]] = []

    def cb(fut, t0: float) -> None:
        t1 = time.perf_counter()
        with lock:
            recs.append((t0, t1, fut.exception() is None))

    before = _counters(svc, scenario)
    futs = []
    submitted = 0
    i = phase * 7919            # decorrelate uid streams across segments
    with _quiesced_gc():
        t_start = time.perf_counter()
        t_end = t_start + duration
        next_t = t_start
        while True:
            next_t += rng.exponential(1.0 / offered_qps)
            if next_t >= t_end:
                break
            lag = next_t - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            uid = int(ring[i % len(ring)])
            i += 1
            dl = deadline_ms if rng.random() < deadline_frac else None
            t0 = time.perf_counter()
            fut = svc.submit(scenario, wl.req(uid),
                             slo=SLO_DEADLINE if dl is not None
                             else SLO_BEST_EFFORT,
                             deadline_ms=dl)
            fut.add_done_callback(lambda f, t0=t0: cb(f, t0))
            futs.append(fut)
            submitted += 1
        _wait_futures(futs, timeout=120.0)
    after = _counters(svc, scenario)
    return {
        "lat_ms": [(t1 - t0) * 1e3 for t0, t1, ok in recs if ok],
        "submitted": submitted, "duration": duration,
        "shed_best_effort": after["shed_best_effort"]
        - before["shed_best_effort"],
        "shed_deadline": after["shed_deadline"] - before["shed_deadline"],
        "degraded": after["degraded_requests"] - before["degraded_requests"],
    }


def aggregate_point(segs: list[dict], offered_qps: float,
                    deadline_frac: float) -> dict:
    lat = sorted(x for s in segs for x in s["lat_ms"])
    total_dur = sum(s["duration"] for s in segs)
    submitted = sum(s["submitted"] for s in segs)
    shed_be = sum(s["shed_best_effort"] for s in segs)
    shed_dl = sum(s["shed_deadline"] for s in segs)
    pct = (lambda q: round(float(np.percentile(lat, q)), 3)) if lat \
        else (lambda q: None)
    return {
        "offered_qps": round(offered_qps, 1),
        "achieved_qps": round(len(lat) / total_dur, 1),
        "submitted": submitted, "completed": len(lat),
        "segments": len(segs),
        "p50_ms": pct(50), "p95_ms": pct(95), "p99_ms": pct(99),
        "shed_best_effort": shed_be, "shed_deadline": shed_dl,
        "degraded": sum(s["degraded"] for s in segs),
        "shed_rate": round((shed_be + shed_dl) / max(submitted, 1), 4),
        "deadline_frac": deadline_frac,
    }


# ---------------------------------------------------------------------------
# Per-preset run: saturation probe + offered-load curve, both variants
# ---------------------------------------------------------------------------

def run_preset(svc, preset: str, wl: Workload, ring: np.ndarray,
               args, rng: np.random.Generator) -> dict:
    """Saturation probes and curve points run as short segments with the
    two variants strictly interleaved (c,l,c,l,...) — slow machine-noise
    drift on this shared CPU lands on both sides instead of whichever
    variant happened to run second; per-variant stats merge segments."""
    scen = {v: f"{preset}:{v}" for v in VARIANTS}
    variants: dict = {v: {"curve": []} for v in VARIANTS}
    sat_probes: dict = {v: [] for v in VARIANTS}
    for _ in range(args.reps):
        for v in VARIANTS:
            sat_probes[v].append(closed_loop_saturation(
                svc, scen[v], wl, ring, args.duration, args.workers))
    for v in VARIANTS:
        qps = round(float(np.median([p["qps"] for p in sat_probes[v]])), 1)
        variants[v]["saturation"] = {"qps": qps, "probes": sat_probes[v],
                                     "workers": args.workers}
        print(f"load/{preset}/{v}/saturation,qps={qps},"
              f"workers={args.workers}", flush=True)
    # both variants face the SAME absolute offered loads — fractions of the
    # weaker variant's saturation, so every sub-1.0 point is sub-saturation
    # for both and the comparison is at fixed load
    base_qps = min(variants[v]["saturation"]["qps"] for v in VARIANTS)
    for frac in args.fractions:
        offered = max(frac * base_qps, 1.0)
        segs: dict = {v: [] for v in VARIANTS}
        for rep in range(args.reps):
            for v in VARIANTS:
                segs[v].append(open_loop_segment(
                    svc, scen[v], wl, ring, offered_qps=offered,
                    duration=args.duration, rng=rng,
                    deadline_frac=args.deadline_frac,
                    deadline_ms=args.deadline_ms, phase=rep))
        for v in VARIANTS:
            pt = aggregate_point(segs[v], offered, args.deadline_frac)
            pt["fraction_of_saturation"] = frac
            variants[v]["curve"].append(pt)
            print(f"load/{preset}/{v}/offered={frac:g}x,"
                  f"qps={pt['achieved_qps']},p99_ms={pt['p99_ms']},"
                  f"shed={pt['shed_best_effort'] + pt['shed_deadline']},"
                  f"degraded={pt['degraded']}", flush=True)
    for v in VARIANTS:
        variants[v]["pipeline_forks"] = \
            _counters(svc, scen[v])["pipeline_forks"]
    # comparison at the largest CLEARLY sub-saturation fraction: near 1.0
    # the queue rides the edge of instability and tiny service-rate
    # deltas integrate into unbounded waiting-time noise
    sub = max((f for f in args.fractions if f <= 0.75),
              default=min(args.fractions))
    idx = args.fractions.index(sub)
    cpt = variants["continuous"]["curve"][idx]
    lpt = variants["lockstep"]["curve"][idx]
    comparison = {
        "base_qps": base_qps,
        "sub_saturation_fraction": sub,
        "offered_qps": cpt["offered_qps"],
        "continuous_p99_ms": cpt["p99_ms"],
        "lockstep_p99_ms": lpt["p99_ms"],
        "p99_ratio": (round(cpt["p99_ms"] / lpt["p99_ms"], 3)
                      if cpt["p99_ms"] and lpt["p99_ms"] else None),
        "continuous_saturation_qps": variants["continuous"]["saturation"]["qps"],
        "lockstep_saturation_qps": variants["lockstep"]["saturation"]["qps"],
        "saturation_ratio": round(
            variants["continuous"]["saturation"]["qps"]
            / variants["lockstep"]["saturation"]["qps"], 3),
    }
    print(f"load/{preset}/comparison,p99_ratio={comparison['p99_ratio']},"
          f"saturation_ratio={comparison['saturation_ratio']}", flush=True)
    return {"variants": variants, "comparison": comparison}


# ---------------------------------------------------------------------------
# Chaos: deterministic fault schedule + self-healing acceptance
# ---------------------------------------------------------------------------

# Count-bounded (p=1) specs land on the same pokes every run: 3 of the 4
# slot_write faults quarantine the device tier and open the breaker
# (breaker_failures=3); dispatch faults and injected corruption exercise
# the retry path mid-stream; one worker_loop fault kills the dispatch
# thread once. Every count is finite, so the recovery phase always
# reaches a clean half-open probe and the breaker closes.
CHAOS_SITES = (
    "slot_write:error:count=4",
    "stage2_dispatch:error:after=10,count=3",
    "collect:corrupt:after=6,count=2",
    "worker_loop:error:after=4,count=1",
)


def build_chaos_plan(args):
    from repro.serve import ServePlan
    plan = ServePlan.preset("paper").evolve(
        batch__max_batch=args.max_batch, batch__min_bucket=args.B,
        batch__hedging=False, batch__linger_ms=args.linger_ms,
        cache__device_resident=True, cache__device_slots=args.device_slots,
        ft__inject=True, ft__seed=args.seed, ft__sites=CHAOS_SITES,
        ft__retries=4, ft__retry_backoff_ms=2.0,
        ft__breaker_failures=3, ft__breaker_cooldown_ms=150.0,
        ft__breaker_probes=1)
    if getattr(args, "trace", None):
        plan = plan.evolve(obs__trace=True)
    return plan


def run_chaos(svc, graph, params, wl: Workload, args) -> dict:
    """Drive one scenario under ``CHAOS_SITES`` and assert self-healing.

    Contract (the PR's acceptance): zero hung futures, bit-identical
    scores on every success vs a fault-free reference, availability above
    the floor, and the breaker walking open -> half-open -> closed.
    """
    from repro.serve import SLO_DEADLINE
    scen = "chaos"
    plan = build_chaos_plan(args)
    svc.register(scen, graph=graph, params=params, plan=plan)
    eng = svc.engine(scen)
    inj = eng.fault_injector
    assert inj is not None and eng.breaker is not None, \
        "chaos plan must arm the injector and the breaker"

    # warmup + fault-free references with the injector DISARMED: disarmed
    # pokes advance no counters, so compile-time traffic cannot consume
    # the deterministic fault counts. Scores depend only on uid % pool
    # (user feeds repeat across the universe), so one reference per pool
    # slot covers every uid in the drive.
    inj.set_armed(False)
    warm(svc, scen, wl, args.max_batch)
    pool = len(wl.ufeeds)
    refs = [svc.score(scen, wl.req(slot)).scores.copy()
            for slot in range(pool)]
    inj.set_armed(True)

    n_requests = 80
    futs = []
    for i in range(n_requests):
        uid = i % (pool * 6)      # revisit users: rebuild-after-quarantine
        dl = 1000.0 if i % 5 == 0 else None   # generous: never infeasible
        futs.append((uid, svc.submit(
            scen, wl.req(uid),
            slo=SLO_DEADLINE if dl is not None else "best_effort",
            deadline_ms=dl)))
        time.sleep(0.004)         # spread arrivals across breaker windows

    _wait_futures([f for _, f in futs], timeout=120.0)
    hung = [i for i, (_, f) in enumerate(futs) if not f.done()]
    assert not hung, f"hung futures (never resolved): {hung}"

    ok = 0
    failures: list[str] = []
    for uid, f in futs:
        if f.exception() is None:
            res = f.result()
            assert np.array_equal(res.scores, refs[uid % pool]), (
                f"chaos: successful response for uid={uid} is NOT "
                f"bit-identical to the fault-free reference")
            ok += 1
        else:
            failures.append(type(f.exception()).__name__)
    availability = ok / n_requests
    assert availability >= args.chaos_floor, (
        f"availability {availability:.3f} below floor {args.chaos_floor} "
        f"(failures: {failures})")

    # recovery: every fault count is exhausted by now (or exhausts on the
    # next few probes), so after each cooldown the half-open probe scores
    # a clean on-slots pack and the breaker closes — bounded rounds, no
    # sleep-and-hope
    for _ in range(10):
        if eng.breaker.state == "closed":
            break
        time.sleep(plan.ft.breaker_cooldown_ms / 1e3 + 0.02)
        svc.score(scen, wl.req(1))
    st = svc.stats()["scenarios"][scen]
    br = st["breaker"]
    assert br["opens"] >= 1, f"breaker never opened: {br}"
    assert br["closes"] >= 1 and br["state"] == "closed", (
        f"breaker never restored the fast path: {br}")
    assert st["device_store"]["quarantines"] >= 1, \
        "device tier was never quarantined"
    assert st["worker_crashes"] >= 1 and st["worker_respawns"] >= 1, (
        f"worker supervision never exercised: crashes="
        f"{st['worker_crashes']} respawns={st['worker_respawns']}")
    assert st["fallback_packs"] >= 1, \
        "breaker-open traffic never routed through the re-stack fallback"
    assert st["retries_attempted"] >= 1, "no retry was ever attempted"
    # fast path actually restored: a post-close request scores on slots
    post = svc.score(scen, wl.req(2))
    assert np.array_equal(post.scores, refs[2 % pool])
    assert eng.breaker.state == "closed"

    out = {
        "requests": n_requests, "ok": ok,
        "availability": round(availability, 4),
        "failure_types": sorted(set(failures)),
        "faults": st["faults"], "breaker": br,
        "quarantines": st["device_store"]["quarantines"],
        "worker_crashes": st["worker_crashes"],
        "worker_respawns": st["worker_respawns"],
        "fallback_packs": st["fallback_packs"],
        "corruptions_detected": st["corruptions_detected"],
        "retries_attempted": st["retries_attempted"],
        "retries_exhausted": st["retries_exhausted"],
        "plan": plan.to_dict(),
    }
    print(f"load/chaos,availability={availability:.3f},"
          f"ok={ok}/{n_requests},"
          f"faults={st['faults']['total_fired']},"
          f"quarantines={out['quarantines']},"
          f"breaker_opens={br['opens']},breaker_closes={br['closes']},"
          f"respawns={out['worker_respawns']},"
          f"retries={out['retries_attempted']}", flush=True)
    print("# chaos asserts passed", flush=True)
    return out


# ---------------------------------------------------------------------------
# Assertions
# ---------------------------------------------------------------------------

def smoke_asserts(results: dict) -> None:
    """Harness contracts — cheap, load-level, CI-gateable:

    * at the lowest (clearly sub-saturation) offered load, achieved qps
      tracks offered within 2x slack (the open loop is actually open);
    * the achieved curve is monotone-ish: more offered load never LOSES
      more than 30% of achieved throughput (no livelock cliff);
    * the deadline class is never shed at sub-saturation load (depth-based
      shedding must not touch it — only infeasible budgets can, and the
      smoke deadline budget is generous).
    """
    for preset, res in results.items():
        for variant, v in res["variants"].items():
            curve = v["curve"]
            lo = curve[0]
            assert lo["achieved_qps"] >= 0.5 * lo["offered_qps"], (
                f"{preset}/{variant}: achieved {lo['achieved_qps']} qps "
                f"<< offered {lo['offered_qps']} at the lowest load point")
            for a, b in zip(curve, curve[1:]):
                assert b["achieved_qps"] >= 0.7 * a["achieved_qps"], (
                    f"{preset}/{variant}: achieved qps fell "
                    f"{a['achieved_qps']} -> {b['achieved_qps']} as offered "
                    f"rose — throughput cliff under load")
            for pt in curve:
                if pt["fraction_of_saturation"] <= 0.9:
                    assert pt["shed_deadline"] == 0, (
                        f"{preset}/{variant}: {pt['shed_deadline']} deadline "
                        f"requests shed at sub-saturation load "
                        f"{pt['fraction_of_saturation']}x")
    print("# smoke asserts passed", flush=True)


def check_asserts(results: dict, tol: float) -> None:
    """The PR's acceptance: at fixed sub-saturation load the continuous
    loop's p99 must not exceed lockstep's, and its saturation qps must not
    be lower (within ``tol`` measurement slack for this shared-CPU box —
    the committed BENCH_load.json is expected to satisfy both strictly)."""
    for preset, res in results.items():
        c = res["comparison"]
        assert c["p99_ratio"] is not None and c["p99_ratio"] <= tol, (
            f"{preset}: continuous p99 {c['continuous_p99_ms']}ms > "
            f"{tol:g}x lockstep p99 {c['lockstep_p99_ms']}ms at "
            f"{c['sub_saturation_fraction']}x saturation")
        assert c["saturation_ratio"] >= 1.0 / tol, (
            f"{preset}: continuous saturation "
            f"{c['continuous_saturation_qps']} qps < lockstep "
            f"{c['lockstep_saturation_qps']} qps / {tol:g}")
    print("# check asserts passed", flush=True)


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small universe + short points + harness asserts "
                         "(the CI mode)")
    ap.add_argument("--check", action="store_true",
                    help="assert the continuous-vs-lockstep acceptance "
                         "criteria on this run")
    ap.add_argument("--chaos", action="store_true",
                    help="run the deterministic fault schedule instead of "
                         "the load curves and assert self-healing (zero "
                         "hung futures, bit-identical successes, breaker "
                         "recovery)")
    ap.add_argument("--chaos-floor", type=float, default=0.9,
                    help="minimum fraction of chaos requests that must "
                         "succeed")
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable ObsPlan tracing on every variant and "
                         "write a Perfetto-loadable Chrome trace here "
                         "(one track group per preset:variant scenario)")
    ap.add_argument("--presets", default=None,
                    help="comma list of ServePlan presets (default: "
                         "paper,vanilla; smoke: paper)")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--universe", type=int, default=None,
                    help="Zipf user universe (default 1_000_000; smoke "
                         "20_000)")
    ap.add_argument("--zipf-s", type=float, default=1.3)
    ap.add_argument("--pool", type=int, default=8,
                    help="distinct user-feed tensors reused across the "
                         "universe")
    ap.add_argument("--B", type=int, default=64,
                    help="candidate pool rows per request")
    ap.add_argument("--max-batch", type=int, default=1024)
    ap.add_argument("--linger-ms", type=float, default=1.0)
    ap.add_argument("--duration", type=float, default=None,
                    help="seconds per measurement segment (default 1.0; "
                         "smoke 0.4)")
    ap.add_argument("--reps", type=int, default=None,
                    help="interleaved segments per (point, variant) "
                         "(default 3; smoke 2)")
    ap.add_argument("--workers", type=int, default=8,
                    help="closed-loop saturation probes")
    ap.add_argument("--fractions", default=None,
                    help="comma list of offered-load fractions of "
                         "saturation (default 0.3,0.6,0.9,1.2; smoke "
                         "0.4,1.5)")
    ap.add_argument("--deadline-frac", type=float, default=0.2)
    ap.add_argument("--deadline-ms", type=float, default=250.0)
    ap.add_argument("--shed-depth", type=int, default=64)
    ap.add_argument("--degrade-depth", type=int, default=32)
    ap.add_argument("--device-slots", type=int, default=256)
    ap.add_argument("--tol", type=float, default=1.10,
                    help="--check measurement slack")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.universe is None:
        args.universe = 20_000 if args.smoke else 1_000_000
    if args.duration is None:
        args.duration = 0.4 if args.smoke else 1.0
    if args.reps is None:
        args.reps = 2 if args.smoke else 3
    if args.presets is None:
        args.presets = "paper" if args.smoke else "paper,vanilla"
    if args.fractions is None:
        args.fractions = "0.4,1.5" if args.smoke else "0.3,0.6,0.9,1.2"
    presets = [p.strip() for p in args.presets.split(",") if p.strip()]
    args.fractions = [float(f) for f in args.fractions.split(",")]

    import jax
    from repro.graph.executor import init_graph_params
    from repro.models.ranking import (PaperRankingConfig,
                                      build_paper_ranking_model)
    from repro.serve import RankingService

    cfg = PaperRankingConfig().scaled(args.scale)
    graph, cfg = build_paper_ranking_model(cfg)
    params = init_graph_params(graph, jax.random.PRNGKey(args.seed))
    wl = Workload(graph, B=args.B, pool=args.pool, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    cdf = zipf_cdf(args.universe, args.zipf_s)
    ring = sample_users(cdf, 200_000, rng)
    hot = float(np.mean(ring < args.device_slots))
    print(f"# universe={args.universe} zipf_s={args.zipf_s} "
          f"(top-{args.device_slots} users carry {hot:.0%} of traffic)",
          flush=True)

    results = {}
    plans = {}
    with RankingService() as svc:
        if args.chaos:
            results["chaos"] = run_chaos(svc, graph, params, wl, args)
        else:
            for preset in presets:
                for variant in ("continuous", "lockstep"):
                    plan = build_plan(preset, variant, args)
                    svc.register(f"{preset}:{variant}", graph=graph,
                                 params=params, plan=plan)
                    warm(svc, f"{preset}:{variant}", wl, args.max_batch)
                    if variant == "continuous":
                        plans[preset] = plan.to_dict()
            for preset in presets:
                results[preset] = run_preset(svc, preset, wl, ring, args,
                                             rng)
                results[preset]["preset"] = preset
                results[preset]["plan"] = plans[preset]
        if args.trace:
            from repro.obs import write_trace
            tracers = {sc: svc.engine(sc).tracer for sc in svc.scenarios
                       if svc.engine(sc).tracer is not None}
            write_trace(args.trace, tracers)
            print(f"# wrote trace {args.trace} "
                  f"({sum(len(t) for t in tracers.values())} events)",
                  flush=True)

    if args.smoke and not args.chaos:
        smoke_asserts(results)
    if args.check and not args.chaos:
        check_asserts(results, args.tol)

    if args.json:
        payload = {
            "bench": "load", "config": "paper_ranking",
            "scale": args.scale, "universe": args.universe,
            "zipf_s": args.zipf_s, "pool_users": args.pool, "B": args.B,
            "hot_traffic_share": round(hot, 4),
            "duration_s": args.duration, "workers": args.workers,
            "fractions": args.fractions,
            "deadline_frac": args.deadline_frac,
            "deadline_ms": args.deadline_ms,
            "smoke": args.smoke, "presets": results,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
