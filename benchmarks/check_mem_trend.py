"""CI gate for the memory-hierarchy benchmark (``benchmarks.memtier``).

Compares a fresh ``--smoke`` payload against the committed
``BENCH_mem.json`` baseline and fails (exit 1) when the tier contracts
break. This gate — not per-run asserts inside ``memtier`` — owns them:

* **trend**: every ``memtier/*`` row present in BOTH files must not
  regress by more than ``--max-regress`` (default 60% — per-request
  medians on shared CI runners are noisy) in ``us_per_call``;
* **tier ordering (fresh)**: for every fresh point, the cold-hit median
  must be STRICTLY below the recompute median — one arena read must beat
  a stage-1 recompute or the tier is not paying for itself — and every
  class must actually occur (a stream that never recomputes or never
  cold-hits proves nothing);
* **hit-rate floor (fresh)**: every fresh point's combined (hot + cold)
  hit rate must clear ``--min-hit`` (default 0.85 — smoke universes are
  small);
* **bit-identity (fresh)**: the cache-off double-score check must have
  run on the fresh payload and passed, covering >1 request class;
* **acceptance (baseline)**: the committed baseline must carry the
  U=1M point at >= ``--accept-hit`` (default 0.9) combined hit rate with
  cold strictly below recompute — the tentpole claim, pinned to the
  committed artifact so a smoke-only CI run still enforces it.

Usage (what CI runs):

    python -m benchmarks.memtier --smoke --json BENCH_mem_fresh.json
    python -m benchmarks.check_mem_trend \
        --baseline BENCH_mem.json --fresh BENCH_mem_fresh.json

Faster-than-baseline rows never gate; improvements are committed by
regenerating ``BENCH_mem.json``.
"""
from __future__ import annotations

import argparse
import json
import sys

CLASSES = ("hot", "cold", "recompute")


def _rows(payload: dict) -> dict[str, dict]:
    return {r["name"]: r for r in payload.get("rows", [])
            if r["name"].startswith("memtier/")}


def _points(payload: dict) -> dict[str, dict]:
    return payload.get("memtier", {}).get("points", {})


def check(baseline: dict, fresh: dict, max_regress: float,
          min_hit: float, accept_hit: float,
          accept_universe: int = 1_000_000) -> list[str]:
    """Return the list of failure messages (empty == gate passes)."""
    failures: list[str] = []
    base_rows, fresh_rows = _rows(baseline), _rows(fresh)

    # -- trend: per-row regression gate on shared rows ----------------------
    print(f"{'row':40s} {'base_us':>10s} {'fresh_us':>10s} {'delta':>8s}")
    for name in sorted(set(base_rows) & set(fresh_rows)):
        b = float(base_rows[name]["us_per_call"])
        f = float(fresh_rows[name]["us_per_call"])
        delta = (f - b) / b if b else 0.0
        mark = ""
        if delta > max_regress:
            mark = "  << REGRESSION"
            failures.append(
                f"regression: {name} {b:.1f}us -> {f:.1f}us "
                f"({delta:+.0%} > {max_regress:.0%} budget)")
        print(f"{name:40s} {b:10.1f} {f:10.1f} {delta:+7.0%}{mark}")
    if not set(base_rows) & set(fresh_rows):
        failures.append(
            "no shared memtier/* rows between baseline and fresh — the "
            "smoke universe must overlap the committed sweep")

    # -- fresh contracts: tier ordering + hit-rate floor ---------------------
    fresh_points = _points(fresh)
    if not fresh_points:
        failures.append("fresh payload has no memtier points")
    for key, p in sorted(fresh_points.items(), key=lambda kv: int(kv[0])):
        for cls in CLASSES:
            if not p.get(cls, {}).get("n"):
                failures.append(
                    f"U={key}: request class {cls!r} never occurred — the "
                    f"stream exercises nothing")
        cold = p.get("cold", {}).get("p50_us")
        rec = p.get("recompute", {}).get("p50_us")
        if cold is not None and rec is not None and not cold < rec:
            failures.append(
                f"U={key}: cold-hit median {cold}us not strictly below "
                f"recompute {rec}us — the arena read stopped paying for "
                f"itself")
        hr = p.get("hit_rate", 0.0)
        print(f"# U={key}: hit_rate={hr} warmed={p.get('warmed')} "
              f"cold={cold}us recompute={rec}us")
        if hr < min_hit:
            failures.append(
                f"U={key}: combined hit rate {hr} < floor {min_hit}")

    # -- fresh bit-identity ---------------------------------------------------
    ident = [p for p in fresh_points.values() if "bit_identical" in p]
    if not ident:
        failures.append("fresh payload ran no bit-identity check")
    for p in ident:
        if not p["bit_identical"]:
            failures.append(
                f"U={p['universe']}: tiered scores diverged from the "
                f"cache-off engine")
        if len(p.get("identity_classes", [])) < 2:
            failures.append(
                f"U={p['universe']}: bit-identity covered only "
                f"{p.get('identity_classes')} — needs >1 request class")

    # -- baseline acceptance: the committed U=1M claim ------------------------
    accept = _points(baseline).get(str(accept_universe))
    if accept is None:
        failures.append(
            f"committed baseline is missing the U={accept_universe} "
            f"acceptance point")
    else:
        hr = accept.get("hit_rate", 0.0)
        cold = accept.get("cold", {}).get("p50_us")
        rec = accept.get("recompute", {}).get("p50_us")
        print(f"# baseline U={accept_universe}: hit_rate={hr} "
              f"cold={cold}us recompute={rec}us")
        if hr < accept_hit:
            failures.append(
                f"baseline U={accept_universe} hit rate {hr} < acceptance "
                f"floor {accept_hit}")
        if cold is None or rec is None or not cold < rec:
            failures.append(
                f"baseline U={accept_universe}: cold median {cold}us must "
                f"be strictly below recompute {rec}us")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_mem.json",
                    help="committed memtier JSON (the trend baseline)")
    ap.add_argument("--fresh", default="BENCH_mem_fresh.json",
                    help="memtier JSON from this run")
    ap.add_argument("--max-regress", type=float, default=0.60,
                    help="per-row us_per_call regression budget "
                         "(0.60 = fail beyond +60%%)")
    ap.add_argument("--min-hit", type=float, default=0.85,
                    help="combined hit-rate floor for every fresh point")
    ap.add_argument("--accept-hit", type=float, default=0.90,
                    help="hit-rate floor for the committed U=1M point")
    args = ap.parse_args()
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures = check(baseline, fresh, args.max_regress, args.min_hit,
                     args.accept_hit)
    if failures:
        print(f"\nFAIL: {len(failures)} memtier violation(s)")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nOK: memtier rows within trend budget, tier contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
