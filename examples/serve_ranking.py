"""End-to-end serving driver: the coarse-ranking stage of Fig. 2.

Part 1 — paradigm comparison: a stream of requests (one user, thousands of
candidates each) flows through the two-stage ServingEngine: the user-only
subgraph runs once per user and its outputs are cached (stage 1);
candidates are scored by the separately compiled batched residual (stage 2)
in power-of-two batch buckets. Compares the three inference paradigms of
Fig. 1 on the same request stream.

Part 2 — async cross-user coalescing: a simulated multi-user burst (ragged
pool sizes, mixed cache hits/misses) is submitted concurrently to the
``CoalescingBatcher``, which packs candidate chunks from different users
into shared stage-2 buckets — each executed as ONE row-wise call (every
candidate row gathers its own user's cached reps). Scores are bit-identical
to the sequential per-request loop; throughput is reported for both.

Part 3 — overload & SLO admission: the same graph behind a
``RankingService`` with the continuous dispatch loop and deliberately tiny
admission thresholds, hit with a burst far past what the queue will hold.
best_effort requests are shed (typed ``AdmissionError``, failing fast at
submit) or degraded (candidate pool truncated) while every deadline-tagged
request completes at full pool size — the SLO tiering in one printout.

Part 4 — hierarchical memory tier: the user universe is bulk-``warm``ed
OFFLINE into the host-RAM cold arena (``MemPlan.cold_tier``) through the
engine's own jitted stage 1, then the part-2 burst is replayed against a
deliberately tiny hot LRU. Every request is served from a tier — hot hit
or one cold-arena read — with zero online stage-1 recomputes, scores
bit-identical to the recompute path, and repeat traffic promoted back to
the hot tier by the async promotion worker.

  PYTHONPATH=src python examples/serve_ranking.py [--candidates 4096]
"""
import argparse
import time

import jax
import numpy as np

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve import (AdmissionError, CoalescingBatcher, RankingService,
                         SLO_DEADLINE, ServePlan, ServeRequest, ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidates", type=int, default=4096)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=2048)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--linger-ms", type=float, default=3.0,
                    help="batcher linger window for collecting co-arriving "
                         "requests")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route mari_dense through the fused Pallas kernel "
                         "(interpret mode off-TPU: slow, validation only)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of parts "
                         "2+3 (coalescing + overload) — overlapped groups "
                         "show as concurrent group:N tracks")
    args = ap.parse_args()

    graph, cfg = build_paper_ranking_model(PaperRankingConfig().scaled(args.scale))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}

    # user features are a function of the USER, not the request: the
    # rep-cache contract says one (user_id, feature_version) key maps to
    # one feature set. (The single-stage vani engine no longer caches raw
    # feeds, so a stream violating this would let vani see per-request
    # features while uoi/mari serve cached reps — stale-cache semantics,
    # not a paradigm difference.)
    user_feeds = {}

    def make_request(r, key, candidates):
        uid = r % args.users
        feeds = make_recsys_feeds(graph, candidates, key)
        if uid not in user_feeds:
            user_feeds[uid] = {k2: v for k2, v in feeds.items()
                               if k2 in user_in}
        return ServeRequest(
            user_id=uid,
            user_feeds=user_feeds[uid],
            candidate_feeds={k2: v for k2, v in feeds.items()
                             if k2 not in user_in})

    def request_stream(key):
        for r in range(args.requests):
            key, k = jax.random.split(key)
            yield make_request(r, k, args.candidates)

    # ---- part 1: VanI vs UOI vs MaRI, sequential per-request loop ----------
    print(f"requests={args.requests} users={args.users} "
          f"candidates/request={args.candidates} max_batch={args.max_batch}")
    ref_scores = None
    # ONE declarative plan, evolved per paradigm — the three engines differ
    # only in graph.mode (repro.serve.plan is the config spine)
    base_plan = ServePlan().evolve(batch__max_batch=args.max_batch,
                                   kernel__use_pallas=args.use_pallas)
    for mode in ("vani", "uoi", "mari"):
        eng = ServingEngine(graph, params,
                            plan=base_plan.evolve(graph__mode=mode))
        if eng.conversion:
            print(f"[{mode}] MaRI rewrote "
                  f"{len(eng.conversion.rewrites)} matmuls")
        if eng.two_stage:
            print(f"[{mode}] {eng.split.summary()}")
        lats, hits, hedges = [], 0, 0
        last = None
        for req in request_stream(jax.random.PRNGKey(42)):
            res = eng.score(req)
            lats.append(res.latency_ms)
            hits += res.user_cache_hit
            hedges += res.hedged
            last = res.scores
        lats = np.asarray(lats[2:])   # drop warm-up/compile
        if ref_scores is None:
            ref_scores = last
        else:
            err = np.abs(ref_scores - last).max()
            assert err < 1e-3, f"{mode} diverged from VanI by {err}"
        extra = (f"  stage1_runs={eng.stage1_calls}"
                 f"  stage2_compiles={eng.stage2_compilations}"
                 if eng.two_stage else "")
        print(f"[{mode}] avg={lats.mean():7.2f}ms  "
              f"p50={np.percentile(lats, 50):7.2f}ms  "
              f"p99={np.percentile(lats, 99):7.2f}ms  "
              f"user_cache_hits={hits}/{args.requests}  "
              f"hedged={hedges}{extra}")
        eng.close()
    print("all modes score-identical ✓")

    # ---- part 2: async multi-user stream through the coalescing batcher ----
    print(f"\n-- async coalescing (mari): multi-user burst, ragged pools, "
          f"linger={args.linger_ms}ms --")
    # hedging off for the timed comparison: duplicate executions on a
    # shared CPU would contaminate the seq-vs-coalesced req/s numbers
    eng = ServingEngine(graph, params, plan=base_plan.evolve(
        graph__mode="mari", batch__hedging=False,
        obs__trace=args.trace is not None))
    rng = np.random.default_rng(0)
    keys = jax.random.split(jax.random.PRNGKey(7), args.requests)
    burst = [make_request(r, keys[r],
                          int(rng.integers(args.candidates // 4,
                                           args.candidates)))
             for r in range(args.requests)]

    seq_results = [eng.score(r) for r in burst]      # warms every cache/shape
    t0 = time.perf_counter()
    for r in burst:
        eng.score(r)
    seq_s = time.perf_counter() - t0

    with CoalescingBatcher(eng, linger_ms=args.linger_ms) as batcher:
        co_results = batcher.score_many(burst)       # warm coalesced shapes
        # counters are lifetime-cumulative; snapshot so the printout
        # reflects only the timed burst
        calls0, cross0, batches0 = (eng.stage2_calls, eng.coalesced_calls,
                                    batcher.batches)
        t0 = time.perf_counter()
        co_results = batcher.score_many(burst)
        co_s = time.perf_counter() - t0
        calls = eng.stage2_calls - calls0
        cross = eng.coalesced_calls - cross0
        batches = batcher.batches - batches0

    for s, c in zip(seq_results, co_results):
        assert np.array_equal(s.scores, c.scores), "coalescing changed scores"
    rows = sum(r.scores.shape[0] for r in co_results)
    print(f"[sequential] {args.requests / seq_s:7.1f} req/s "
          f"({rows / seq_s:10.0f} candidates/s)")
    print(f"[coalesced ] {args.requests / co_s:7.1f} req/s "
          f"({rows / co_s:10.0f} candidates/s)  "
          f"stage2_calls/burst={calls}  "
          f"cross_user_calls={cross}  batches={batches}")
    print("coalesced scores bit-identical to per-request ✓")
    eng.close()

    # ---- part 3: overload burst against SLO-tiered admission control -------
    print("\n-- overload & admission (mari): burst past the queue, tiny "
          "shed/degrade depths --")
    # thresholds are deliberately small so a laptop-sized burst trips every
    # tier: shed best_effort beyond 8 queued, halve its candidate pool
    # beyond 4 queued; deadline-tagged requests are exempt from both
    over_plan = base_plan.evolve(
        graph__mode="mari", batch__hedging=False, batch__continuous=True,
        batch__admission=True, batch__shed_queue_depth=8,
        batch__degrade_queue_depth=4, batch__degrade_frac=0.5,
        batch__linger_ms=args.linger_ms,
        obs__trace=args.trace is not None)
    svc = RankingService(over_plan)
    svc.register("ranking", graph=graph, params=params, plan=over_plan)
    for r in burst[:4]:                       # warm shapes + rep caches
        svc.score("ranking", r)

    futs = []
    for i, r in enumerate(burst * 3):         # ~3x the part-2 burst at once
        deadline = i % 5 == 0                 # every 5th request is urgent
        futs.append((deadline, svc.submit(
            "ranking", r, slo=SLO_DEADLINE if deadline else "best_effort",
            deadline_ms=250.0 if deadline else None)))
    # a shed future is already failed (fast, typed) when submit returns —
    # it never hangs; admitted futures resolve to ServeResults
    done, shed = [], 0
    for d, f in futs:
        err = f.exception()
        if err is not None:
            assert isinstance(err, AdmissionError), err
            assert not d, "deadline work must never be shed by depth"
            assert err.queue_depth >= 8, err
            shed += 1
        else:
            done.append((d, f.result()))
    assert all(not res.degraded for d, res in done if d), \
        "deadline work must never be degraded"
    degraded = sum(res.degraded for _, res in done)

    sc = svc.stats()["scenarios"]["ranking"]
    print(f"[burst     ] submitted={len(burst) * 3}  "
          f"completed={len(done)}  shed_at_submit={shed}  "
          f"degraded={degraded}")
    print(f"[counters  ] shed_best_effort={sc['shed_best_effort']}  "
          f"shed_deadline={sc['shed_deadline']}  "
          f"degraded_requests={sc['degraded_requests']}  "
          f"pipeline_forks={sc['pipeline_forks']}")
    print("deadline tier untouched under overload ✓")

    # ---- part 4: memory tier — warm offline, cold-hit online, promote -----
    print("\n-- memory tier (mari): bulk-warm offline, serve from the cold "
          "arena, promote repeat users --")
    # hot LRU deliberately smaller than the user universe: users live ONLY
    # in the host-RAM arena until the promotion worker sees repeat traffic
    mem_eng = ServingEngine(graph, params, plan=base_plan.evolve(
        graph__mode="mari", batch__hedging=False,
        cache__max_cached_users=2, mem__cold_tier=True))
    warmed = mem_eng.warm(sorted(user_feeds.items()))
    warm_results = [mem_eng.score(r) for r in burst]
    hot = sum(r.user_cache_hit for r in warm_results)
    cold = sum(r.cold_hit for r in warm_results)
    assert mem_eng.stage1_calls == 0, \
        "warmed users must never pay stage 1 online"
    for w, s in zip(warm_results, seq_results):
        assert np.array_equal(w.scores, s.scores), \
            "warmed reps changed scores"
    mem_eng.flush_promotions()
    ms = mem_eng.mem_stats()
    print(f"[warm      ] users={warmed}  "
          f"arena_bytes={ms['cold']['bytes']}  "
          f"stage1_launches={ms['warm']['stage1_launches']}")
    print(f"[stream    ] hot_hits={hot}  cold_hits={cold}  "
          f"stage1_recomputes={mem_eng.stage1_calls}  "
          f"promotions={ms['promote']['promotions']}  "
          f"demotions={ms['demotions']}")
    print("every request tier-served, warmed reps bit-identical to "
          "recomputed ✓")
    mem_eng.close()
    if args.trace:
        from repro.obs import write_trace
        tracers = {}
        if eng.tracer is not None:
            tracers["coalesce"] = eng.tracer      # part 2 (events persist)
        t3 = svc.engine("ranking").tracer
        if t3 is not None:
            tracers["overload"] = t3              # part 3
        write_trace(args.trace, tracers)
        print(f"wrote trace -> {args.trace} "
              f"({sum(len(t) for t in tracers.values())} events; load it "
              f"at https://ui.perfetto.dev)")
    svc.close()


if __name__ == "__main__":
    main()
