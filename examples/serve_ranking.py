"""End-to-end serving driver: the coarse-ranking stage of Fig. 2.

A stream of requests (one user, thousands of candidates each) flows through
the two-stage ServingEngine: the user-only subgraph runs once per user and
its outputs are cached (stage 1); candidates are scored by the separately
compiled batched residual (stage 2) in power-of-two batch buckets. Compares
the three inference paradigms of Fig. 1 on the same request stream.

  PYTHONPATH=src python examples/serve_ranking.py [--candidates 4096]
"""
import argparse

import jax
import numpy as np

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve.engine import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidates", type=int, default=4096)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=2048)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route mari_dense through the fused Pallas kernel "
                         "(interpret mode off-TPU: slow, validation only)")
    args = ap.parse_args()

    graph, cfg = build_paper_ranking_model(PaperRankingConfig().scaled(args.scale))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}

    def request_stream(key):
        for r in range(args.requests):
            key, k = jax.random.split(key)
            feeds = make_recsys_feeds(graph, args.candidates, k)
            yield ServeRequest(
                user_id=r % args.users,
                user_feeds={k2: v for k2, v in feeds.items() if k2 in user_in},
                candidate_feeds={k2: v for k2, v in feeds.items()
                                 if k2 not in user_in})

    print(f"requests={args.requests} users={args.users} "
          f"candidates/request={args.candidates} max_batch={args.max_batch}")
    ref_scores = None
    for mode in ("vani", "uoi", "mari"):
        eng = ServingEngine(graph, params, mode=mode,
                            max_batch=args.max_batch,
                            use_pallas=args.use_pallas)
        if eng.conversion:
            print(f"[{mode}] MaRI rewrote "
                  f"{len(eng.conversion.rewrites)} matmuls")
        if eng.two_stage:
            print(f"[{mode}] {eng.split.summary()}")
        lats, hits = [], 0
        last = None
        for req in request_stream(jax.random.PRNGKey(42)):
            res = eng.score(req)
            lats.append(res.latency_ms)
            hits += res.user_cache_hit
            last = res.scores
        lats = np.asarray(lats[2:])   # drop warm-up/compile
        if ref_scores is None:
            ref_scores = last
        else:
            err = np.abs(ref_scores - last).max()
            assert err < 1e-3, f"{mode} diverged from VanI by {err}"
        extra = (f"  stage1_runs={eng.stage1_calls}"
                 f"  stage2_compiles={eng.stage2_compilations}"
                 if eng.two_stage else "")
        print(f"[{mode}] avg={lats.mean():7.2f}ms  "
              f"p50={np.percentile(lats, 50):7.2f}ms  "
              f"p99={np.percentile(lats, 99):7.2f}ms  "
              f"user_cache_hits={hits}/{args.requests}{extra}")
    print("all modes score-identical ✓")


if __name__ == "__main__":
    main()
