"""Quickstart: MaRI in 60 seconds.

Builds a small user/item/cross ranking graph, auto-detects the eligible
feature-fusion matmuls with GCA (Algorithm 1), re-parameterizes them
(Eq. 7), and shows (a) bit-level losslessness and (b) the latency win.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common import timeit
from repro.core import apply_mari, run_gca
from repro.graph import Executor, GraphBuilder, init_graph_params

# 1. A ranking model: user tower feeds a fusion MLP together with
#    per-candidate item/cross features. D_user dominates (the industrial
#    regime the paper targets: rich user profiles, B candidates).
b = GraphBuilder()
user = b.input("user_profile", shape=(2000,), domain="user")
item = b.input("item_feats", shape=(250,), domain="item")
cross = b.input("cross_feats", shape=(250,), domain="cross")
u_emb = b.dense("user_tower", user, 512, activation="relu")
fusion = b.concat("fusion", [u_emb, item, cross])
h = b.dense("fc1", fusion, 512, activation="relu")
h = b.dense("fc2", h, 128, activation="relu")
logit = b.dense("ctr_logit", h, 1)
b.output(logit)
graph = b.graph

# 2. GCA finds what to rewrite — no manual annotation of fc1.
gca = run_gca(graph)
print(gca.summary())

# 3. Convert the trained weights (here: random init stands in).
params = init_graph_params(graph, jax.random.PRNGKey(0))
mari_graph, mari_params, conv = apply_mari(graph, params)
print(conv.summary())

# 4. Score B=4096 candidates for one user, three ways.
B = 4096
key = jax.random.PRNGKey(1)
feeds = {
    "user_profile": jax.random.normal(key, (1, 2000)),
    "item_feats": jax.random.normal(key, (B, 250)),
    "cross_feats": jax.random.normal(key, (B, 250)),
}
vani = jax.jit(Executor(graph, "vani").run)
uoi = jax.jit(Executor(graph, "uoi").run)
mari = jax.jit(Executor(mari_graph, "uoi").run)

s_vani = vani(params, feeds)["ctr_logit"]
s_mari = mari(mari_params, feeds)["ctr_logit"]
err = float(np.abs(np.asarray(s_vani) - np.asarray(s_mari)).max())
print(f"max |VanI - MaRI| over {B} candidates: {err:.2e}  (lossless)")
assert err < 1e-4

for name, fn, p in [("VanI", vani, params), ("UOI", uoi, params),
                    ("MaRI", mari, mari_params)]:
    t = timeit(lambda: fn(p, feeds), warmup=2, iters=10)
    print(f"{name:>5}: {t['mean_us'] / 1e3:8.2f} ms/call  "
          f"(p99 {t['p99_us'] / 1e3:.2f} ms)")
