"""GCA demo: Algorithm 1 on (a) the graph IR and (b) a raw traced jaxpr.

Shows the coloring, the boundary concats, and why nodes behind a
nonlinearity are NOT eligible — plus the jaxpr-level auditor that works on
any jitted model function.

  PYTHONPATH=src python examples/gca_demo.py
"""
import jax
import jax.numpy as jnp

from repro.core import Color, detect_in_jaxpr, run_gca
from repro.models.ranking import (PaperRankingConfig,
                                  build_paper_ranking_model,
                                  expected_eligible)

# ---- (a) graph IR: the paper's own ranking model -------------------------
graph, cfg = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
res = run_gca(graph)
print("=== GCA on the paper's ranking model (Fig. 1) ===")
print(res.summary())
print("\nnode colors:")
for name, color in res.colors.items():
    marker = {Color.YELLOW: "Y", Color.BLUE: "B", Color.UNCOLORED: "."}[color]
    star = " <-- MaRI-eligible" if name in res.eligible else ""
    print(f"  [{marker}] {name}{star}")

expect = expected_eligible(cfg)
found = set(res.eligible)
print(f"\npaper-named sites found automatically: {sorted(expect & found)}")
print(f"extra sites GCA discovered: {sorted(found - expect)}")
assert expect <= found

# ---- (b) jaxpr-level detection on an arbitrary jitted function ------------
print("\n=== jaxpr-GCA on a hand-written model function ===")


def my_model(params, feeds):
    u = jax.nn.relu(feeds["user_vec"] @ params["wu"])
    z = jnp.concatenate(
        [jnp.broadcast_to(u, (feeds["item_vec"].shape[0], u.shape[-1])),
         feeds["item_vec"]], axis=-1)
    h = z @ params["w1"]                    # eligible (pre-activation)
    h2 = jax.nn.relu(h) @ params["w2"]      # NOT eligible (behind relu)
    return h2


params = {"wu": jnp.zeros((32, 16)), "w1": jnp.zeros((48, 64)),
          "w2": jnp.zeros((64, 1))}
feeds = {"user_vec": jnp.zeros((1, 32)), "item_vec": jnp.zeros((100, 32))}
report = detect_in_jaxpr(my_model,
                         {"user_vec": "user", "item_vec": "item"},
                         params, feeds)
print(report.summary())
assert len(report.eligible) == 1
print("exactly the pre-activation matmul is flagged ✓")
