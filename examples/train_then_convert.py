"""End-to-end driver: TRAIN the paper's ranking model (MMoE + cross-attention
+ task towers) for a few hundred steps, CONVERT with GCA + MaRI, and verify
the deployment claim: identical scores, identical AUC, faster serving.

This is the full production workflow of §2.5 — training pipeline untouched,
inference graph re-parameterized after training.

  PYTHONPATH=src python examples/train_then_convert.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.common import timeit, tree_size
from repro.core import apply_mari
from repro.data.features import make_recsys_feeds
from repro.graph import Executor, init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.train.loop import LoopConfig, train_loop
from repro.train.losses import auc, bce_with_logits
from repro.train.optim import adam, apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", type=float, default=0.05,
                    help="model scale (1.0 = paper dims, CPU-slow)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ranking_ckpt")
    args = ap.parse_args()

    cfg = PaperRankingConfig().scaled(args.scale)
    graph, cfg = build_paper_ranking_model(cfg)
    outputs = list(graph.outputs)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    print(f"[1/4] built ranking model: {len(graph.nodes)} nodes, "
          f"{tree_size(params) / 1e6:.1f}M params, {len(outputs)} tasks")

    # synthetic 'ground truth': a frozen teacher generates labels so AUC
    # is a meaningful quantity.
    teacher = init_graph_params(graph, jax.random.PRNGKey(99))
    ex = Executor(graph, "vani")

    def gen_batch(key, bsz=64):
        feeds = make_recsys_feeds(graph, bsz, key, tile_user=True)
        t = ex.run(teacher, feeds)
        logits = jnp.concatenate([t[o] for o in outputs], -1)
        labels = (logits > jnp.median(logits, axis=0)).astype(jnp.float32)
        return feeds, labels

    opt = adam(2e-3)

    def step(state, batch):
        feeds, labels = batch
        def loss_fn(p):
            out = ex.run(p, feeds)
            return bce_with_logits(
                jnp.concatenate([out[o] for o in outputs], -1), labels)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates),
                 "opt": opt_state}, {"loss": loss})

    step = jax.jit(step)

    def batches():
        key = jax.random.PRNGKey(1)
        while True:
            key, k = jax.random.split(key)
            yield gen_batch(k)

    print(f"[2/4] training {args.steps} steps (ckpt + resume enabled)...")
    mgr = CheckpointManager(args.ckpt_dir, max_to_keep=2)
    state, hist = train_loop(
        step, {"params": params, "opt": opt.init(params)}, batches(), mgr,
        LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=50))
    params = state["params"]

    print("[3/4] GCA + MaRI conversion (training pipeline untouched)...")
    mari_graph, mari_params, conv = apply_mari(graph, params)
    print("   ", conv.summary())

    # evaluation: scores + AUC before/after conversion
    feeds, labels = gen_batch(jax.random.PRNGKey(12345), bsz=512)
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    sfeeds = {k: (v[:1] if k in user_in else v) for k, v in feeds.items()}
    base = ex.run(params, feeds)
    base_logits = np.asarray(jnp.concatenate([base[o] for o in outputs], -1))
    mex = Executor(mari_graph, "uoi")
    mout = mex.run(mari_params, sfeeds)
    mari_logits = np.asarray(jnp.concatenate([mout[o] for o in outputs], -1))

    labels_np = np.asarray(labels)
    for t in range(len(outputs)):
        a0 = auc(base_logits[:, t], labels_np[:, t])
        a1 = auc(mari_logits[:, t], labels_np[:, t])
        print(f"    task {t}: AUC before={a0:.6f} after={a1:.6f} "
              f"delta={abs(a0 - a1):.2e}")
        assert abs(a0 - a1) < 1e-9, "MaRI must be lossless"

    print("[4/4] serving latency (B=2048 candidates/request):")
    B = 2048
    bench_feeds = make_recsys_feeds(graph, B, jax.random.PRNGKey(7))
    for name, g, p, mode in [("UOI (prod baseline)", graph, params, "uoi"),
                             ("MaRI", mari_graph, mari_params, "uoi")]:
        fn = jax.jit(Executor(g, mode).run)
        t = timeit(lambda: fn(p, bench_feeds), warmup=3, iters=20)
        print(f"    {name:<20} {t['mean_us'] / 1e3:8.2f} ms "
              f"(p99 {t['p99_us'] / 1e3:.2f} ms)")


if __name__ == "__main__":
    main()
