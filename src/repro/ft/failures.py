"""Fault-tolerance control plane (simulated — this container has one host,
so the DETECTION and PLANNING layers are real code exercised by tests, while
the transport (who pings whom) is an injectable clock/callback).

* HeartbeatMonitor — declares a worker dead after ``timeout`` without a
  heartbeat; the training loop polls it each step and triggers
  checkpoint-restore + re-mesh when membership changes.
* plan_elastic_remesh — given surviving device count, picks the largest
  valid (data, model) mesh that preserves the TP degree (model axis is
  topology-constrained; DP shrinks), and reports the batch re-split.
* HedgePolicy — straggler mitigation for serving. The policy (rolling-p99
  deadline) and its real executor (duplicate execution, first result wins)
  now live in ``repro.serve.hedging``; the name is re-exported here for
  backward compatibility — lazily, so this module stays importable without
  pulling the serve/JAX stack into stdlib-only control-plane processes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


def __getattr__(name):            # lazy back-compat re-export (PEP 562)
    if name == "HedgePolicy":
        from repro.serve.hedging import HedgePolicy
        return HedgePolicy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class HeartbeatMonitor:
    def __init__(self, workers: list[str], timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last_seen = {w: clock() for w in workers}
        self._removed: set[str] = set()

    def heartbeat(self, worker: str) -> None:
        # removal is sticky: a stray beat from a decommissioned worker
        # (e.g. one the remesh already planned around) must not silently
        # re-register it — rejoining goes through the explicit add()
        if worker in self._removed:
            return
        self.last_seen[worker] = self.clock()

    def add(self, worker: str) -> None:
        """Explicitly (re-)register a worker, clearing sticky removal."""
        self._removed.discard(worker)
        self.last_seen[worker] = self.clock()

    def dead(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t <= self.timeout]

    def remove(self, worker: str) -> None:
        self.last_seen.pop(worker, None)
        self._removed.add(worker)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]
    dropped_devices: int
    global_batch_scale: float     # keep per-device batch constant
    notes: str = ""


def plan_elastic_remesh(old_shape: tuple[int, ...], axes: tuple[str, ...],
                        surviving_devices: int) -> ElasticPlan:
    """Shrink DP axes to the largest power-of-two that fits the survivors
    while preserving the model (TP) axis — TP re-layout would need a full
    resharding of every weight, DP shrink only re-splits the batch."""
    model = old_shape[axes.index("model")]
    if surviving_devices < model:
        raise ValueError(
            f"cannot preserve TP={model} with {surviving_devices} devices; "
            "full re-layout required")
    dp_budget = surviving_devices // model
    new_dp = 1
    while new_dp * 2 <= dp_budget:
        new_dp *= 2
    if "pod" in axes:
        # collapse pod into data when a pod is partially lost
        new_shape = tuple(
            {"pod": 1, "data": new_dp, "model": model}[a] for a in axes)
    else:
        new_shape = tuple(
            {"data": new_dp, "model": model}[a] for a in axes)
    old_dp = 1
    for a, s in zip(axes, old_shape):
        if a != "model":
            old_dp *= s
    return ElasticPlan(
        old_shape=old_shape, new_shape=new_shape, axes=axes,
        dropped_devices=old_dp * model - surviving_devices,
        global_batch_scale=new_dp / old_dp,
        notes=f"DP {old_dp}->{new_dp}, TP preserved at {model}")
