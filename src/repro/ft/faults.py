"""Deterministic fault injection for the serving runtime.

A ``FaultInjector`` is a registry of *armed fault specs* keyed by named
sites threaded through the serve stack.  Each hot-path hook calls
``injector.poke(site)``; when a spec for that site decides to fire, the
poke either

* raises a typed ``FaultInjected`` (kind ``error``),
* sleeps ``delay_ms`` milliseconds (kind ``delay``), or
* returns the ``CORRUPT`` sentinel (kind ``corrupt``) — the caller then
  poisons its payload with NaN, which propagates through stage-2 matmuls
  to the scores and is *detected* at collect (the detectable-corruption
  contract: a corrupted response is never silently served).

Everything is deterministic: each site draws from its own
``random.Random`` seeded ``crc32(site) ^ seed`` (``crc32``, not
``hash()``, which varies per process), and ``count=K`` / ``after=N``
params bound exactly which pokes fire regardless of probability.  The
chaos harness leans on this to script breaker transitions: with
``count``-bounded ``p=1`` specs the Nth failure — and therefore the
open → half-open → close walk — lands on the same poke every run.

Spec strings (carried on ``ServePlan.ft.sites``)::

    site:kind[:key=value[,key=value...]]

    slot_write:error                      every slot write fails
    slot_write:error:count=4              ... only the first 4
    stage2_dispatch:error:after=10,count=3  pokes 11..13 fail
    collect:corrupt:p=0.5                 each collect corrupts w.p. 0.5
    transfer_copy:delay:delay_ms=25       25 ms stall per transfer

Module import is stdlib-only (``FaultInjected`` is imported lazily from
``repro.serve.errors`` at fire time) so plan validation can parse specs
without pulling jax.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
import zlib

# The injection sites wired through the serve stack.  Specs naming any
# other site are rejected at plan construction.
SITES = (
    "stage1",           # engine: user-rep compute (after a cache miss)
    "pack",             # engine: greedy pack formation / write barrier
    "stage2_dispatch",  # engine: stage-2 executable launch
    "transfer_copy",    # engine: host->device candidate buffer transfer
    "slot_write",       # cache: donated device-table row write
    "table_fork",       # cache: copy-on-write generation fork
    "collect",          # engine: per-pack result unpack
    "worker_loop",      # batcher: dispatch-loop group formation
    "spmd_heartbeat",   # dist runner: per-step worker heartbeat
)

FAULT_SITES = SITES               # the public alias re-exported by repro.ft

KINDS = ("error", "delay", "corrupt")

#: Sentinel returned by ``poke`` for kind ``corrupt``.  Callers that can
#: poison a float payload do so with NaN; sites with no payload treat it
#: like an error.
CORRUPT = "corrupt"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One parsed fault spec: where, what, and exactly when."""

    site: str
    kind: str
    p: float = 1.0              # fire probability per eligible poke
    count: int | None = None    # max fires (None = unbounded)
    after: int = 0              # skip the first N pokes at this site
    delay_ms: float = 10.0      # stall length for kind "delay"

    def describe(self) -> str:
        parts = [f"{self.site}:{self.kind}"]
        opts = []
        if self.p < 1.0:
            opts.append(f"p={self.p:g}")
        if self.count is not None:
            opts.append(f"count={self.count}")
        if self.after:
            opts.append(f"after={self.after}")
        if self.kind == "delay":
            opts.append(f"delay_ms={self.delay_ms:g}")
        if opts:
            parts.append(",".join(opts))
        return ":".join(parts)


def parse_fault_spec(spec: str) -> FaultSpec:
    """Parse ``site:kind[:k=v,...]`` into a ``FaultSpec``.

    Raises ``ValueError`` with a pointed message on any malformed piece —
    plan validation wraps this into a ``PlanError`` so a typo'd chaos
    schedule fails at construction, not mid-run.
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError("fault spec must be a non-empty string")
    head, _, tail = spec.strip().partition(":")
    kind, _, params = tail.partition(":")
    site = head.strip()
    kind = kind.strip()
    if site not in SITES:
        raise ValueError(
            f"unknown site {site!r} (sites: {', '.join(SITES)})")
    if kind not in KINDS:
        raise ValueError(
            f"unknown kind {kind!r} (kinds: {', '.join(KINDS)})")
    kw: dict = {}
    if params.strip():
        for piece in params.split(","):
            key, eq, val = piece.partition("=")
            key = key.strip()
            if not eq or not val.strip():
                raise ValueError(f"malformed param {piece!r} (want k=v)")
            if key == "p":
                kw["p"] = float(val)
                if not 0.0 < kw["p"] <= 1.0:
                    raise ValueError(f"p={val} outside (0, 1]")
            elif key == "count":
                kw["count"] = int(val)
                if kw["count"] < 1:
                    raise ValueError(f"count={val} must be >= 1")
            elif key == "after":
                kw["after"] = int(val)
                if kw["after"] < 0:
                    raise ValueError(f"after={val} must be >= 0")
            elif key == "delay_ms":
                kw["delay_ms"] = float(val)
                if kw["delay_ms"] < 0:
                    raise ValueError(f"delay_ms={val} must be >= 0")
            else:
                raise ValueError(
                    f"unknown param {key!r} (params: p, count, after, "
                    f"delay_ms)")
    if "delay_ms" in kw and kind != "delay":
        raise ValueError("delay_ms only applies to kind 'delay'")
    return FaultSpec(site=site, kind=kind, **kw)


class _ArmedSpec:
    """Mutable per-spec fire state (guarded by the injector lock)."""

    __slots__ = ("spec", "rng", "pokes", "fired")

    def __init__(self, spec: FaultSpec, seed: int):
        self.spec = spec
        # crc32 keeps the per-site stream stable across processes and
        # PYTHONHASHSEED values; xor-ing the kind in separates streams
        # when one site carries several probabilistic specs.
        self.rng = random.Random(
            zlib.crc32(f"{spec.site}:{spec.kind}".encode()) ^ seed)
        self.pokes = 0
        self.fired = 0


class FaultInjector:
    """Seeded, thread-safe fault registry.

    ``poke(site)`` is the single hot-path entry: a no-op (None) when the
    injector is disarmed or no spec for the site elects to fire, else it
    raises / sleeps / returns ``CORRUPT`` per the spec kind.  ``armed``
    starts True; the chaos harness disarms during warmup so compile-time
    pokes never consume deterministic fault counts.
    """

    def __init__(self, sites, seed: int = 0, tracer=None):
        self._lock = threading.Lock()
        self._tracer = tracer
        self._armed = True
        self.seed = seed
        self._specs: dict[str, list[_ArmedSpec]] = {}
        for raw in sites:
            spec = raw if isinstance(raw, FaultSpec) else parse_fault_spec(raw)
            self._specs.setdefault(spec.site, []).append(
                _ArmedSpec(spec, seed))
        self.fired: dict[str, int] = {s: 0 for s in self._specs}
        self.total_fired = 0

    # -- arming ---------------------------------------------------------
    @property
    def armed(self) -> bool:
        return self._armed

    def set_armed(self, flag: bool) -> None:
        """Arm/disarm all specs.  Disarmed pokes do not advance poke
        counters, so ``after=N`` offsets count live traffic only."""
        with self._lock:
            self._armed = bool(flag)

    # -- the hot-path hook ----------------------------------------------
    def poke(self, site: str, **ctx) -> str | None:
        """Maybe fire a fault at ``site``.

        Returns None (no fault) or ``CORRUPT``; raises ``FaultInjected``
        for kind ``error``; sleeps then returns None for kind ``delay``.
        Extra kwargs ride onto the trace instant for debuggability.
        """
        with self._lock:
            specs = self._specs.get(site)
            if not self._armed or not specs:
                return None
            hit: FaultSpec | None = None
            for st in specs:
                st.pokes += 1
                if hit is not None:
                    continue                     # at most one fire per poke
                spec = st.spec
                if st.pokes <= spec.after:
                    continue
                if spec.count is not None and st.fired >= spec.count:
                    continue
                if spec.p < 1.0 and st.rng.random() >= spec.p:
                    continue
                st.fired += 1
                self.fired[site] += 1
                self.total_fired += 1
                hit = spec
        if hit is None:
            return None
        if self._tracer is not None:
            self._tracer.instant("fault_injected", site=site, kind=hit.kind,
                                 **ctx)
        if hit.kind == "delay":
            time.sleep(hit.delay_ms / 1e3)
            return None
        if hit.kind == "corrupt":
            return CORRUPT
        from repro.serve.errors import FaultInjected
        raise FaultInjected(f"injected fault at site {site!r}", site=site)

    # -- introspection --------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "armed": self._armed,
                "total_fired": self.total_fired,
                "fired": dict(self.fired),
                "specs": [st.spec.describe()
                          for specs in self._specs.values()
                          for st in specs],
            }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"FaultInjector(seed={self.seed}, "
                f"fired={self.total_fired}, armed={self._armed})")
