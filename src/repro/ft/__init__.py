from repro.ft.failures import (  # noqa: F401
    HeartbeatMonitor,
    ElasticPlan,
    plan_elastic_remesh,
    HedgePolicy,
)
