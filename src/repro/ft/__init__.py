from repro.ft.failures import (  # noqa: F401
    HeartbeatMonitor,
    ElasticPlan,
    plan_elastic_remesh,
)
from repro.ft.faults import (  # noqa: F401
    CORRUPT,
    FAULT_SITES,
    FaultInjector,
    FaultSpec,
    parse_fault_spec,
)
from repro.ft.recovery import (  # noqa: F401
    CircuitBreaker,
    RetryPolicy,
)


def __getattr__(name):            # lazy back-compat re-export (PEP 562):
    if name == "HedgePolicy":     # keeps `import repro.ft` free of the
        from repro.serve.hedging import HedgePolicy  # serve/JAX stack
        return HedgePolicy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
