"""Self-healing primitives: circuit breaker + retry policy.

``CircuitBreaker`` guards the stage-2 device-resident fast path.  The
classic three-state walk, tuned for a path that has a *bit-identical
fallback* (re-stacking) rather than an error response:

* CLOSED — traffic flows; ``failures`` consecutive recorded failures
  trip it OPEN;
* OPEN — ``allow()`` is False (the engine routes every pack through the
  fallback) until ``cooldown_ms`` elapses, then the next ``allow()``
  moves to HALF_OPEN;
* HALF_OPEN — probes flow freely (no in-flight probe bookkeeping: a
  probe whose outcome is never reported must not wedge the breaker);
  ``probes`` consecutive successes close it, any failure re-opens.

The clock is injectable so tests walk the cooldown without sleeping.
``RetryPolicy`` is the exponential-backoff + jitter schedule the batcher
bounds by each request's remaining deadline budget.  Module import is
stdlib-only; ``CircuitOpenError`` is imported lazily at raise time.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Thread-safe three-state circuit breaker with injectable clock."""

    def __init__(self, failures: int = 5, cooldown_ms: float = 100.0,
                 probes: int = 1, clock=time.monotonic, on_transition=None):
        if failures < 1:
            raise ValueError("failures must be >= 1")
        if cooldown_ms < 0:
            raise ValueError("cooldown_ms must be >= 0")
        if probes < 1:
            raise ValueError("probes must be >= 1")
        self.failure_threshold = failures
        self.cooldown_ms = cooldown_ms
        self.probes = probes
        self._clock = clock
        self._on_transition = on_transition
        self._lock = threading.Lock()
        self._state = CLOSED
        self._opened_at = 0.0
        self._consecutive = 0
        self._half_open_ok = 0
        self.opens = 0
        self.closes = 0
        self.failures_recorded = 0
        self.successes_recorded = 0

    # -- state ----------------------------------------------------------
    @property
    def state(self) -> str:
        pending: list = []
        with self._lock:
            self._maybe_half_open(pending)
            state = self._state
        self._flush(pending)
        return state

    def _maybe_half_open(self, pending: list) -> None:
        # lock held
        if (self._state == OPEN
                and (self._clock() - self._opened_at) * 1e3
                >= self.cooldown_ms):
            self._transition(HALF_OPEN, pending)
            self._half_open_ok = 0

    def _transition(self, new: str, pending: list) -> None:
        # lock held; pending defers the callback until the lock drops
        old, self._state = self._state, new
        if old != new and self._on_transition is not None:
            pending.append((old, new))

    def _flush(self, pending: list) -> None:
        for old, new in pending:
            self._on_transition(old, new)

    # -- the guard ------------------------------------------------------
    def allow(self) -> bool:
        """True when traffic may take the guarded path right now."""
        pending: list = []
        with self._lock:
            self._maybe_half_open(pending)
            ok = self._state != OPEN
        self._flush(pending)
        return ok

    def guard(self) -> None:
        """Raise ``CircuitOpenError`` instead of returning False."""
        if not self.allow():
            from repro.serve.errors import CircuitOpenError
            raise CircuitOpenError(
                f"circuit open ({self.failures_recorded} failures recorded; "
                f"cooldown {self.cooldown_ms:g} ms)")

    def record_success(self) -> None:
        pending: list = []
        with self._lock:
            self.successes_recorded += 1
            if self._state == CLOSED:
                self._consecutive = 0
            elif self._state == HALF_OPEN:
                self._half_open_ok += 1
                if self._half_open_ok >= self.probes:
                    self._transition(CLOSED, pending)
                    self.closes += 1
                    self._consecutive = 0
        self._flush(pending)

    def record_failure(self) -> None:
        pending: list = []
        with self._lock:
            self.failures_recorded += 1
            if self._state == HALF_OPEN:
                self._transition(OPEN, pending)
                self.opens += 1
                self._opened_at = self._clock()
            elif self._state == CLOSED:
                self._consecutive += 1
                if self._consecutive >= self.failure_threshold:
                    self._transition(OPEN, pending)
                    self.opens += 1
                    self._opened_at = self._clock()
            else:
                # failure reported while open (a straggler from before
                # the trip): extend the cooldown window
                self._opened_at = self._clock()
        self._flush(pending)

    def call(self, fn, *args, **kwargs):
        """Run ``fn`` through the breaker: guard, then record outcome."""
        self.guard()
        try:
            result = fn(*args, **kwargs)
        except Exception:
            self.record_failure()
            raise
        self.record_success()
        return result

    def stats(self) -> dict:
        pending: list = []
        with self._lock:
            self._maybe_half_open(pending)
            snap = {
                "state": self._state,
                "opens": self.opens,
                "closes": self.closes,
                "failures": self.failures_recorded,
                "successes": self.successes_recorded,
                "consecutive_failures": self._consecutive,
            }
        self._flush(pending)
        return snap


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with multiplicative jitter.

    Attempt ``k`` (0-based) sleeps ``backoff_ms * 2**k`` scaled by
    ``1 + jitter * U[0,1)``.  The caller compares each delay against the
    request's remaining deadline budget and stops retrying when the
    sleep alone would blow it.
    """

    retries: int = 0
    backoff_ms: float = 1.0
    jitter: float = 0.5

    def backoff_s(self, attempt: int,
                  rng: random.Random | None = None) -> float:
        base = self.backoff_ms * (2 ** attempt) / 1e3
        if self.jitter > 0 and rng is not None:
            base *= 1.0 + self.jitter * rng.random()
        return base
