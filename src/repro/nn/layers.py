"""Functional NN layers (no flax in this environment — init/apply dataclasses).

Params are plain dicts of jnp arrays so they checkpoint / shard trivially.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.common import Array, KeySeq, glorot

ACTIVATIONS: dict[str, Callable[[Array], Array]] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


def act(name: str, x: Array) -> Array:
    return ACTIVATIONS[name](x)


def dense_apply(params: dict, x: Array) -> Array:
    """y = x @ w (+ b). w: (D_in, D_out)."""
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


@dataclasses.dataclass(frozen=True)
class Dense:
    in_dim: int
    out_dim: int
    use_bias: bool = True

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        p = {"w": glorot(key, (self.in_dim, self.out_dim), dtype)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_dim,), dtype)
        return p

    def apply(self, params: dict, x: Array) -> Array:
        return dense_apply(params, x)


@dataclasses.dataclass(frozen=True)
class MLP:
    """Stack of Dense layers with activation between (and optionally after)."""

    dims: Sequence[int]  # [in, h1, h2, ..., out]
    activation: str = "relu"
    final_activation: str = "identity"
    use_bias: bool = True

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        ks = KeySeq(key)
        layers = {}
        for i, (din, dout) in enumerate(zip(self.dims[:-1], self.dims[1:])):
            layers[f"layer_{i}"] = Dense(din, dout, self.use_bias).init(next(ks), dtype)
        return layers

    def apply(self, params: dict, x: Array) -> Array:
        n = len(self.dims) - 1
        for i in range(n):
            x = dense_apply(params[f"layer_{i}"], x)
            name = self.activation if i < n - 1 else self.final_activation
            x = act(name, x)
        return x


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-6

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        del key
        return {"scale": jnp.ones((self.dim,), dtype), "bias": jnp.zeros((self.dim,), dtype)}

    def apply(self, params: dict, x: Array) -> Array:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + self.eps)
        return y * params["scale"] + params["bias"]


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        del key
        return {"scale": jnp.ones((self.dim,), dtype)}

    def apply(self, params: dict, x: Array) -> Array:
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps).astype(x.dtype)
        return y * params["scale"]


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale
