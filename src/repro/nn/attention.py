"""Attention primitives: GQA (w/ RoPE, sliding window, KV cache), DIN target
attention, and the ranking-model cross attention from the paper's Fig. 1."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import Array

NEG_INF = -1e30


def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0) -> tuple[Array, Array]:
    """Returns (cos, sin) of shape (max_pos, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array, positions: Array) -> Array:
    """x: (..., S, H, D). positions: (..., S) int32 absolute positions."""
    c = jnp.take(cos, positions, axis=0)[..., None, :]  # (..., S, 1, D/2)
    s = jnp.take(sin, positions, axis=0)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D)."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def gqa_attention(
    q: Array,              # (B, Sq, Hq, D)
    k: Array,              # (B, Sk, Hkv, D)
    v: Array,              # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,          # sliding-window attention (Mistral/Mixtral)
    q_positions: Array | None = None,   # (B, Sq) absolute positions (decode offsets)
    kv_positions: Array | None = None,  # (B, Sk)
    kv_mask: Array | None = None,       # (B, Sk) bool valid mask (ring-buffer caches)
) -> Array:
    """Grouped-query scaled-dot attention. Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (b, k.shape[1]))
    dist = q_positions[:, :, None] - kv_positions[:, None, :]  # (B, Sq, Sk)
    mask = jnp.ones_like(dist, dtype=bool)
    if causal:
        mask &= dist >= 0
    if window is not None:
        mask &= dist < window
    if kv_mask is not None:
        mask &= kv_mask[:, None, :]
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)

    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def target_attention(
    query: Array,      # (B, D)   candidate-item embedding (DIN target)
    keys: Array,       # (B, L, D) or (1, L, D) user history (broadcast over B)
    mask: Array,       # (B, L) or (1, L) bool valid positions
    mlp_apply,         # callable(x: (..., 4D)) -> (..., 1) attention MLP
) -> Array:
    """DIN local-activation unit: score each history item against the target
    via an MLP over [key, query, key-query, key*query]; weighted sum-pool."""
    if keys.shape[0] == 1 and query.shape[0] != 1:
        keys = jnp.broadcast_to(keys, (query.shape[0],) + keys.shape[1:])
        mask = jnp.broadcast_to(mask, (query.shape[0],) + mask.shape[1:])
    q = jnp.broadcast_to(query[:, None, :], keys.shape)  # (B, L, D)
    feats = jnp.concatenate([keys, q, keys - q, keys * q], axis=-1)
    scores = mlp_apply(feats)[..., 0]  # (B, L)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bl,bld->bd", w, keys)


def cross_attention(
    q: Array,          # (B, I, D) item-side queries
    k: Array,          # (1, L, D) user-sequence keys (computed ONCE — UOI)
    v: Array,          # (1, L, D)
    mask: Array | None = None,  # (1, L)
) -> Array:
    """Single-head candidate→user-history cross attention (paper Eq. 1).

    In UOI/MaRI, K/V carry batch 1 (user side, computed one-shot) and the
    einsum broadcasts — the tiled copy never materializes. In VanI, K/V
    arrive already tiled to B and the batched path is used.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    if k.shape[0] == 1 and q.shape[0] != 1:
        logits = jnp.einsum("bid,ld->bil", q, k[0]).astype(jnp.float32) * scale
    else:
        logits = jnp.einsum("bid,bld->bil", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if v.shape[0] == 1 and probs.shape[0] != 1:
        return jnp.einsum("bil,ld->bid", probs, v[0])
    return jnp.einsum("bil,bld->bid", probs, v)
