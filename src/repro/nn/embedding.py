"""Embedding tables and EmbeddingBag.

JAX has no native EmbeddingBag / CSR sparse — we implement the standard
industrial pattern: ``jnp.take`` over the table + ``jax.ops.segment_sum``
pooling over a flattened (values, segment_ids) multi-hot encoding. This IS
part of the system (recsys hot path); the Pallas kernel in
``repro/kernels/embedding_bag`` accelerates the same contract on TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common import Array, normal_init


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Plain single-id lookup table."""

    vocab: int
    dim: int

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        return {"table": normal_init(key, (self.vocab, self.dim), 0.02, dtype)}

    def apply(self, params: dict, ids: Array) -> Array:
        return jnp.take(params["table"], ids, axis=0)


def embedding_bag_lookup(
    table: Array,
    ids: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    combiner: str = "sum",
    weights: Array | None = None,
) -> Array:
    """Pooled multi-hot lookup.

    table: (V, D); ids: (nnz,) flat indices into table; segment_ids: (nnz,)
    row each id belongs to (sorted or not); returns (num_segments, D).
    """
    rows = jnp.take(table, ids, axis=0)  # (nnz, D)
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "sum":
        return summed
    if combiner == "mean":
        counts = jax.ops.segment_sum(
            jnp.ones((ids.shape[0],), table.dtype), segment_ids, num_segments=num_segments
        )
        return summed / jnp.maximum(counts, 1.0)[:, None]
    raise ValueError(f"unknown combiner {combiner!r}")


@dataclasses.dataclass(frozen=True)
class EmbeddingBag:
    """Multi-hot pooled embedding (sum/mean combiner), torch.EmbeddingBag contract."""

    vocab: int
    dim: int
    combiner: str = "sum"

    def init(self, key: Array, dtype=jnp.float32) -> dict:
        scale = 1.0 / max(self.vocab, 1) ** 0.5
        return {"table": normal_init(key, (self.vocab, self.dim), scale, dtype)}

    def apply(self, params: dict, ids: Array, segment_ids: Array, num_segments: int,
              weights: Array | None = None) -> Array:
        return embedding_bag_lookup(
            params["table"], ids, segment_ids, num_segments,
            combiner=self.combiner, weights=weights,
        )

    def apply_dense(self, params: dict, ids: Array) -> Array:
        """Fixed-hot (B, H) id matrix variant — pools along axis 1."""
        rows = jnp.take(params["table"], ids, axis=0)  # (B, H, D)
        pooled = rows.sum(axis=1)
        if self.combiner == "mean":
            pooled = pooled / ids.shape[1]
        return pooled
