from repro.nn.layers import (  # noqa: F401
    Dense,
    MLP,
    LayerNorm,
    RMSNorm,
    dense_apply,
    act,
)
from repro.nn.embedding import Embedding, EmbeddingBag, embedding_bag_lookup  # noqa: F401
from repro.nn.attention import (  # noqa: F401
    rope_freqs,
    apply_rope,
    gqa_attention,
    target_attention,
    cross_attention,
)
