"""Checkpointing: pytree <-> disk with async writes, retention, resume.

Format: one ``.npz`` of flattened leaves (keyed by tree path) + a msgpack
sidecar with the treedef paths and step metadata. Writes go to a temp dir
and are atomically renamed, so a killed process never leaves a half-written
checkpoint — the restart path picks the newest COMPLETE step (this is the
node-failure story: any worker can die at any point and training resumes
from the last durable step).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import re
import shutil
import time
from typing import Any

import jax
import msgpack
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _to_numpy(leaf) -> np.ndarray:
    """npz can't hold ml_dtypes (bf16/f8); widen to f32 — the restore path
    casts back to the template dtype, losslessly for widening round-trips."""
    arr = np.asarray(jax.device_get(leaf))
    if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        arr = np.asarray(jax.device_get(
            jax.numpy.asarray(leaf, jax.numpy.float32)))
    return arr


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), _to_numpy(leaf))
            for path, leaf in leaves]


def save_pytree(tree: PyTree, path: str, meta: dict | None = None) -> None:
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    items = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": arr for i, (_, arr) in enumerate(items)})
    sidecar = {"paths": [p for p, _ in items], "meta": meta or {},
               "time": time.time()}
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(sidecar))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)  # atomic publish


def restore_pytree(template: PyTree, path: str) -> PyTree:
    """Restore into the structure (and shardings/dtypes) of ``template``."""
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        sidecar = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    by_path = {p: data[f"leaf_{i}"] for i, p in enumerate(sidecar["paths"])}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path_t, leaf in leaves:
        key = jax.tree_util.keystr(path_t)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_path[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def checkpoint_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())["meta"]


class CheckpointManager:
    """Step-indexed checkpoints with retention and async (overlapped) saves."""

    def __init__(self, directory: str, max_to_keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(1) if async_save else None
        self._pending: cf.Future | None = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, meta: dict | None = None) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host BEFORE returning control (device buffers may be
        # donated by the next step)
        host = jax.tree_util.tree_map(_to_numpy, tree)
        path = os.path.join(self.dir, f"step_{step}")
        meta = dict(meta or {}, step=step)
        if self._pool is None:
            save_pytree(host, path, meta)
            self._gc()
        else:
            def work():
                save_pytree(host, path, meta)
                self._gc()
            self._pending = self._pool.submit(work)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.msgpack")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: int | None = None
                ) -> tuple[PyTree, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        return restore_pytree(template, path), checkpoint_meta(path)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
