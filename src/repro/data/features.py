"""Synthetic feature pipeline for the recsys graphs.

Generates feeds matching a graph's input nodes: user-side inputs at batch 1,
item/cross-side at batch B — the serving contract of Fig. 1. Vocab sizes are
discovered from the consuming embedding nodes so generated ids are in range.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.ir import Graph


def _vocab_for_input(graph: Graph, input_name: str) -> int | None:
    for n in graph.consumers(input_name):
        if n.op == "embedding":
            return n.attrs["vocab"]
    return None


def feed_specs(graph: Graph, batch: int, train: bool = False
               ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct feeds for the dry-run (no allocation).

    Serving: user inputs at batch 1 (one request, B candidates). Training:
    every example carries its own user -> all inputs at B."""
    specs = {}
    for n in graph.input_nodes():
        dom = n.attrs.get("domain")
        lead = batch if (train or dom != "user") else 1
        shape = (lead,) + tuple(n.attrs["shape"])
        dt = jnp.dtype(n.attrs.get("dtype", "float32"))
        specs[n.name] = jax.ShapeDtypeStruct(shape, dt)
    return specs


def make_recsys_feeds(graph: Graph, batch: int, key,
                      tile_user: bool = False) -> dict[str, jax.Array]:
    """Random feeds. ``tile_user=True`` pre-tiles user feeds to B (VanI-style
    data batching — used to benchmark the vanilla path faithfully)."""
    feeds = {}
    for n in graph.input_nodes():
        key, sub = jax.random.split(key)
        dom = n.attrs.get("domain")
        lead = batch if (dom != "user" or tile_user) else 1
        shape = (lead,) + tuple(n.attrs["shape"])
        dt = n.attrs.get("dtype", "float32")
        if dt.startswith("int"):
            vocab = _vocab_for_input(graph, n.name) or 1000
            feeds[n.name] = jax.random.randint(sub, shape, 0, vocab, jnp.dtype(dt))
        else:
            feeds[n.name] = jax.random.normal(sub, shape, jnp.dtype(dt))
        if dom == "user" and tile_user and lead == batch:
            # identical rows, as replication would produce
            feeds[n.name] = jnp.broadcast_to(feeds[n.name][:1], shape)
    return feeds


def make_labels(batch: int, key, n_tasks: int = 1) -> jax.Array:
    return jax.random.bernoulli(key, 0.2, (batch, n_tasks)).astype(jnp.float32)


def fragment_layout(d_total: int, chunk: int, rng: np.random.Generator
                    ) -> list[tuple[str, int]]:
    """Split a D-wide feature span into interleaved user/item chunks of size
    ``chunk`` (last chunk may be smaller) — the §2.4 fragmented layout."""
    out = []
    doms = ["user", "item"]
    i = 0
    off = 0
    while off < d_total:
        w = min(chunk, d_total - off)
        out.append((doms[i % 2] if rng is None else rng.choice(doms), w))
        off += w
        i += 1
    return out
