"""LM token batches (synthetic) and their ShapeDtypeStruct specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def token_batch(key, batch: int, seq: int, vocab: int) -> dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, vocab, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def token_batch_specs(batch: int, seq: int) -> dict[str, jax.ShapeDtypeStruct]:
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
