from repro.data.features import make_recsys_feeds, make_labels, feed_specs  # noqa: F401
from repro.data.sampler import NeighborSampler, random_graph, batched_molecules  # noqa: F401
from repro.data.lm import token_batch, token_batch_specs  # noqa: F401
