"""Graph data: synthetic graph generation, a real fanout neighbor sampler
(minibatch GNN training), and small-molecule batching."""
from __future__ import annotations

import dataclasses

import numpy as np


def random_graph(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0,
                 n_classes: int = 16) -> dict[str, np.ndarray]:
    """Random directed graph in edge-index (COO) form with features/labels/
    synthetic 3D positions (SchNet needs coordinates — DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    senders = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    receivers = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    return {
        "features": rng.standard_normal((n_nodes, d_feat), dtype=np.float32),
        "positions": (rng.standard_normal((n_nodes, 3)) * 3.0).astype(np.float32),
        "senders": senders,
        "receivers": receivers,
        "labels": rng.integers(0, n_classes, n_nodes, dtype=np.int32),
    }


@dataclasses.dataclass
class NeighborSampler:
    """GraphSAGE-style fanout sampling with fixed output shapes (padded) so
    every sampled minibatch lowers to the same XLA program."""

    senders: np.ndarray
    receivers: np.ndarray
    n_nodes: int
    fanouts: tuple[int, ...]

    def __post_init__(self):
        # CSR over incoming edges: receiver -> its senders
        order = np.argsort(self.receivers, kind="stable")
        self._src_sorted = self.senders[order]
        counts = np.bincount(self.receivers, minlength=self.n_nodes)
        self._offsets = np.concatenate([[0], np.cumsum(counts)])

    def max_sample_nodes(self, batch_nodes: int) -> int:
        n, total = batch_nodes, batch_nodes
        for f in self.fanouts:
            n *= f
            total += n
        return total

    def max_sample_edges(self, batch_nodes: int) -> int:
        n, total = batch_nodes, 0
        for f in self.fanouts:
            n *= f
            total += n
        return total

    def sample(self, seed_nodes: np.ndarray, rng: np.random.Generator
               ) -> dict[str, np.ndarray]:
        """Returns padded arrays: nodes (max_nodes,), senders/receivers
        (max_edges,) as LOCAL indices into nodes, edge_mask, node_mask."""
        bs = len(seed_nodes)
        max_n = self.max_sample_nodes(bs)
        max_e = self.max_sample_edges(bs)
        nodes = list(seed_nodes)
        local = {int(n): i for i, n in enumerate(seed_nodes)}
        snd, rcv = [], []
        frontier = list(seed_nodes)
        for f in self.fanouts:
            nxt = []
            for v in frontier:
                lo, hi = self._offsets[v], self._offsets[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = rng.choice(deg, size=take, replace=False)
                for p in picks:
                    u = int(self._src_sorted[lo + p])
                    if u not in local:
                        local[u] = len(nodes)
                        nodes.append(u)
                        nxt.append(u)
                    snd.append(local[u])
                    rcv.append(local[v])
            frontier = nxt
        n_real_nodes, n_real_edges = len(nodes), len(snd)
        nodes_arr = np.zeros(max_n, np.int32)
        nodes_arr[:n_real_nodes] = nodes
        senders = np.zeros(max_e, np.int32)
        receivers = np.full(max_e, max_n - 1, np.int32)  # pad edges to a sink
        senders[:n_real_edges] = snd
        receivers[:n_real_edges] = rcv
        edge_mask = np.zeros(max_e, bool)
        edge_mask[:n_real_edges] = True
        node_mask = np.zeros(max_n, bool)
        node_mask[:n_real_nodes] = True
        return {"nodes": nodes_arr, "senders": senders, "receivers": receivers,
                "edge_mask": edge_mask, "node_mask": node_mask,
                "n_seed": bs}


def batched_molecules(n_graphs: int, n_nodes: int, n_edges: int, seed: int = 0
                      ) -> dict[str, np.ndarray]:
    """Batch of small molecules flattened into one disjoint graph."""
    rng = np.random.default_rng(seed)
    N, E = n_graphs * n_nodes, n_graphs * n_edges
    offs = np.repeat(np.arange(n_graphs) * n_nodes, n_edges)
    return {
        "atom_types": rng.integers(1, 20, N, dtype=np.int32),
        "positions": (rng.standard_normal((N, 3)) * 2.0).astype(np.float32),
        "senders": (rng.integers(0, n_nodes, E) + offs).astype(np.int32),
        "receivers": (rng.integers(0, n_nodes, E) + offs).astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32),
        "energies": rng.standard_normal(n_graphs).astype(np.float32),
    }
