"""Small shared utilities: rng threading, pytree helpers, timing, shape math."""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


class KeySeq:
    """Stateful PRNG key splitter for init code (training uses explicit keys)."""

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __next__(self) -> Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def take(self, n: int) -> list[Array]:
        return [next(self) for _ in range(n)]


def tree_size(tree: PyTree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))


def glorot(key: Array, shape: tuple[int, ...], dtype=jnp.float32) -> Array:
    fan_in, fan_out = shape[0], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def normal_init(key: Array, shape: tuple[int, ...], stddev: float = 0.02,
                dtype=jnp.float32) -> Array:
    return jax.random.normal(key, shape, dtype) * stddev


def timeit(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 10) -> dict:
    """Wall-clock a thunk returning jax arrays; blocks on results.

    Returns mean/std/p50/p99 in microseconds over `iters` runs.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    ts = np.asarray(times)
    return {
        "mean_us": float(ts.mean()),
        "std_us": float(ts.std()),
        "p50_us": float(np.percentile(ts, 50)),
        "p99_us": float(np.percentile(ts, 99)),
        "iters": iters,
    }


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def prev_pow2(n: int) -> int:
    """Largest power of two <= n (requires n >= 1)."""
    return 1 << (n.bit_length() - 1)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def chunked(seq, n: int) -> Iterator:
    for i in range(0, len(seq), n):
        yield seq[i : i + n]
