"""Row-block partition spec for feature-fusion weight matrices (Eq. 3).

Shared by the graph rewriter, the Pallas kernel wrapper and the benchmarks:
describes how the rows of W (concatenated feature dim D) split into
user/item/cross blocks and derives FLOPs/bytes for roofline accounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mari import mari_flops, vanilla_flops


@dataclasses.dataclass(frozen=True)
class WeightPartition:
    d_user: int
    d_item: int
    d_cross: int
    d_out: int

    @property
    def d_in(self) -> int:
        return self.d_user + self.d_item + self.d_cross

    @property
    def d_rest(self) -> int:
        return self.d_item + self.d_cross

    def row_slices(self) -> dict[str, slice]:
        o1, o2 = self.d_user, self.d_user + self.d_item
        return {"user": slice(0, o1), "item": slice(o1, o2),
                "cross": slice(o2, self.d_in)}

    def split(self, w) -> dict[str, np.ndarray]:
        sl = self.row_slices()
        return {k: w[s] for k, s in sl.items()}

    # -- accounting ----------------------------------------------------------
    def flops_vanilla(self, batch: int) -> int:
        return vanilla_flops(batch, self.d_in, self.d_out)

    def flops_mari(self, batch: int) -> int:
        return mari_flops(batch, self.d_user, self.d_rest, self.d_out)

    def flops_speedup(self, batch: int) -> float:
        return self.flops_vanilla(batch) / self.flops_mari(batch)

    def bytes_vanilla(self, batch: int, itemsize: int = 4) -> int:
        # read tiled X (B, D), W (D, d); write (B, d)
        return itemsize * (batch * self.d_in + self.d_in * self.d_out
                           + batch * self.d_out)

    def bytes_mari(self, batch: int, itemsize: int = 4) -> int:
        # read X_u (1, D_u), X_rest (B, D_rest), W (D, d); write (B, d)
        return itemsize * (self.d_user + batch * self.d_rest
                           + self.d_in * self.d_out + batch * self.d_out)
