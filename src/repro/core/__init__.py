"""The paper's primary contribution: GCA detection, MaRI rewrite, reorg."""
from repro.core.gca import Color, run_gca, GCAResult  # noqa: F401
from repro.core.mari import (  # noqa: F401
    mari_rewrite,
    convert_params,
    MaRIConversion,
    matmul_mari,
    matmul_mari_fragmented,
    mari_flops,
    vanilla_flops,
)
from repro.core.mari import apply_mari  # noqa: F401
from repro.core.split import split_two_stage, TwoStageSplit  # noqa: F401
from repro.core.partition import WeightPartition  # noqa: F401
from repro.core.reorg import reorganize, ReorgPlan, convert_params_reorg  # noqa: F401
from repro.core.jaxpr_gca import detect_in_jaxpr, JaxprGCAReport  # noqa: F401
