"""MaRI — structural re-parameterization of feature-fusion MatMuls (§2.2).

Two layers of API:

* Functional ops (``matmul_mari``, ``matmul_mari_fragmented``) — Eq. 7 as plain
  jnp functions, used by benchmarks and the Pallas kernel's reference.
* Graph rewrite (``mari_rewrite`` + ``convert_params``) — step (3) of the MaRI
  workflow (§2.5): replaces GCA-detected ``dense`` nodes with ``mari_dense``
  nodes and physically re-partitions the trained weight matrices into
  per-group row blocks (the "re-parameterization"). Lossless by the block
  matmul identity (Eq. 2).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.common import Array
from repro.core.gca import Color, GCAResult, run_gca
from repro.graph.ir import Graph, Node, REWRITE_SAFE_OPS, infer_shapes


# ---------------------------------------------------------------------------
# Functional form (benchmarks, kernels, FLOPs accounting)
# ---------------------------------------------------------------------------

def matmul_vanilla(x_tiled: Array, w: Array, b: Array | None = None) -> Array:
    """Baseline: the full (B, D) @ (D, d) product over tiled features (Eq. 5)."""
    y = x_tiled @ w
    return y if b is None else y + b


def matmul_mari(x_user: Array, x_rest: Array, w_user: Array, w_rest: Array,
                b: Array | None = None) -> Array:
    """Eq. 7 (two-group form): Tile(x_u W_u, B) + x_r W_r.

    x_user: (1, D_u); x_rest: (B, D_r). The tile is a broadcast add — the
    (B, D_u) copy of user features never exists.
    """
    y = x_user @ w_user + x_rest @ w_rest
    return y if b is None else y + b


def matmul_mari3(x_user: Array, x_item: Array, x_cross: Array,
                 w_user: Array, w_item: Array, w_cross: Array,
                 b: Array | None = None) -> Array:
    """Eq. 7, paper-faithful three-term form."""
    y = x_user @ w_user + x_item @ w_item + x_cross @ w_cross
    return y if b is None else y + b


def matmul_mari_fragmented(segments: list[tuple[Array, Array]],
                           b: Array | None = None) -> Array:
    """§2.4 regime: one matmul per interleaved feature chunk."""
    acc = None
    for x, w in segments:
        y = x @ w
        acc = y if acc is None else acc + y
    return acc if b is None else acc + b


def vanilla_flops(batch: int, d_in: int, d_out: int) -> int:
    """Eq. 8."""
    return 2 * batch * d_in * d_out


def mari_flops(batch: int, d_user: int, d_rest: int, d_out: int) -> int:
    """Eq. 9: 2 d [D_u + B (D_i + D_c)]."""
    return 2 * d_out * (d_user + batch * d_rest)


# ---------------------------------------------------------------------------
# Graph rewrite
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DenseRewrite:
    dense: str
    concat: str
    chain: tuple[str, ...]               # transparent node names concat -> dense
    seg_names: tuple[str, ...]           # original concat inputs, in order
    seg_widths: tuple[int, ...]
    seg_groups: tuple[str, ...]          # group label per segment
    groups: tuple[tuple[str, tuple[int, ...]], ...]  # (label, seg indices)
    fragment: bool


@dataclasses.dataclass
class AttnRewrite:
    """Beyond-paper: re-parameterization of the DIN local-activation unit.

    The first attention-MLP layer acts on [k, q, k-q, k*q] @ W1 with
    W1 = [W_k; W_q; W_d; W_p] row blocks. Identically,

        = k @ (W_k + W_d)  +  q @ (W_q - W_d)  +  (k*q) @ W_p

    The first term is user-side (batch 1, one-shot); the second is (B, h)
    broadcast over L; only the Hadamard term scales with B*L — and it
    contracts against the precomputed user-side tensor T[l,d,h] = k[l,d]
    W_p[d,h], so the (B, L, 4D) feature tensor never materializes. Same
    algebraic identity as Eq. 7, pushed through the attention feature
    concat — lossless.
    """
    node: str
    d: int
    h1: int


@dataclasses.dataclass
class MaRIConversion:
    graph: Graph
    rewrites: list[DenseRewrite]
    skipped: list[tuple[str, str]]       # (dense, reason)
    gca: GCAResult
    attn_rewrites: list[AttnRewrite] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        return (f"MaRI: rewrote {len(self.rewrites)} matmuls "
                f"({[r.dense for r in self.rewrites]}), "
                f"{len(self.attn_rewrites)} attention units, "
                f"skipped {len(self.skipped)} {self.skipped}")


def _segment_domain(graph: Graph, colors: dict[str, Color], name: str) -> str:
    """Origin domain of a segment: 'user' if Yellow; for Blue segments,
    'item'/'cross' if all feature ancestors share one domain, else 'rest'."""
    if colors[name] is Color.YELLOW:
        return "user"
    doms: set[str] = set()
    stack, seen = [name], {name}
    while stack:
        u = stack.pop()
        node = graph.nodes[u]
        if node.op == "input" and node.attrs.get("domain"):
            doms.add(node.attrs["domain"])
        for i in node.inputs:
            if i not in seen:
                seen.add(i)
                stack.append(i)
    doms.discard("user")  # user ancestors of a Blue segment don't relabel it
    if doms == {"item"}:
        return "item"
    if doms == {"cross"}:
        return "cross"
    return "rest"


def _trace_chain(graph: Graph, dense: Node, concat: str) -> tuple[str, ...] | None:
    """Walk dense's input upward through transparent ops to ``concat``.
    Returns the chain node names (may be empty) or None if not a clean path."""
    chain: list[str] = []
    cur = dense.inputs[0]
    while cur != concat:
        node = graph.nodes[cur]
        if node.op not in REWRITE_SAFE_OPS or len(node.inputs) != 1:
            return None
        chain.append(cur)
        cur = node.inputs[0]
    return tuple(reversed(chain))


def mari_rewrite(
    graph: Graph,
    gca: GCAResult | None = None,
    *,
    fragment: bool = False,
    group_by_domain: bool = False,
    reparam_attention: bool = False,
) -> MaRIConversion:
    """Replace GCA-detected dense nodes with ``mari_dense`` nodes.

    fragment=False groups concat segments by domain (the §2.4 reorganization:
    user segments → one matmul, rest → one; ``group_by_domain=True`` keeps
    item and cross separate, the paper's three-matmul layout).
    fragment=True keeps one matmul per segment — the Table-3 regime.
    reparam_attention=True additionally re-parameterizes target_attention
    units whose keys are user-side (beyond-paper, see AttnRewrite).
    """
    gca = gca or run_gca(graph)
    shapes = infer_shapes(graph)
    new = graph.copy()
    rewrites: list[DenseRewrite] = []
    skipped: list[tuple[str, str]] = []
    attn_rewrites: list[AttnRewrite] = []

    if reparam_attention:
        for n in graph.topo_order():
            if n.op != "target_attention":
                continue
            if gca.colors[n.inputs[1]] is not Color.YELLOW:
                continue  # keys must be one-shot user-side
            d = shapes[n.inputs[0]][-1]
            h1 = n.attrs["mlp_hidden"][0]
            attrs = dict(n.attrs)
            attrs["decomposed"] = True
            new.nodes[n.name] = Node(n.name, "target_attention", n.inputs,
                                     attrs)
            attn_rewrites.append(AttnRewrite(node=n.name, d=d, h1=h1))

    for dense_name, concat_name in sorted(gca.eligible.items()):
        dense = graph.nodes[dense_name]
        concat = graph.nodes[concat_name]
        if concat.attrs.get("axis", -1) != -1:
            skipped.append((dense_name, "concat axis != -1"))
            continue
        chain = _trace_chain(graph, dense, concat_name)
        if chain is None:
            skipped.append((dense_name, "non-shape-preserving path (reshape)"))
            continue
        seg_names = concat.inputs
        seg_widths = tuple(shapes[s][-1] for s in seg_names)
        if group_by_domain:
            seg_groups = tuple(
                _segment_domain(graph, gca.colors, s) for s in seg_names)
        else:
            seg_groups = tuple(
                "user" if gca.colors[s] is Color.YELLOW else "rest"
                for s in seg_names)
        if "user" not in seg_groups:
            skipped.append((dense_name, "no user segment (nothing to save)"))
            continue
        # group order: user first (computed once), then the batched groups.
        labels = ["user"] + [g for g in dict.fromkeys(seg_groups) if g != "user"]
        groups = tuple(
            (lab, tuple(i for i, g in enumerate(seg_groups) if g == lab))
            for lab in labels)

        cast_dtype = None
        for c in chain:
            if graph.nodes[c].op == "cast":
                cast_dtype = graph.nodes[c].attrs["dtype"]

        attrs = dict(
            units=dense.attrs["units"],
            use_bias=dense.attrs.get("use_bias", True),
            activation=dense.attrs.get("activation", "identity"),
            seg_widths=seg_widths,
            seg_groups=seg_groups,
            groups=groups,
            fragment=fragment,
            cast_dtype=cast_dtype,
        )
        new.nodes[dense_name] = Node(dense_name, "mari_dense", seg_names, attrs)
        rewrites.append(DenseRewrite(
            dense=dense_name, concat=concat_name, chain=chain,
            seg_names=seg_names, seg_widths=seg_widths, seg_groups=seg_groups,
            groups=groups, fragment=fragment))

    new = new.dce()  # drops the concat/tile path if nothing else consumes it
    return MaRIConversion(graph=new, rewrites=rewrites, skipped=skipped,
                          gca=gca, attn_rewrites=attn_rewrites)


def convert_params(conv: MaRIConversion, params: dict) -> dict:
    """Physically re-partition trained weights for the rewritten graph.

    For each rewritten dense: W (D, units) rows are split at segment
    boundaries and regrouped per domain group (the §2.4 parameter remap).
    Biases pass through. All other params are shared by reference.
    """
    out = dict(params)
    for r in conv.rewrites:
        p = params[r.dense]
        w = p["w"]
        offs = np.concatenate([[0], np.cumsum(r.seg_widths)])
        seg_rows = [w[offs[i]:offs[i + 1]] for i in range(len(r.seg_widths))]
        newp = {}
        if r.fragment:
            for i, rows in enumerate(seg_rows):
                newp[f"w_seg{i}"] = rows
        else:
            for label, idx in r.groups:
                newp[f"w_{label}"] = jnp.concatenate([seg_rows[i] for i in idx], axis=0)
        if "b" in p:
            newp["b"] = p["b"]
        out[r.dense] = newp
    for ar in conv.attn_rewrites:
        p = dict(params[ar.node])
        l0 = p["layer_0"]
        w1, d = l0["w"], ar.d
        wk, wq, wd, wp = (w1[:d], w1[d:2 * d], w1[2 * d:3 * d], w1[3 * d:])
        p["layer_0"] = {"w_kd": wk + wd, "w_qd": wq - wd, "w_p": wp,
                        "b": l0["b"]}
        out[ar.node] = p
    return out


def apply_mari(graph: Graph, params: dict, **kw) -> tuple[Graph, dict, MaRIConversion]:
    """One-call conversion: GCA detect → rewrite → re-parameterize weights."""
    conv = mari_rewrite(graph, **kw)
    return conv.graph, convert_params(conv, params), conv
