"""Two-stage bipartition of a (MaRI-rewritten) serving graph — Fig. 2 made
executable.

The MaRI premise is that the user side of a ranking graph is identical for
every candidate in the batch. ``split_two_stage`` cuts a graph into:

* **stage 1** — the user-only precompute subgraph: every node GCA colors
  Yellow (plus their uncolored ancestors), and one *partial* node per
  rewritten unit:

  - each ``mari_dense``'s user-side product ``x_user @ w_user (+ b)``
    (op ``mari_user_partial``) — the ``Tile(·, B)`` operand of Eq. 7,
  - each decomposed ``target_attention``'s one-shot tensors
    ``u_part = k @ w_kd + b`` (op ``attn_user_part``) and
    ``T[l,d,h] = k[l,d] * w_p[d,h]`` (op ``attn_user_T``).

  Stage 1 runs at batch 1, once per (user, feature version); its outputs are
  content-addressed and cached by the serving engine.

* **stage 2** — the batched residual subgraph: every Blue node, with user
  activations arriving as ``input`` nodes (domain ``"user"``) whose names
  equal the stage-1 output names, so ``stage2_feeds = {**stage1_out,
  **candidate_feeds}``. Rewritten ``mari_dense`` nodes consume the
  precomputed partial as their accumulator init (``precomputed_user``);
  decomposed attention consumes ``u_part``/``T`` (``precomputed``).

  Stage-2 user inputs accept TWO batch layouts (the executor dispatches on
  the leading dim): **batch-1** — one user per call, broadcast over all B
  candidate rows (the classic Fig. 2 contract) — or **row-wise batch-B** —
  a cross-user coalesced batch where candidate row b carries *its own*
  user's cached stage-1 outputs, produced by gathering a stacked (U, ...)
  rep table with a per-row user index (``reps[name][user_index]``). The
  serving engine's coalescing runtime uses the row-wise form;
  ``boundary_specs`` gives the per-example shape of every crossing value so
  the runtime can stack/pad rep tables without re-running shape inference.
  Under the engine's gather-at-load options (``kernel_gather``,
  ``gather_attention``) eligible user inputs skip the explicit gather
  entirely: the stacked (U, ...) table is fed as-is and the consuming
  kernel (Pallas ``mari_matmul`` acc-init / ``kernels.gather_einsum``
  attention contractions) indexes it by ``user_index`` at load time.

  The row-wise tables admit a third, *persistent* realization
  (``CachePlan.device_resident``): instead of stacking U cached rows per
  call, the serving cache holds ONE live (capacity, ...) device array per
  boundary name — shaped by ``TwoStageSplit.table_specs`` — written one
  row at a time and addressed by per-row *slot* indices. The contract
  that makes this safe is the same one the coalesced form relies on:
  stage-2 gathers clamp (``mode="clip"``) and row results are independent
  of table size and of the contents of unreferenced rows, so dead or
  stale slots can never leak into a live row's score.

Both stages share ONE params dict: partial nodes reference their source
node's params via ``attrs["param_of"]`` indirection, so no weight is copied
or re-keyed.

Lossless by construction: stage-1 ∘ stage-2 computes exactly the single
graph's values (the split only reassociates where each value is produced).
"""
from __future__ import annotations

import dataclasses

from repro.core.gca import Color, GCAResult, run_gca
from repro.graph.ir import Graph, Node, infer_shapes


def rep_table_pspecs(boundary_specs: dict) -> dict:
    """Rank-matched replicated PartitionSpecs for the stacked (U, ...)
    stage-2 rep tables: 1 table dim + per-example rank, every dim
    unsharded. THE single source of the rep-table sharding contract
    (re-exported by ``repro.dist.sharding`` for serving-side callers).

    User representations replicate across candidate shards because every
    shard scores rows for every user — and with the gather-at-load serving
    path (``kernel_gather`` / ``gather_attention``) replication is the
    whole cross-shard story: each shard indexes its replicated (U, ...)
    table by its own slice of ``user_index`` inside the contraction, so no
    (B, ...)-sized gathered user block — in particular no (B, L, D, h)
    attention tensor — is ever formed, let alone all-gathered."""
    from jax.sharding import PartitionSpec as P
    return {name: P(*([None] * (1 + len(shape))))
            for name, shape in boundary_specs.items()}


@dataclasses.dataclass
class TwoStageSplit:
    stage1: Graph                 # inputs: user feeds; outputs: boundary
    stage2: Graph                 # inputs: boundary + candidate feeds
    boundary: tuple[str, ...]     # stage-1 output names == stage-2 user inputs
    user_nodes: frozenset[str]    # stage-1 node set in the source graph
    n_precompute_nodes: int       # compute nodes skipped on a user-cache hit
    # per-example (batch-dim-free) shape of every stage-2 user-side input:
    # boundary activations AND rewritten-unit partials — the contract the
    # coalescing runtime stacks into (U, ...) rep tables
    boundary_specs: dict[str, tuple[int, ...]] = dataclasses.field(
        default_factory=dict)

    def summary(self) -> str:
        return (f"split: stage1 {len(self.stage1.nodes)} nodes "
                f"({self.n_precompute_nodes} compute) -> "
                f"{len(self.boundary)} boundary values; "
                f"stage2 {len(self.stage2.nodes)} nodes")

    def boundary_pspecs(self) -> dict:
        """Per-entry specs for this split's stacked rep tables — the
        ``rep_table_pspecs`` contract over ``boundary_specs``."""
        return rep_table_pspecs(self.boundary_specs)

    def table_specs(self, capacity: int) -> dict[str, tuple[int, ...]]:
        """Full array shapes of a persistent (capacity, ...) rep-table set
        over this split's boundary — what ``DeviceRepStore`` allocates for
        the device-resident serving tier, and the contract it validates
        first-put rows against."""
        return {name: (capacity,) + tuple(shape)
                for name, shape in self.boundary_specs.items()}


def _split_mari_dense(n: Node, pre: set[str]) -> tuple[Node, list[Node]]:
    """Peel the user-side product of a ``mari_dense`` into a stage-1 partial.

    Returns (stage-2 node, stage-1 partial nodes). Falls back to the
    unmodified node when there is nothing user-side to peel (the node then
    reads its user segments as boundary inputs — still correct, just less
    precomputation).
    """
    attrs = n.attrs
    base = dict(param_of=n.name, units=attrs["units"],
                use_bias=attrs.get("use_bias", True),
                cast_dtype=attrs.get("cast_dtype"))
    if attrs.get("fragment"):
        user_idx = tuple(i for i, s in enumerate(n.inputs) if s in pre)
        if not user_idx:
            return n, []
        rest_idx = tuple(i for i in range(len(n.inputs)) if i not in user_idx)
        pname = n.name + "::u"
        pnode = Node(pname, "mari_user_partial",
                     tuple(n.inputs[i] for i in user_idx),
                     dict(base, fragment=True, seg_idx=user_idx))
        attrs2 = dict(attrs, precomputed_user=True, use_bias=False,
                      seg_param_idx=rest_idx)
        node2 = Node(n.name, "mari_dense",
                     (pname,) + tuple(n.inputs[i] for i in rest_idx), attrs2)
        return node2, [pnode]

    groups = attrs["groups"]
    user_groups = [(lab, idx) for lab, idx in groups if lab == "user"]
    if len(user_groups) != 1:
        return n, []
    user_idx = user_groups[0][1]
    if any(n.inputs[i] not in pre for i in user_idx):
        # segment labels disagree with the actual coloring — don't peel
        return n, []
    pname = n.name + "::u"
    pnode = Node(pname, "mari_user_partial",
                 tuple(n.inputs[i] for i in user_idx),
                 dict(base, fragment=False))
    new_inputs: list[str] = [pname]
    new_groups: list[tuple[str, tuple[int, ...]]] = []
    for lab, idx in groups:
        if lab == "user":
            continue
        nidx = []
        for i in idx:
            new_inputs.append(n.inputs[i])
            nidx.append(len(new_inputs) - 1)
        new_groups.append((lab, tuple(nidx)))
    attrs2 = dict(attrs, groups=tuple(new_groups), precomputed_user=True,
                  use_bias=False)
    return Node(n.name, "mari_dense", tuple(new_inputs), attrs2), [pnode]


def _split_attention(n: Node) -> tuple[Node, list[Node]]:
    """Peel the one-shot tensors of a decomposed ``target_attention``."""
    h1 = n.attrs["mlp_hidden"][0]
    keys = n.inputs[1]
    pu = Node(n.name + "::u_part", "attn_user_part", (keys,),
              dict(param_of=n.name, h1=h1))
    pt = Node(n.name + "::T", "attn_user_T", (keys,),
              dict(param_of=n.name, h1=h1))
    attrs2 = dict(n.attrs, precomputed=True)
    node2 = Node(n.name, "target_attention",
                 tuple(n.inputs) + (pu.name, pt.name), attrs2)
    return node2, [pu, pt]


def split_two_stage(graph: Graph, gca: GCAResult | None = None) -> TwoStageSplit:
    gca = gca or run_gca(graph)
    shapes = infer_shapes(graph)

    # Stage-1 set: Yellow nodes plus their (necessarily non-Blue) ancestors —
    # an uncolored ancestor of a Yellow node is constant w.r.t. the candidate
    # batch, so precomputing it per user is sound.
    pre = {name for name, c in gca.colors.items() if c is Color.YELLOW}
    for n in reversed(graph.topo_order()):
        if n.name in pre:
            pre.update(n.inputs)

    boundary: list[str] = []
    seen: set[str] = set()

    def need(name: str) -> None:
        if name in pre and name not in seen:
            seen.add(name)
            boundary.append(name)

    partials: list[Node] = []
    s2_body: list[Node] = []
    for n in graph.topo_order():
        if n.name in pre:
            continue
        if n.op == "mari_dense":
            node2, pnodes = _split_mari_dense(n, pre)
        elif (n.op == "target_attention" and n.attrs.get("decomposed")
                and n.inputs[1] in pre):
            node2, pnodes = _split_attention(n)
        else:
            node2, pnodes = n, []
        partials.extend(pnodes)
        for i in node2.inputs:
            need(i)
        s2_body.append(node2)
    for o in graph.outputs:
        need(o)  # a user-only graph output passes straight through stage 2

    # Partial output shapes (per-example, batch dim excluded).
    pshape: dict[str, tuple[int, ...]] = {}
    for p in partials:
        if p.op == "mari_user_partial":
            pshape[p.name] = (p.attrs["units"],)
        elif p.op == "attn_user_part":
            L, _ = shapes[p.inputs[0]]
            pshape[p.name] = (L, p.attrs["h1"])
        else:  # attn_user_T
            L, D = shapes[p.inputs[0]]
            pshape[p.name] = (L, D, p.attrs["h1"])

    # ---- stage 1: user subgraph + partials, pruned to what stage 2 needs
    s1 = Graph()
    for n in graph.topo_order():
        if n.name in pre:
            s1.add(n)
    for p in partials:
        s1.add(p)
    s1.set_outputs(boundary + [p.name for p in partials])
    s1 = s1.dce()

    # ---- stage 2: boundary values arrive as batch-1 "user" inputs
    s2 = Graph()
    for name in boundary:
        n0 = graph.nodes[name]
        if n0.op == "input":
            s2.add(n0)
        else:
            s2.add(Node(name, "input", (),
                        dict(shape=tuple(shapes[name]), domain="user",
                             dtype="float32")))
    for p in partials:
        s2.add(Node(p.name, "input", (),
                    dict(shape=tuple(pshape[p.name]), domain="user",
                         dtype="float32")))
    for n in s2_body:
        s2.add(n)
    s2.set_outputs(graph.outputs)
    s2 = s2.dce()

    n_compute = sum(1 for n in s1.nodes.values() if n.op != "input")
    specs = {name: tuple(shapes[name]) for name in boundary}
    specs.update({p.name: tuple(pshape[p.name]) for p in partials})
    return TwoStageSplit(stage1=s1, stage2=s2,
                         boundary=tuple(s1.outputs),
                         user_nodes=frozenset(pre),
                         n_precompute_nodes=n_compute,
                         boundary_specs=specs)
