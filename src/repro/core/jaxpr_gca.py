"""GCA on raw jaxprs — Algorithm 1 applied to traced JAX functions.

The graph-IR pass (repro.core.gca) is the rewriting path. This module is the
*detector* for arbitrary jitted model functions: colour the jaxpr's input
avals by feature domain, propagate Yellow/Blue through equations, find
``concatenate`` eqns with mixed-colour operands, and report every
``dot_general`` reachable through non-computational primitives. Useful as an
audit tool ("did the serving graph regress? which matmuls SHOULD be MaRI?")
and as evidence the detection transfers beyond our own IR.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.extend import core as jcore

from repro.core.gca import Color

# Primitives that do not compute on values (layout/metadata only) — the
# jaxpr analogue of the paper's "non-computational nodes".
TRANSPARENT_PRIMITIVES = frozenset({
    "reshape", "convert_element_type", "stop_gradient", "squeeze",
    "broadcast_in_dim", "transpose", "copy", "bitcast_convert_type",
})


@dataclasses.dataclass
class EligibleMatMul:
    eqn_index: int
    primitive: str
    boundary_concat_index: int
    lhs_shape: tuple[int, ...]
    rhs_shape: tuple[int, ...]


@dataclasses.dataclass
class JaxprGCAReport:
    colors_in: dict[int, Color]          # invar index -> colour
    mixed_concats: list[int]             # eqn indices of boundary concatenates
    eligible: list[EligibleMatMul]
    n_eqns: int

    def summary(self) -> str:
        return (f"jaxpr-GCA: {self.n_eqns} eqns, "
                f"{len(self.mixed_concats)} boundary concats, "
                f"{len(self.eligible)} eligible dot_generals "
                f"{[(e.eqn_index, e.lhs_shape, e.rhs_shape) for e in self.eligible]}")


def _color_of_var(colors: dict, v) -> Color:
    if isinstance(v, jcore.Literal):
        return Color.UNCOLORED
    return colors.get(v, Color.UNCOLORED)


def _merge(colors_in: list[Color]) -> Color:
    if Color.BLUE in colors_in:
        return Color.BLUE
    if Color.YELLOW in colors_in:
        return Color.YELLOW
    return Color.UNCOLORED


def detect_in_jaxpr(
    fn: Callable,
    domains: dict[str, str],
    *example_args,
    static_argnums: tuple[int, ...] = (),
) -> JaxprGCAReport:
    """Trace ``fn(**example_args)`` and run GCA.

    domains maps flattened-input-leaf *path substrings* (from
    jax.tree_util.keystr over the args tuple) to 'user'|'item'|'cross'.
    Leaves not mentioned are Uncoloured (params etc.). Feature inputs must
    therefore arrive in named containers (dicts / dataclasses) so their
    domain is visible in the path — which is how every model in this repo
    passes feeds.
    """
    closed = jax.make_jaxpr(fn, static_argnums=static_argnums)(*example_args)
    jaxpr = closed.jaxpr

    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(example_args)
    colors: dict = {}
    colors_in: dict[int, Color] = {}
    for i, (path, _leaf) in enumerate(leaves_with_paths):
        key = jax.tree_util.keystr(path)
        dom = None
        for name, d in domains.items():
            if name in key:
                dom = d
                break
        c = (Color.YELLOW if dom == "user"
             else Color.BLUE if dom in ("item", "cross")
             else Color.UNCOLORED)
        if i < len(jaxpr.invars):
            colors[jaxpr.invars[i]] = c
            colors_in[i] = c

    mixed: list[int] = []
    producers: dict = {}
    for idx, eqn in enumerate(jaxpr.eqns):
        in_colors = [_color_of_var(colors, v) for v in eqn.invars]
        out_color = _merge(in_colors)
        for ov in eqn.outvars:
            colors[ov] = out_color
            producers[ov] = (idx, eqn)
        if (eqn.primitive.name == "concatenate"
                and Color.YELLOW in in_colors and Color.BLUE in in_colors):
            mixed.append(idx)

    # forward walk: from each boundary concat output, follow transparent eqns
    # to dot_general.
    eligible: list[EligibleMatMul] = []
    seen_dots: set[int] = set()
    for cidx in mixed:
        frontier = set(jaxpr.eqns[cidx].outvars)
        while frontier:
            nxt = set()
            for idx, eqn in enumerate(jaxpr.eqns):
                if not any((not isinstance(v, jcore.Literal)) and v in frontier
                           for v in eqn.invars):
                    continue
                pname = eqn.primitive.name
                if pname == "dot_general" and idx not in seen_dots:
                    seen_dots.add(idx)
                    eligible.append(EligibleMatMul(
                        eqn_index=idx, primitive=pname,
                        boundary_concat_index=cidx,
                        lhs_shape=tuple(eqn.invars[0].aval.shape),
                        rhs_shape=tuple(eqn.invars[1].aval.shape)))
                elif pname in TRANSPARENT_PRIMITIVES:
                    nxt.update(eqn.outvars)
            frontier = nxt

    return JaxprGCAReport(colors_in=colors_in, mixed_concats=mixed,
                          eligible=eligible, n_eqns=len(jaxpr.eqns))
