"""GCA — Graph Coloring Algorithm (paper §2.3, Algorithm 1).

Detects MaRI-optimizable MatMul (dense) nodes automatically:

1. Initialize: user-side feature nodes Yellow; item/cross-side Blue;
   everything else Uncolored.
2. DFS colour propagation with Blue dominating (a node fed by any Blue
   ancestor is Blue; fed only by Yellow is Yellow).
3. Every ``concat`` whose inputs mix Yellow and Blue is a boundary node.
4. Every matmul reachable from a boundary concat through *non-computational*
   ops (TRANSPARENT_OPS) is MaRI-optimizable.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.graph.ir import Graph, Node, TRANSPARENT_OPS


class Color(enum.Enum):
    UNCOLORED = 0
    YELLOW = 1  # user-side
    BLUE = 2    # item/cross-side


@dataclasses.dataclass
class GCAResult:
    colors: dict[str, Color]
    boundary_concats: list[str]                 # mixed-input concat nodes
    eligible: dict[str, str]                    # dense node -> its boundary concat
    user_subgraph: set[str]                     # Yellow nodes (batch-1 one-shot set)

    def summary(self) -> str:
        ny = sum(1 for c in self.colors.values() if c is Color.YELLOW)
        nb = sum(1 for c in self.colors.values() if c is Color.BLUE)
        return (f"GCA: {ny} yellow / {nb} blue nodes, "
                f"{len(self.boundary_concats)} boundary concats, "
                f"{len(self.eligible)} MaRI-eligible matmuls: "
                f"{sorted(self.eligible)}")


def _init_colors(graph: Graph) -> dict[str, Color]:
    colors = {name: Color.UNCOLORED for name in graph.nodes}
    for n in graph.input_nodes():
        d = n.attrs.get("domain")
        if d == "user":
            colors[n.name] = Color.YELLOW
        elif d in ("item", "cross"):
            colors[n.name] = Color.BLUE
    return colors


def _propagate(graph: Graph, colors: dict[str, Color]) -> None:
    """Algorithm 1, step 2 — DFS with Blue dominance. Adjacency is computed
    once (traverse pruning per the paper's note)."""
    downstream: dict[str, list[str]] = {name: [] for name in graph.nodes}
    for n in graph.topo_order():
        for i in n.inputs:
            downstream[i].append(n.name)

    stack = [name for name, c in colors.items() if c is not Color.UNCOLORED]
    while stack:
        u = stack.pop()
        cu = colors[u]
        for v in downstream[u]:
            updated = False
            if cu is Color.BLUE and colors[v] is not Color.BLUE:
                colors[v] = Color.BLUE
                updated = True
            elif cu is Color.YELLOW and colors[v] is Color.UNCOLORED:
                colors[v] = Color.YELLOW
                updated = True
            if updated:
                stack.append(v)


def _matmuls_via_transparent(graph: Graph, start: str) -> set[str]:
    """Algorithm 1, step 3 — matmul nodes reachable from ``start`` through
    paths containing only non-computational nodes."""
    found: set[str] = set()
    stack = [start]
    seen = {start}
    while stack:
        u = stack.pop()
        for n in graph.consumers(u):
            if n.name in seen:
                continue
            if n.op == "dense":
                found.add(n.name)  # matmul reached — path ends here
            elif n.op in TRANSPARENT_OPS:
                seen.add(n.name)
                stack.append(n.name)
            # any other op is computational: path is broken
    return found


def run_gca(graph: Graph) -> GCAResult:
    colors = _init_colors(graph)
    _propagate(graph, colors)

    boundary: list[str] = []
    eligible: dict[str, str] = {}
    for n in graph.topo_order():
        if n.op != "concat":
            continue
        in_colors = {colors[i] for i in n.inputs}
        if Color.YELLOW in in_colors and Color.BLUE in in_colors:
            boundary.append(n.name)
            for m in _matmuls_via_transparent(graph, n.name):
                # first boundary wins; nested mixed concats keep the nearest
                eligible.setdefault(m, n.name)

    user_sub = {name for name, c in colors.items() if c is Color.YELLOW}
    return GCAResult(colors=colors, boundary_concats=boundary,
                     eligible=eligible, user_subgraph=user_sub)
