"""Feature & parameter reorganization (paper §2.4 — "a bitter lesson").

Industrial feature layouts interleave user/item/cross chunks; naive MaRI then
issues many fragmented matmuls (Table 3: up to 96% slower than neat MaRI).
This pass permutes boundary-concat segment order into the neat
``[user | item | cross]`` layout of Eq. 4 and remaps the learnable
parameters (weight rows) of every downstream matmul to match — a lossless
re-layout. Non-matmul consumers of a reorganized concat receive an explicit
``gather_last`` restore node so their semantics are untouched.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.gca import GCAResult, run_gca
from repro.core.mari import _segment_domain, _trace_chain
from repro.graph.ir import Graph, Node, infer_shapes

_DOMAIN_RANK = {"user": 0, "item": 1, "cross": 2, "rest": 3}


@dataclasses.dataclass
class ReorgPlan:
    concat: str
    old_order: tuple[str, ...]
    new_order: tuple[str, ...]
    perm: tuple[int, ...]            # new position -> old segment index
    row_perm: np.ndarray             # new row -> old row (for weight remap)
    remapped_denses: tuple[str, ...]
    restored_consumers: tuple[str, ...]


def reorganize(graph: Graph, gca: GCAResult | None = None
               ) -> tuple[Graph, list[ReorgPlan]]:
    gca = gca or run_gca(graph)
    shapes = infer_shapes(graph)
    new = graph.copy()
    plans: list[ReorgPlan] = []

    for cname in gca.boundary_concats:
        concat = graph.nodes[cname]
        segs = concat.inputs
        widths = [shapes[s][-1] for s in segs]
        domains = [_segment_domain(graph, gca.colors, s) for s in segs]
        perm = tuple(sorted(range(len(segs)),
                            key=lambda i: (_DOMAIN_RANK[domains[i]], i)))
        if perm == tuple(range(len(segs))):
            continue  # already neat

        offs = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
        row_perm = np.concatenate(
            [np.arange(offs[i], offs[i + 1]) for i in perm])

        new.nodes[cname] = Node(cname, "concat", tuple(segs[i] for i in perm),
                                dict(concat.attrs))

        remapped, restored = [], []
        for dense_name, bc in gca.eligible.items():
            if bc == cname and _trace_chain(graph, graph.nodes[dense_name], cname) is not None:
                remapped.append(dense_name)
        # consumers not reached through a rewrite-safe chain need a restore.
        reachable_from_denses = set(remapped)
        for cons in graph.consumers(cname):
            if cons.name in reachable_from_denses:
                continue
            if cons.op == "dense" or _leads_only_to_remapped(
                    graph, cons, reachable_from_denses):
                continue
            restore_perm = np.argsort(row_perm)
            rn = f"{cname}__restore_for_{cons.name}"
            new.nodes[rn] = Node(rn, "gather_last", (cname,),
                                 {"indices": tuple(int(i) for i in restore_perm)})
            patched = tuple(rn if i == cname else i for i in cons.inputs)
            new.nodes[cons.name] = Node(cons.name, cons.op, patched,
                                        dict(cons.attrs))
            restored.append(cons.name)

        # reinsert restore nodes in topological position: rebuild node dict
        new.nodes = _retopo(new)
        plans.append(ReorgPlan(
            concat=cname, old_order=segs,
            new_order=tuple(segs[i] for i in perm), perm=perm,
            row_perm=row_perm, remapped_denses=tuple(remapped),
            restored_consumers=tuple(restored)))
    return new, plans


def _leads_only_to_remapped(graph: Graph, node: Node, remapped: set[str]) -> bool:
    """True if ``node`` is a transparent op whose every consumer path ends in
    a remapped dense (so no restore needed)."""
    from repro.graph.ir import REWRITE_SAFE_OPS
    if node.op not in REWRITE_SAFE_OPS:
        return False
    for c in graph.consumers(node.name):
        if c.name in remapped:
            continue
        if not _leads_only_to_remapped(graph, c, remapped):
            return False
    return True


def _retopo(g: Graph) -> dict[str, Node]:
    """Kahn re-topo-sort of the node dict (restore nodes were appended)."""
    indeg = {k: 0 for k in g.nodes}
    for n in g.nodes.values():
        for i in n.inputs:
            indeg[n.name] = indeg.get(n.name, 0) + 1
    order: dict[str, Node] = {}
    ready = [k for k, v in g.nodes.items() if not v.inputs]
    remaining = {k: set(v.inputs) for k, v in g.nodes.items()}
    while ready:
        k = ready.pop(0)
        order[k] = g.nodes[k]
        for name, deps in remaining.items():
            if k in deps:
                deps.discard(k)
                if not deps and name not in order and name not in ready:
                    ready.append(name)
    if len(order) != len(g.nodes):
        raise ValueError("reorg produced a cyclic graph")
    return order


def convert_params_reorg(plans: list[ReorgPlan], params: dict) -> dict:
    """Remap weight rows of every dense affected by a reorganization."""
    out = dict(params)
    for plan in plans:
        for dense in plan.remapped_denses:
            p = dict(out[dense])
            p["w"] = p["w"][plan.row_perm]
            out[dense] = p
    return out
