"""Thread-local sharding policy.

Model code (``repro.models.transformer``) stays mesh-agnostic: instead of
threading shardings through every function signature, the launch layer
activates a policy for the duration of a trace::

    with policy.use(moe_shard_axes=("data",), residual=NamedSharding(...)):
        jitted.lower(*args)

and the model consults it at trace time via ``policy.get`` (a value or
None) or ``policy.constrain`` (``with_sharding_constraint`` when the key is
set, identity otherwise). Policies nest — inner ``use`` blocks shadow outer
keys — and are thread-local, so concurrent traces (the serve batcher's
worker thread vs. the main thread) cannot leak shardings into each other.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterator

_local = threading.local()


def _stack() -> list[dict]:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


@contextlib.contextmanager
def use(**kv: Any) -> Iterator[None]:
    """Activate policy entries for the enclosed trace (nestable)."""
    _stack().append(kv)
    try:
        yield
    finally:
        _stack().pop()


def get(key: str, default: Any = None) -> Any:
    """Innermost active value for ``key``, or ``default``."""
    for frame in reversed(_stack()):
        if key in frame:
            return frame[key]
    return default


def constrain(x, key: str):
    """``with_sharding_constraint(x, policy[key])`` if set, else ``x``."""
    sh = get(key)
    if sh is None:
        return x
    import jax
    return jax.lax.with_sharding_constraint(x, sh)


def active() -> dict:
    """Flattened view of the current policy (inner frames win)."""
    out: dict = {}
    for frame in _stack():
        out.update(frame)
    return out
