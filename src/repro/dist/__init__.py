"""repro.dist — distributed execution subsystem.

Four layers, smallest first:

* ``policy``   — thread-local sharding policy the model code consults
  (``policy.use(...)`` / ``policy.get`` / ``policy.constrain``) so model
  functions stay mesh-agnostic;
* ``sharding`` — PartitionSpec rule sets for every model family
  (LM Megatron-style TP + ZeRO-1, recsys big-table sharding, GNN
  replication) plus the stage-2 candidate-axis serving specs;
* ``compress`` — int8 gradient/score compression (``quantize_int8``,
  ``compressed_psum`` with error feedback);
* ``topology`` / ``runner`` — multi-process serving: ``jax.distributed``
  process topology, the collective-aware bucket planner, and the SPMD
  serving runner that drives ``ServingEngine`` across workers.
"""
from repro.dist import policy  # noqa: F401
from repro.dist.compress import (compressed_psum, dequantize_int8,  # noqa: F401
                                 quantize_int8)
from repro.dist.sharding import (candidate_pspecs, dp_axes,  # noqa: F401
                                 lm_param_pspecs, named, recsys_param_pspecs,
                                 zero1_pspecs)
from repro.dist.topology import (Topology, bucket_for,  # noqa: F401
                                 candidate_mesh, plan_buckets)
