"""Int8 compression for cross-shard reductions (gradients, shard scores).

Symmetric linear quantizer: ``q = round(x / scale)`` with
``scale = max|x| / 127``, so ``|dequantize(q) - x| <= scale / 2``
(round-to-nearest) — the bound ``tests/test_property.py`` checks.

``compressed_psum`` is the collective built on it: participants agree on
a shared scale (one ``pmax`` scalar per leaf), quantize to int8 codes,
and the reduce moves the integer code tensor instead of the f32 original;
the local quantization residual is returned as an **error-feedback** term
— add it to the next step's input and the bias cancels over steps (the
standard EF-SGD construction), which is what makes a lossy ~4x-smaller
wire format usable for gradient sync.
Score reduction in the serving runner reuses the same quantizer for its
opt-in compressed result gather (``repro.dist.runner``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization: returns (q int8, scale f32 scalar).

    ``scale = max|x| / 127``; an all-zero input keeps scale 0 (dequantizes
    to exact zeros — the divide guards internally).
    """
    x = jnp.asarray(x)
    scale = (jnp.max(jnp.abs(x)) / 127.0).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / safe), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def compressed_psum(tree, axis_name: str):
    """Int8-compressed all-reduce **mean** over ``axis_name`` with error
    feedback. Call inside ``shard_map``/``pmap``.

    Shared-scale formulation (the standard integer-accumulating
    compressed all-reduce): a tiny ``pmax`` agrees on one scale per leaf,
    every participant quantizes to int8 codes against it, and the reduce
    moves the integer code tensor (int8 value range, int32 accumulator —
    summing codes of a shared scale is exact, which is what makes integer
    wire formats composable with ring reductions) plus that single f32
    scale, instead of the full f32 tensor.

    Returns ``(mean_tree, err_tree)``:

    * ``mean_tree`` — per-leaf mean over the axis of the participants'
      dequantized values;
    * ``err_tree`` — this participant's residual ``x - dequantize(q)``.
      Feed it back into the next step's input (error feedback), so the
      quantization bias cancels over steps instead of accumulating.

    With one participant: ``mean == dequantize(quantize(x))`` and
    ``mean + err == x`` exactly.
    """
    n = jax.lax.psum(1, axis_name)

    def one(x):
        xf = x.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
        scale = (amax / 127.0).astype(jnp.float32)
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        err = xf - deq
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean, err

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    outs = [one(x) for x in leaves]
    mean_tree = jax.tree_util.tree_unflatten(treedef, [m for m, _ in outs])
    err_tree = jax.tree_util.tree_unflatten(treedef, [e for _, e in outs])
    return mean_tree, err_tree


def compressed_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Int8-compressed tiled all-gather over leading dim. Call inside
    ``shard_map``.

    The serving engine's opt-in score-collection path: each shard
    quantizes its local score block, the all-gather moves int8 rows plus
    one f32 scale per shard (~4x less wire traffic than the fp32 gather),
    and every participant dequantizes each block with its producer's
    scale. Per-element error is bounded by that shard's ``scale / 2``.
    """
    rows = x.shape[0]                       # rows per shard (static)
    q, scale = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name, axis=0, tiled=True)
    sg = jax.lax.all_gather(scale, axis_name)          # (shards,)
    row_scale = jnp.repeat(sg, rows)                   # block i -> scale i
    return qg.astype(jnp.float32) * row_scale.reshape(
        (-1,) + (1,) * (x.ndim - 1))
