"""Process/device topology for multi-process serving.

``Topology`` describes one worker's place in a (hosts × devices) fleet and
owns the ``jax.distributed`` handshake; ``candidate_mesh`` flattens the
fleet's devices into the 1-D 'cand' mesh stage 2 shards over; the bucket
planner rounds candidate buckets so **no shard ever receives a ragged
tail** — every compiled stage-2 shape divides evenly over the mesh, which
is what keeps the multi-process dispatch collective-free until the final
score all-gather.

Bucket invariants (property-tested in ``tests/test_dist.py``):

* every bucket is a power of two and a multiple of the shard count;
* per-shard work (bucket / shards) is itself a power of two — one compiled
  executable family per (bucket, shard-count), aligned work per device;
* total padding over a pool never exceeds one bucket.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import numpy as np

from repro.common import next_pow2, prev_pow2


# ---------------------------------------------------------------------------
# Process topology
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """One worker's view of the serving fleet.

    ``initialize()`` must run before any other jax call in the process
    (device enumeration locks on first use). Single-process topologies
    skip the distributed handshake entirely — the degenerate case needs
    no coordinator.
    """
    num_processes: int = 1
    process_id: int = 0
    coordinator: str = "localhost:12421"

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1

    def initialize(self) -> "Topology":
        if self.is_distributed:
            # CPU backends cross processes via gloo; TPU backends ignore
            # the setting and use ICI/DCN.
            try:
                jax.config.update("jax_cpu_collectives_implementation",
                                  "gloo")
            except AttributeError:
                pass  # older/newer jax without the knob: backend default
            jax.distributed.initialize(
                coordinator_address=self.coordinator,
                num_processes=self.num_processes,
                process_id=self.process_id)
        return self

    @classmethod
    def from_env(cls) -> "Topology":
        """Read REPRO_NUM_PROCESSES / REPRO_PROCESS_ID / REPRO_COORDINATOR
        (the runner CLI sets them for its spawned workers)."""
        return cls(
            num_processes=int(os.environ.get("REPRO_NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("REPRO_PROCESS_ID", "0")),
            coordinator=os.environ.get("REPRO_COORDINATOR",
                                       "localhost:12421"))


def candidate_mesh(n_shards: int | None = None) -> jax.sharding.Mesh:
    """1-D 'cand' mesh over the largest power-of-two prefix of the global
    device list (all processes' devices after ``Topology.initialize``).
    ``n_shards`` clamps the shard count (must be a power of two)."""
    devs = jax.devices()
    n = prev_pow2(len(devs))
    if n_shards is not None:
        if n_shards & (n_shards - 1):
            raise ValueError(f"n_shards must be a power of two: {n_shards}")
        n = min(n, n_shards)
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("cand",))


# ---------------------------------------------------------------------------
# Collective-aware bucket planner
# ---------------------------------------------------------------------------

def bucket_for(n: int, shards: int, *, min_bucket: int = 128,
               max_batch: int = 4096) -> int:
    """Smallest valid bucket holding ``n`` rows: a power of two, at least
    ``max(min_bucket, shards)``, at most ``max_batch`` — so bucket % shards
    == 0 and per-shard work is a power of two.

    With ``shards > 1`` a non-power-of-two ``max_batch`` cap is rounded
    DOWN to the nearest power of two (never below ``shards``): a cap-sized
    bucket must itself divide evenly over the mesh. Unsharded callers keep
    the raw cap (seed behavior — a cap-sized bucket needs no alignment).
    """
    if shards & (shards - 1):
        raise ValueError(f"shard count must be a power of two: {shards}")
    hi = max_batch if shards == 1 else max(prev_pow2(max_batch), shards)
    lo = max(min(min_bucket, hi), shards)
    return min(hi, next_pow2(max(n, lo)))


def plan_buckets(pool: int, shards: int, *, min_bucket: int = 128,
                 max_batch: int = 4096) -> list[int]:
    """Decompose a candidate pool into shard-aligned buckets.

    Greedy: full ``max_batch`` buckets while the remainder overflows one,
    then a single tail bucket sized to the remainder — so total padding is
    strictly less than the (smallest) tail bucket, i.e. never exceeds one
    bucket, and every bucket divides evenly over ``shards``.
    """
    if pool <= 0:
        return []
    out: list[int] = []
    rem = pool
    while rem > 0:
        b = bucket_for(rem, shards, min_bucket=min_bucket,
                       max_batch=max_batch)
        out.append(b)
        rem -= b
    return out
