"""Multi-process SPMD serving runner.

Every worker process runs the IDENTICAL program: build the paper's ranking
graph from a fixed seed, construct a ``ServingEngine`` with
``shard_candidates=True`` (the 'cand' mesh spans all processes' devices
after ``jax.distributed`` initializes), and drive the same request
sequence in lockstep. Stage 2's inputs are globalized onto the mesh —
candidate rows and the per-row user index sharded, params and rep tables
replicated — so each worker's devices score their candidate slice and the
closing all-gather (the step's one collective) hands every host the full
score vector.

Correctness contract (the subprocess test in ``tests/test_dist.py``):
sharded fp32 scores are **bit-identical** to a process-local single-device
``ServingEngine`` — candidate-axis sharding only partitions row-parallel
work, it reassociates nothing.

Usage (spawner re-execs itself as the workers)::

  python -m repro.dist.runner --spawn 2 --devices-per-process 2 --verify
  python -m repro.dist.runner --spawn 1 --devices-per-process 4 --bench
  python -m repro.dist.runner --spawn 2 --plan plan.json --verify

Each worker prints one JSON record per mode; the spawner re-emits worker
0's stdout and fails if any worker fails.

The serving configuration travels as a serialized ``ServePlan``: the
spawner resolves ONE plan (``--plan`` file or the flag defaults, sharding
forced on) and ships it to every worker as ``--plan-json``, so workers
build their engines from the identical declarative config instead of
re-parsing argv flags — the plan JSON is the single source of truth for
the SPMD fleet's engine shape.
"""
from __future__ import annotations

import os

# The forced host-device count must be locked in before any jax import
# (the spawner sets REPRO_HOST_DEVICES in each worker's environment).
if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_HOST_DEVICES"])
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import socket
import subprocess
import sys
import time

from repro.serve.plan import ServePlan

MODES = ("vani", "uoi", "mari")


def build_plan(args) -> ServePlan:
    """The fleet's serving plan: an optional ``--plan`` JSON file with the
    runner's operating requirements layered on top — candidate-axis
    sharding on (that is what this runner exists to drive) and hedging off
    (per-process duplicates would desynchronize the SPMD schedule).

    Flag overrides beat the plan file only when EXPLICITLY given; without
    a plan file the runner's own bench-sized defaults apply. A plan file's
    ``max_batch``/``min_bucket``/``compress_scores`` therefore survive
    unless the caller asks otherwise."""
    base = ServePlan.load(args.plan) if args.plan else ServePlan()
    over = {"batch__hedging": False}
    if not base.shard.shard_candidates:
        # force sharding ON, but keep a plan file's explicit shard COUNT
        over["shard__shard_candidates"] = True
    if args.max_batch is not None:
        over["batch__max_batch"] = args.max_batch
    elif not args.plan:
        over["batch__max_batch"] = 256
    if args.min_bucket is not None:
        over["batch__min_bucket"] = args.min_bucket
    elif not args.plan:
        over["batch__min_bucket"] = 16
    if args.compress_scores:             # store_true: only ever forces ON
        over["shard__compress_scores"] = True
    if getattr(args, "device_resident", False):
        # persistent device rep tables (serve/cache.DeviceRepStore). On a
        # single-process mesh the sharded engine stores the tables with
        # the replicated boundary shardings and skips per-pack re-stacking;
        # multi-process engines fall back at engine level (per-process
        # asynchronous table writes cannot stay SPMD-identical).
        over["cache__device_resident"] = True
    if getattr(args, "trace", None):
        over["obs__trace"] = True
    return base.evolve(**over)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def build_problem(scale: float, pool: int, users: int):
    """Deterministic (graph, params, requests) — identical in every
    worker, so the SPMD dispatch sequence matches without coordination."""
    import jax

    from repro.data.features import make_recsys_feeds
    from repro.graph.executor import init_graph_params
    from repro.models.ranking import (PaperRankingConfig,
                                      build_paper_ranking_model)
    from repro.serve.engine import ServeRequest

    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(scale))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    reqs = []
    for u in range(users):
        # ragged pools on purpose: exercises the shard-aligned bucketing
        n = max(1, pool // users + 7 * u)
        feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(u + 1))
        reqs.append(ServeRequest(
            user_id=u,
            user_feeds={k: v for k, v in feeds.items() if k in user_in},
            candidate_feeds={k: v for k, v in feeds.items()
                             if k not in user_in}))
    return graph, params, reqs


def run_worker(args) -> int:
    from repro.dist.topology import Topology

    topo = Topology.from_env().initialize()
    import jax
    import numpy as np

    from repro.serve.engine import ServingEngine

    graph, params, reqs = build_problem(args.scale, args.pool, args.users)
    pool_rows = sum(next(iter(r.candidate_feeds.values())).shape[0]
                    for r in reqs)
    # the spawner ships the resolved plan as JSON; a directly-invoked
    # worker (no --plan-json) falls back to building it from its own flags
    plan = (ServePlan.from_json(args.plan_json) if args.plan_json
            else build_plan(args))
    compress = plan.shard.compress_scores
    # fault-tolerance surface (plan.ft): a per-worker FaultInjector whose
    # ``spmd_heartbeat`` site simulates missed per-step heartbeats, fed to
    # a HeartbeatMonitor on a step-counter clock (timeout ~1.5 steps: one
    # missed beat degrades, two consecutive misses declare the worker
    # dead) — the detection layer the elastic-remesh planner consumes.
    injector = monitor = None
    wid = f"w{topo.process_id}"
    hb_step = [0]
    hb_missed = 0
    if plan.ft.inject and plan.ft.sites:
        from repro.ft import FaultInjector, HeartbeatMonitor
        injector = FaultInjector(plan.ft.sites,
                                 seed=plan.ft.seed + topo.process_id)
        monitor = HeartbeatMonitor([wid], timeout=1.5,
                                   clock=lambda: float(hb_step[0]))
    records = []
    tracers = {}
    for mode in args.modes.split(","):
        mplan = plan.evolve(graph__mode=mode)
        ref = ref_scores = None
        if args.verify:
            # process-local reference: plain single-device engine
            # (identical inputs in every worker -> identical references)
            ref = ServingEngine(graph, params, plan=mplan.evolve(
                shard__shard_candidates=False,
                shard__compress_scores=False))
            ref_scores = [r.scores for r in ref.score_coalesced(reqs)]

        eng = ServingEngine(graph, params, plan=mplan)
        res = eng.score_coalesced(reqs)         # compile + verify pass
        rec = {"mode": mode, "processes": topo.num_processes,
               "shards": int(eng.mesh.devices.size),
               "devices_per_process": len(jax.local_devices()),
               "pool": pool_rows,
               "users": len(reqs),
               "compress_scores": bool(compress),
               "plan": mplan.to_dict()}
        if args.verify:
            if compress:
                # int8 wire: exact identity is forfeit by construction;
                # per-element error <= that shard's scale/2
                tol = max(float(np.abs(s).max()) for s in ref_scores) \
                    / 127.0 / 2.0 + 1e-6
                ok = all(np.allclose(a.scores, b, atol=tol)
                         for a, b in zip(res, ref_scores))
                rec["within_int8_bound"] = bool(ok)
            else:
                ok = all(np.array_equal(a.scores, b)
                         for a, b in zip(res, ref_scores))
                rec["bit_identical"] = bool(ok)
            if not ok:
                print(json.dumps(rec), flush=True)
                print(f"[runner] VERIFY FAILED mode={mode}", file=sys.stderr)
                return 1
        if args.bench:
            eng.score_coalesced(reqs)           # warm every shape
            eng.profiler.reset()                # breakdown = timed loop only
            walls = []
            for _ in range(args.passes):
                t0 = time.perf_counter()
                eng.score_coalesced(reqs)
                walls.append(time.perf_counter() - t0)
            wall = float(np.median(walls))
            rec["qps"] = round(len(reqs) / wall, 2)
            rec["rows_per_s"] = round(rec["pool"] / wall, 1)
            # per-phase mean µs per engine call over the timed passes —
            # the same taxonomy as the serve bench's breakdown rows, so
            # the dispatch path stays attributable per shard count
            rec["breakdown"] = eng.profiler.snapshot()
        if monitor is not None:
            from repro.serve.errors import FaultInjected
            hb_step[0] += 1
            try:
                injector.poke("spmd_heartbeat", worker=wid, mode=mode)
                monitor.heartbeat(wid)
            except FaultInjected:
                hb_missed += 1          # this step's beat never arrived
            rec["heartbeat"] = {"worker": wid, "step": hb_step[0],
                                "missed": hb_missed,
                                "dead": monitor.dead()}
            rec["faults"] = injector.stats()
        records.append(rec)
        if eng.tracer is not None:
            tracers[mode] = eng.tracer    # events outlive the engine
        eng.close()
        if ref is not None:
            ref.close()
        if topo.process_id == 0:
            print(json.dumps(rec), flush=True)
    if args.trace:
        from repro.obs import write_trace
        write_trace(args.trace, tracers)
    if topo.process_id == 0:
        print(json.dumps({"ok": True, "records": len(records)}), flush=True)
    return 0


def spawn(args) -> int:
    """Re-exec this module once per worker process on localhost.

    Worker output goes to temp files, not pipes: the workers are coupled
    through collectives, so serially draining pipes could deadlock the
    fleet if one worker filled its pipe buffer (chatty XLA/gloo warnings)
    while another held a collective open.
    """
    import tempfile

    port = args.port or _free_port()
    workers = []
    for pid in range(args.spawn):
        env = dict(os.environ)
        env.update({
            "REPRO_NUM_PROCESSES": str(args.spawn),
            "REPRO_PROCESS_ID": str(pid),
            "REPRO_COORDINATOR": f"localhost:{port}",
            "REPRO_HOST_DEVICES": str(args.devices_per_process),
        })
        src = os.path.join(os.path.dirname(__file__), "..", "..")
        env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                             + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "repro.dist.runner",
               "--modes", args.modes, "--scale", str(args.scale),
               "--pool", str(args.pool), "--users", str(args.users),
               "--passes", str(args.passes),
               # ONE resolved plan, serialized — workers do not re-derive
               # engine knobs from argv
               "--plan-json", build_plan(args).to_json(indent=None)]
        for flag in ("verify", "bench"):
            if getattr(args, flag):
                cmd.append("--" + flag.replace("_", "-"))
        if args.trace:
            # per-worker trace file; the spawner merges them afterwards
            # with pid = shard index so all workers share one timeline
            cmd += ["--trace", f"{args.trace}.w{pid}"]
        out_f = tempfile.TemporaryFile(mode="w+")
        err_f = tempfile.TemporaryFile(mode="w+")
        workers.append((subprocess.Popen(cmd, env=env, stdout=out_f,
                                         stderr=err_f, text=True),
                        out_f, err_f))
    rc = 0
    deadline = time.monotonic() + args.timeout
    for pid, (p, out_f, err_f) in enumerate(workers):
        try:
            p.wait(timeout=max(1.0, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait()
            print(f"[runner] worker {pid} timed out", file=sys.stderr)
            rc = 1
        out_f.seek(0)
        err_f.seek(0)
        out, err = out_f.read(), err_f.read()
        out_f.close()
        err_f.close()
        if pid == 0 and out:
            sys.stdout.write(out)
        if p.returncode != 0:
            print(f"[runner] worker {pid} failed rc={p.returncode}:\n"
                  + err[-3000:], file=sys.stderr)
            rc = 1
    if args.trace and rc == 0:
        from repro.obs import merge_trace_files
        paths = [f"{args.trace}.w{pid}" for pid in range(args.spawn)]
        merge_trace_files(paths, args.trace)    # pid i = shard i
        for p in paths:
            os.remove(p)
        print(f"[runner] merged {args.spawn} worker traces -> {args.trace}")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spawn", type=int, default=0,
                    help="spawn N localhost worker processes and exit")
    ap.add_argument("--devices-per-process", type=int, default=2)
    ap.add_argument("--port", type=int, default=0,
                    help="coordinator port (0 = pick a free one)")
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--pool", type=int, default=90)
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="stage-2 row budget (default: the --plan file's "
                         "value, else 256)")
    ap.add_argument("--min-bucket", type=int, default=None,
                    help="smallest bucket (default: the --plan file's "
                         "value, else 16)")
    ap.add_argument("--passes", type=int, default=5)
    ap.add_argument("--verify", action="store_true",
                    help="assert sharded == local fp32 scores bit-identically")
    ap.add_argument("--bench", action="store_true",
                    help="emit qps rows per mode")
    ap.add_argument("--device-resident", action="store_true",
                    help="persistent device rep tables + donated stage-2 "
                         "buffers (single-process meshes; multi-process "
                         "engines fall back to per-pack re-stacking)")
    ap.add_argument("--compress-scores", action="store_true",
                    help="opt-in int8-compressed score all-gather")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="base ServePlan JSON file (spawner: sharding is "
                         "forced on top of it)")
    ap.add_argument("--plan-json", default=None, metavar="JSON",
                    help="worker-side: the serialized plan shipped by the "
                         "spawner")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="spawner: merge per-worker Chrome traces here "
                         "(pid = shard index); worker: write own trace")
    ap.add_argument("--timeout", type=int, default=900)
    args = ap.parse_args()
    if args.spawn:
        return spawn(args)
    return run_worker(args)


if __name__ == "__main__":
    sys.exit(main())
