"""PartitionSpec rule sets for every model family + the serving specs.

The seed's launch layer (``repro.launch.steps``) builds one jitted program
per (architecture × input shape × mesh) cell; this module is where every
in/out sharding it uses comes from. Rules, not enumerations: each family
gets a function from config/graph to a pytree of ``PartitionSpec`` whose
tree structure mirrors the param tree exactly, so ``jax.tree_util``
transforms (``named``, ``zero1_pspecs``) apply mechanically.

Conventions
-----------
* axis names: ``data`` (+ ``pod`` when multi-pod) carry batch parallelism,
  ``model`` carries tensor parallelism, ``cand`` is the serving-side
  candidate axis (see ``candidate_pspecs``).
* a dim is sharded only when every production config divides evenly
  (vocab pads to 256 = 16×16 precisely so embed/lm_head can consume both
  axes); anything uncertain stays replicated — a replicated spec is always
  valid, a non-divisible one is a compile error.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Embedding tables at or above this row count are worth model-sharding;
# kept in sync with repro.models.recsys.SHARD_THRESHOLD (tables >= this pad
# their vocab to a shardable multiple at build time).
TABLE_SHARD_THRESHOLD = 65536

# ZeRO-1 shards optimizer state over this many data-parallel ways in the
# production meshes (16×16 single pod, 2×16×16 multi-pod: the 'data' axis
# is 16 in both) — a dim is eligible only if it divides evenly.
ZERO1_MULTIPLE = 16


def _rep(shape) -> P:
    """Rank-matched replicated spec (indexable per-dim, unlike P())."""
    return P(*([None] * len(shape)))


def named(mesh: Mesh, tree):
    """Map every ``PartitionSpec`` leaf to ``NamedSharding(mesh, spec)``."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that carry data parallelism ('pod' joins 'data' when the
    mesh spans pods — gradient sync crosses DCN on that axis)."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


# ---------------------------------------------------------------------------
# LM family — Megatron-style tensor parallelism + ZeRO-1 optimizer state
# ---------------------------------------------------------------------------

def lm_param_pspecs(cfg) -> dict:
    """PartitionSpecs mirroring ``init_lm_params(cfg)``.

    Column-parallel in-projections (wq/wk/wv, wg/wu) shard their output
    dim over 'model'; row-parallel out-projections (wo, wd) shard their
    contraction dim, so each layer needs one all-reduce per block.
    embed/lm_head consume ('model', 'data') jointly on the padded vocab
    (vocab_padded % 256 == 0 by construction).
    """
    attn = {"wq": P(None, None, "model"), "wk": P(None, None, "model"),
            "wv": P(None, None, "model"), "wo": P(None, "model", None)}
    if cfg.qk_norm:
        attn["q_norm"] = P(None, None)
        attn["k_norm"] = P(None, None)
    if cfg.is_moe:
        ffn = {"router": P(None, None, None),
               "wg": P(None, None, None, "model"),
               "wu": P(None, None, None, "model"),
               "wd": P(None, None, "model", None)}
    else:
        ffn = {"wg": P(None, None, "model"), "wu": P(None, None, "model"),
               "wd": P(None, "model", None)}
    return {
        "embed": P(("model", "data"), None),
        "layers": {"attn": attn, "ffn": ffn,
                   "ln1": P(None, None), "ln2": P(None, None)},
        "final_norm": P(None),
        "lm_head": P(None, ("model", "data")),
    }


def zero1_pspecs(pspecs, shapes, *, axis: str = "data",
                 multiple: int = ZERO1_MULTIPLE):
    """ZeRO-1: additionally shard optimizer-state replicas over ``axis``.

    For each param, the largest dim that (a) is unsharded in the param
    spec and (b) divides by ``multiple`` gets ``axis``; params already
    touching ``axis`` (embed/lm_head) and params with no eligible dim keep
    their spec. No axis ever appears twice in one spec by construction.
    """
    def one(spec: P, sds) -> P:
        used = [a for part in spec if part
                for a in (part if isinstance(part, tuple) else (part,))]
        if axis in used:
            return spec
        shape = sds.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = None, 0
        for i, (part, size) in enumerate(zip(parts, shape)):
            if part is None and size % multiple == 0 and size > best_size:
                best, best_size = i, size
        if best is None:
            return spec
        parts[best] = axis
        return P(*parts)

    return jax.tree_util.tree_map(
        one, pspecs, shapes, is_leaf=lambda x: isinstance(x, P))


def lm_batch_pspec(mesh: Mesh) -> P:
    """(B, S) token batches: batch over the DP axes, sequence replicated."""
    return P(dp_axes(mesh), None)


def lm_cache_pspecs(mesh: Mesh, batch: int) -> dict:
    """KV cache (L, B, W, n_kv_heads, hd): batch dim over DP when it
    divides; heads stay replicated (n_kv_heads rarely divides the TP
    degree — GQA archs have 4-8 KV heads vs model=16)."""
    ndp = 1
    dp = dp_axes(mesh)
    for a in dp:
        ndp *= mesh.shape[a]
    lead = dp if batch % ndp == 0 and batch >= ndp else None
    spec = P(None, lead, None, None, None)
    return {"k": spec, "v": spec}


def lm_state_pspecs(cfg, params_shapes=None) -> dict:
    """Train-state specs: Megatron params + ZeRO-1 adamw moments/master."""
    pp = lm_param_pspecs(cfg)
    if params_shapes is None:
        from repro.models.transformer import lm_param_specs
        params_shapes = lm_param_specs(cfg)
    zp = zero1_pspecs(pp, params_shapes)
    return {"params": pp,
            "opt": {"mu": zp, "nu": zp, "master": zp, "step": P()}}


# ---------------------------------------------------------------------------
# RecSys family — big embedding tables sharded, dense layers replicated
# ---------------------------------------------------------------------------

def recsys_param_pspecs(graph, table_axes: tuple[str, ...] = ("model",)
                        ) -> dict:
    """PartitionSpecs mirroring ``init_graph_params(graph)``.

    Embedding tables at/above ``TABLE_SHARD_THRESHOLD`` rows shard their
    vocab dim over ``table_axes`` (their vocab is padded to a shardable
    multiple at build time — ``repro.models.recsys.pad_vocab``); small
    tables and every dense/attention weight replicate. MaRI's premise is
    that ranker MLPs are small relative to the tables — replicating them
    trades negligible memory for zero matmul collectives.
    """
    from repro.graph.executor import init_graph_params

    sds = jax.eval_shape(
        lambda: init_graph_params(graph, jax.random.PRNGKey(0)))
    pp = jax.tree_util.tree_map(lambda s: _rep(s.shape), sds)
    lead = table_axes[0] if len(table_axes) == 1 else table_axes
    for n in graph.param_nodes():
        if (n.op == "embedding"
                and n.attrs["vocab"] >= TABLE_SHARD_THRESHOLD):
            pp[n.name]["table"] = P(lead, None)
    return pp


def recsys_feed_pspecs(graph, mesh: Mesh, train: bool = False) -> dict:
    """Input feeds: candidate/example rows over DP; serving-time user feeds
    (leading dim 1) replicated."""
    dp = dp_axes(mesh)
    specs = {}
    for n in graph.input_nodes():
        rank = 1 + len(n.attrs["shape"])
        lead = dp if (train or n.attrs.get("domain") != "user") else None
        specs[n.name] = P(lead, *([None] * (rank - 1)))
    return specs


def recsys_state_pspecs(graph, table_axes: tuple[str, ...] = ("model",)
                        ) -> dict:
    """Train-state specs: adam moments shard exactly like their params
    (the moment of a sharded table is itself that table's size)."""
    pp = recsys_param_pspecs(graph, table_axes=table_axes)
    return {"params": pp, "opt": {"mu": pp, "nu": pp, "step": P()}}


# ---------------------------------------------------------------------------
# GNN family — small params, fully replicated (edges carry the parallelism)
# ---------------------------------------------------------------------------

def gnn_state_pspecs(params_shapes) -> dict:
    pp = jax.tree_util.tree_map(lambda s: _rep(s.shape), params_shapes)
    return {"params": pp, "opt": {"mu": pp, "nu": pp, "step": P()}}


# ---------------------------------------------------------------------------
# Serving stage 2 — candidate-axis sharding over a 'cand' mesh
# ---------------------------------------------------------------------------

# Serving-side re-export: the stage-2 rep-table contract (stacked (U, ...)
# tables, rank-matched replication; the gather-at-load path makes this the
# whole cross-shard story — see its docstring) is owned by the core split
# module, the layer that defines the boundary itself.
from repro.core.split import rep_table_pspecs  # noqa: E402,F401


def candidate_pspecs(mesh: Mesh, *, replicate_out: bool | None = None
                     ) -> tuple[tuple, object]:
    """(in_shardings, out_shardings) for the row-wise stage-2 executable
    ``fn(params, rep_table, user_index, candidate_feeds) -> outs``.

    Params and the stacked (U, ...) user-rep tables replicate (they are
    small and every shard needs every user — ``rep_table_pspecs`` gives the
    per-entry rank-matched form); the per-row user index and
    the candidate feeds shard over 'cand'; each device scores its candidate
    rows with zero in-flight collectives.

    Output: sharded over 'cand' in single-process meshes (the host reads
    all device shards directly); replicated when the mesh spans processes
    (the closing all-gather is the ONE collective of the serving step, and
    it hands every host the full score vector). ``replicate_out`` forces
    either form.
    """
    if replicate_out is None:
        replicate_out = len(set(d.process_index for d in
                                mesh.devices.flat)) > 1
    repl = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P("cand"))
    out = repl if replicate_out else shard
    return (repl, repl, shard, shard), out
