"""Async request queue that coalesces candidate chunks across users.

At "millions of users" scale the compiled stage-2 buckets sit mostly idle
if each request is served alone: every ragged pool pays its own padding and
every call its own dispatch. ``CoalescingBatcher`` is the standard
industrial answer — requests from *different users* are queued, and their
candidate chunks are packed into shared power-of-two buckets, each executed
as ONE cross-user stage-2 call (row-wise user reps gathered by a per-row
user index; see ``ServingEngine.score_coalesced``).

Usage::

    batcher = CoalescingBatcher(engine, linger_ms=2.0)
    fut = batcher.submit(req)          # non-blocking; Future[ServeResult]
    ...
    result = fut.result()
    batcher.close()

or synchronously for a burst of concurrent requests::

    results = batcher.score_many(reqs)

A single worker thread drains the queue: the first waiting request opens a
batch, then the worker lingers up to ``linger_ms`` (or until ``max_batch``
candidate rows / ``max_coalesce`` requests are waiting) collecting
co-arriving requests before handing the group to the engine. Coalesced
scores are bit-identical to per-request ``engine.score`` — both run the
same row-wise executable family.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Sequence

from repro.serve.engine import ServeRequest, ServeResult, ServingEngine


class CoalescingBatcher:
    def __init__(self, engine: ServingEngine, *, linger_ms: float = 2.0,
                 max_coalesce: int = 64, auto_start: bool = True):
        self.engine = engine
        self.linger_ms = linger_ms
        self.max_coalesce = max_coalesce
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._lock = threading.Lock()     # serializes submit vs close
        self._worker: threading.Thread | None = None
        self.batches = 0              # engine handoffs
        self.coalesced_requests = 0   # requests scored in a >1-request group
        self.requests = 0
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="coalescing-batcher", daemon=True)
        self._worker.start()

    def close(self) -> None:
        """Stop the worker after the queue drains; fail anything stranded."""
        with self._lock:              # no submit can interleave past here
            self._stop.set()
            self._q.put(None)         # wake the worker
        if self._worker is not None:
            self._worker.join(timeout=30)
            self._worker = None
        # a request that raced the shutdown may still sit in the dead queue;
        # its waiter must not block forever
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not None and item[1].set_running_or_notify_cancel():
                item[1].set_exception(RuntimeError("batcher closed"))

    def __enter__(self) -> "CoalescingBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------------
    def submit(self, req: ServeRequest) -> "Future[ServeResult]":
        """Enqueue a request; resolves once its group has been scored."""
        with self._lock:              # atomic vs the close() shutdown decision
            if (self._stop.is_set() or self._worker is None
                    or not self._worker.is_alive()):
                raise RuntimeError("batcher is not running (call start())")
            fut: Future = Future()
            self.requests += 1
            self._q.put((req, fut))
        return fut

    def score_many(self, reqs: Sequence[ServeRequest]) -> list[ServeResult]:
        """Submit a burst of concurrent requests; wait for all results."""
        futs = [self.submit(r) for r in reqs]
        return [f.result() for f in futs]

    # -- worker -------------------------------------------------------------
    def _candidate_rows(self, req: ServeRequest) -> int:
        return next(iter(req.candidate_feeds.values())).shape[0]

    def _run(self) -> None:
        import time
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            if item is None:
                if self._stop.is_set() and self._q.empty():
                    return
                continue
            group = [item]
            rows = self._candidate_rows(item[0])
            deadline = time.perf_counter() + self.linger_ms / 1e3
            while (len(group) < self.max_coalesce
                   and rows < self.engine.max_batch):
                timeout = deadline - time.perf_counter()
                if timeout <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=timeout)
                except queue.Empty:
                    break
                if nxt is None:
                    continue
                group.append(nxt)
                rows += self._candidate_rows(nxt[0])
            self._score_group(group)
            if self._stop.is_set() and self._q.empty():
                return

    def _score_group(self, group: list) -> None:
        # claim each future before doing work: a waiter that cancelled while
        # its request sat queued is dropped here, and a claimed (RUNNING)
        # future can no longer be cancelled — so set_result below cannot
        # race a cancel and kill the worker with InvalidStateError
        group = [(req, fut) for req, fut in group
                 if fut.set_running_or_notify_cancel()]
        if not group:
            return
        reqs = [req for req, _ in group]
        try:
            results = self.engine.score_coalesced(reqs)
        except BaseException as e:          # propagate to every waiter
            for _, fut in group:
                if not fut.done():
                    fut.set_exception(e)
            return
        self.batches += 1
        if len(group) > 1:
            self.coalesced_requests += len(group)
        for (_, fut), res in zip(group, results):
            fut.set_result(res)
