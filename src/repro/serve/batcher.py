"""Async request queue that coalesces candidate chunks across users.

At "millions of users" scale the compiled stage-2 buckets sit mostly idle
if each request is served alone: every ragged pool pays its own padding and
every call its own dispatch. ``CoalescingBatcher`` is the standard
industrial answer — requests from *different users* are queued, and their
candidate chunks are packed into shared power-of-two buckets, each executed
as ONE cross-user stage-2 call (row-wise user reps gathered by a per-row
user index; see ``ServingEngine.score_coalesced``).

Usage::

    batcher = CoalescingBatcher(engine, linger_ms=2.0)
    fut = batcher.submit(req)          # non-blocking; Future[ServeResult]
    ...
    result = fut.result()
    batcher.close()

or synchronously for a burst of concurrent requests::

    results = batcher.score_many(reqs)

A single worker thread drains the queue: the first waiting request opens a
batch, then the worker lingers up to ``linger_ms`` (or until ``max_batch``
candidate rows / ``max_coalesce`` requests are waiting) collecting
co-arriving requests before handing the group to the engine. Coalesced
scores are bit-identical to per-request ``engine.score`` — both run the
same row-wise executable family.

**SLO classes** — ``submit(req, slo="deadline", deadline_ms=...)`` marks a
request latency-critical: deadline requests jump the FIFO (the queue is
priority-ordered, FIFO within each class) and shrink the linger window —
a group opened by (or joined by) a deadline request lingers only
``linger_ms * deadline_linger_frac``, further capped by the request's
remaining deadline budget, so a latency-critical arrival never waits out a
full best-effort linger behind older bulk traffic.

The priority is strict: a workload whose deadline-class arrival rate alone
saturates the worker starves queued best-effort requests for as long as
the saturation lasts. That is the intended contract — the deadline class
is for a small latency-critical fraction of traffic, and protecting the
queue from a caller who tags everything "deadline" is admission control's
job (upstream of this batcher), not the dispatcher's. ``deadline_requests
/ requests`` is the counter to alarm on.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Sequence

from repro.serve.engine import ServeRequest, ServeResult, ServingEngine

SLO_BEST_EFFORT = "best_effort"
SLO_DEADLINE = "deadline"
_PRIO = {SLO_DEADLINE: 0, SLO_BEST_EFFORT: 1}


@dataclasses.dataclass(order=True)
class _Item:
    """Priority-queue entry: deadline class first, FIFO within a class."""
    prio: int
    seq: int
    req: ServeRequest | None = dataclasses.field(compare=False, default=None)
    fut: Future | None = dataclasses.field(compare=False, default=None)
    deadline_at: float | None = dataclasses.field(compare=False, default=None)
    submitted_at: float | None = dataclasses.field(compare=False,
                                                   default=None)


class CoalescingBatcher:
    def __init__(self, engine: ServingEngine, *, linger_ms: float = 2.0,
                 max_coalesce: int = 64, auto_start: bool = True,
                 deadline_linger_frac: float = 0.25):
        if getattr(engine, "_multiproc", False):
            # same hazard class as hedging under SPMD: each process's
            # batcher thread would form groups from its own wall-clock
            # linger/scheduling, so dispatch sequences (and collective
            # schedules) diverge across workers and the fleet deadlocks.
            # Multi-process serving drives score_coalesced directly in
            # lockstep (repro.dist.runner).
            raise ValueError(
                "CoalescingBatcher cannot wrap a multi-process sharded "
                "engine: group formation is timing-dependent and would "
                "desynchronize the SPMD collective schedule")
        self.engine = engine
        self.linger_ms = linger_ms
        self.max_coalesce = max_coalesce
        self.deadline_linger_frac = deadline_linger_frac
        self._q: queue.PriorityQueue = queue.PriorityQueue()
        self._seq = 0
        self._stop = threading.Event()
        self._lock = threading.Lock()     # serializes submit vs close
        self._worker: threading.Thread | None = None
        self.batches = 0              # engine handoffs
        self.coalesced_requests = 0   # requests scored in a >1-request group
        self.requests = 0
        self.deadline_requests = 0    # submitted with the deadline SLO
        # cumulative submit->handoff wait: the queueing share of end-to-end
        # latency that the engine's StageProfiler cannot see (it starts
        # timing only once the group reaches score_coalesced)
        self.queue_wait_ms = 0.0
        if auto_start:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="coalescing-batcher", daemon=True)
        self._worker.start()

    def close(self) -> None:
        """Stop the worker after the queue drains; fail anything stranded."""
        with self._lock:              # no submit can interleave past here
            self._stop.set()
            self._q.put(_Item(prio=2, seq=self._next_seq()))  # wake worker
        if self._worker is not None:
            self._worker.join(timeout=30)
            self._worker = None
        # a request that raced the shutdown may still sit in the dead queue;
        # its waiter must not block forever
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if (item.fut is not None
                    and item.fut.set_running_or_notify_cancel()):
                item.fut.set_exception(RuntimeError("batcher closed"))

    def __enter__(self) -> "CoalescingBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------------
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def submit(self, req: ServeRequest, *, slo: str = SLO_BEST_EFFORT,
               deadline_ms: float | None = None) -> "Future[ServeResult]":
        """Enqueue a request; resolves once its group has been scored.

        ``slo="deadline"`` marks it latency-critical: it jumps ahead of
        queued best-effort requests and shrinks its group's linger.
        ``deadline_ms`` (optional, implies the deadline class) additionally
        caps the linger by the remaining budget.
        """
        if deadline_ms is not None:
            slo = SLO_DEADLINE
        if slo not in _PRIO:
            raise ValueError(f"unknown SLO class {slo!r}")
        with self._lock:              # atomic vs the close() shutdown decision
            if (self._stop.is_set() or self._worker is None
                    or not self._worker.is_alive()):
                raise RuntimeError("batcher is not running (call start())")
            fut: Future = Future()
            self.requests += 1
            if slo == SLO_DEADLINE:
                self.deadline_requests += 1
            now = time.perf_counter()
            deadline_at = (now + deadline_ms / 1e3
                           if deadline_ms is not None else None)
            self._q.put(_Item(prio=_PRIO[slo], seq=self._next_seq(),
                              req=req, fut=fut, deadline_at=deadline_at,
                              submitted_at=now))
        return fut

    def score_many(self, reqs: Sequence[ServeRequest],
                   slo: str = SLO_BEST_EFFORT) -> list[ServeResult]:
        """Submit a burst of concurrent requests; wait for all results."""
        futs = [self.submit(r, slo=slo) for r in reqs]
        return [f.result() for f in futs]

    # -- worker -------------------------------------------------------------
    def _candidate_rows(self, req: ServeRequest) -> int:
        return next(iter(req.candidate_feeds.values())).shape[0]

    def _linger_until(self, item: _Item, now: float) -> float:
        """Group-close time implied by one member: full linger for
        best-effort, the shrunken deadline linger (further capped by the
        request's remaining budget) for deadline-class requests."""
        if item.prio == _PRIO[SLO_DEADLINE]:
            until = now + self.linger_ms * self.deadline_linger_frac / 1e3
            if item.deadline_at is not None:
                until = min(until, item.deadline_at)
            return until
        return now + self.linger_ms / 1e3

    def _run(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            if item.req is None:
                if self._stop.is_set() and self._q.empty():
                    return
                continue
            group = [item]
            rows = self._candidate_rows(item.req)
            deadline = self._linger_until(item, time.perf_counter())
            while (len(group) < self.max_coalesce
                   and rows < self.engine.max_batch):
                timeout = deadline - time.perf_counter()
                if timeout <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=timeout)
                except queue.Empty:
                    break
                if nxt.req is None:
                    continue
                group.append(nxt)
                rows += self._candidate_rows(nxt.req)
                # a deadline request joining an open group truncates the
                # remaining linger to its own (shrunken) window
                deadline = min(deadline,
                               self._linger_until(nxt, time.perf_counter()))
            self._score_group(group)
            if self._stop.is_set() and self._q.empty():
                return

    def _score_group(self, group: list[_Item]) -> None:
        # claim each future before doing work: a waiter that cancelled while
        # its request sat queued is dropped here, and a claimed (RUNNING)
        # future can no longer be cancelled — so set_result below cannot
        # race a cancel and kill the worker with InvalidStateError
        now = time.perf_counter()
        self.queue_wait_ms += sum(
            (now - it.submitted_at) * 1e3 for it in group
            if it.submitted_at is not None)
        group = [(it.req, it.fut) for it in group
                 if it.fut.set_running_or_notify_cancel()]
        if not group:
            return
        reqs = [req for req, _ in group]
        try:
            results = self.engine.score_coalesced(reqs)
        except BaseException as e:          # propagate to every waiter
            for _, fut in group:
                if not fut.done():
                    fut.set_exception(e)
            return
        self.batches += 1
        if len(group) > 1:
            self.coalesced_requests += len(group)
        for (_, fut), res in zip(group, results):
            fut.set_result(res)
