"""Async request queue that coalesces candidate chunks across users.

At "millions of users" scale the compiled stage-2 buckets sit mostly idle
if each request is served alone: every ragged pool pays its own padding and
every call its own dispatch. ``CoalescingBatcher`` is the standard
industrial answer — requests from *different users* are queued, and their
candidate chunks are packed into shared power-of-two buckets, each executed
as ONE cross-user stage-2 call (row-wise user reps gathered by a per-row
user index; see ``ServingEngine.score_coalesced``).

Usage::

    batcher = CoalescingBatcher(engine, linger_ms=2.0)
    fut = batcher.submit(req)          # non-blocking; Future[ServeResult]
    ...
    result = fut.result()
    batcher.close()

or synchronously for a burst of concurrent requests::

    results = batcher.score_many(reqs)

A single worker thread drains the queue: the first waiting request opens a
batch, then the worker lingers up to ``linger_ms`` (or until ``max_batch``
candidate rows / ``max_coalesce`` requests are waiting) collecting
co-arriving requests before handing the group to the engine. Coalesced
scores are bit-identical to per-request ``engine.score`` — both run the
same row-wise executable family.

**Continuous dispatch** (``continuous=True``, the default) — instead of
blocking on each group's results before touching the queue again
(lockstep), the worker launches a group via the engine's two-phase API
(``begin_coalesced``) and immediately returns to the queue: group k+1 is
formed, packed into its own transfer buffers, and launched while
group k still executes on device, up to ``max_inflight`` outstanding
groups; finished groups are harvested the moment their device results
are ready (non-blocking ``engine.poll``), so overlap never inflates a
completed request's latency. Stage 2 runs back-to-back with zero idle
whenever work is queued.
Groups are launched AND collected in formation order, so results, counters
and dispatch order are identical to lockstep — the loop changes *when*
packs launch, never *what* they compute (a group needing a device-table
write while older groups are in flight triggers the engine's
copy-on-write generation fork, never a pipeline stall). An engine
without ``begin_coalesced`` falls back to lockstep transparently.

**SLO classes** — ``submit(req, slo="deadline", deadline_ms=...)`` marks a
request latency-critical: deadline requests jump the FIFO (the queue is
priority-ordered, FIFO within each class) and shrink the linger window —
a group opened by (or joined by) a deadline request lingers only
``linger_ms * deadline_linger_frac``, further capped by the request's
remaining deadline budget, so a latency-critical arrival never waits out a
full best-effort linger behind older bulk traffic.

**Admission control** (``admission=True``) — the overload valve upstream
of the priority queue. At submit time, under the queue lock:

* a ``best_effort`` request arriving at queue depth >=
  ``shed_queue_depth`` is SHED: its future fails immediately with a typed
  ``AdmissionError`` (fail fast — never queued, never hung);
* a ``best_effort`` request arriving at queue depth >=
  ``degrade_queue_depth`` is DEGRADED: its candidate pool is truncated to
  the first ``ceil(n * degrade_frac)`` rows (results carry
  ``degraded=True``) — less device work per admitted request, so the
  queue drains faster without dropping users entirely;
* a ``deadline`` request is NEVER shed by queue depth — only when its own
  ``deadline_ms`` budget is already below ``deadline_headroom_ms`` (an
  infeasible deadline: shedding immediately beats returning a late
  answer).

So under overload, best-effort work is degraded first and shed second,
while the deadline class keeps its strict queue priority — the counters
``shed_requests`` / ``shed_best_effort`` / ``shed_deadline`` /
``degraded_requests`` (surfaced by ``RankingService.stats()``) are the
overload alarm. Without admission control the priority is strict and
unbounded: a workload whose deadline-class arrival rate alone saturates
the worker starves queued best-effort requests for as long as the
saturation lasts — that is the intended contract (``deadline_requests /
requests`` is the counter to alarm on).

**Self-healing** (``retries > 0``) — a group whose launch or collect
fails with a retryable error does not fail its waiters outright: each
member is retried individually (``engine.score_coalesced([req])``) with
exponential backoff + jitter, every attempt bounded by the request's
remaining deadline budget — a retry whose backoff would overrun the
deadline stops immediately and the future resolves with a typed
``RetryExhausted`` carrying the last error as ``__cause__``. Typed
refusals (``AdmissionError``, ``BatcherClosedError``) are never retried.

**Worker supervision** — the dispatch loop runs under a supervisor on
the worker thread: an escaped exception (e.g. an injected
``worker_loop`` fault) is a *worker crash*, not a hang. The supervisor
fails-or-retries every request the crashed loop was holding (the group
being formed), collects every in-flight group, and restarts the
dispatch loop on the same thread (``worker_crashes`` /
``worker_respawns`` count the events). An admitted future therefore
always resolves — with a result, a typed error, or a retry outcome —
and ``close()`` semantics are unchanged.

``close()`` drains: every admitted request still queued is scored (with
zero linger) and every in-flight group collected before the worker exits,
so no accepted future is ever abandoned. Anything left after a worker
death or join timeout is failed with ``BatcherClosedError``.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import random
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Sequence

from repro.ft.recovery import RetryPolicy
from repro.obs.metrics import MetricsRegistry
# The error taxonomy lives in repro.serve.errors; AdmissionError and
# BatcherClosedError were defined here historically and are re-exported
# for back-compat (`from repro.serve.batcher import AdmissionError`).
from repro.serve.errors import (  # noqa: F401
    AdmissionError,
    BatcherClosedError,
    RetryExhausted,
    WorkerCrashedError,
)
from repro.serve.engine import ServeRequest, ServeResult, ServingEngine

SLO_BEST_EFFORT = "best_effort"
SLO_DEADLINE = "deadline"
_PRIO = {SLO_DEADLINE: 0, SLO_BEST_EFFORT: 1}


@dataclasses.dataclass(order=True)
class _Item:
    """Priority-queue entry: deadline class first, FIFO within a class."""
    prio: int
    seq: int
    req: ServeRequest | None = dataclasses.field(compare=False, default=None)
    fut: Future | None = dataclasses.field(compare=False, default=None)
    deadline_at: float | None = dataclasses.field(compare=False, default=None)
    submitted_at: float | None = dataclasses.field(compare=False,
                                                   default=None)
    degraded: bool = dataclasses.field(compare=False, default=False)


class CoalescingBatcher:
    def __init__(self, engine: ServingEngine, *, linger_ms: float = 2.0,
                 max_coalesce: int = 64, auto_start: bool = True,
                 deadline_linger_frac: float = 0.25,
                 continuous: bool = True, max_inflight: int = 2,
                 admission: bool = False,
                 shed_queue_depth: int | None = None,
                 degrade_queue_depth: int | None = None,
                 degrade_frac: float = 0.5,
                 deadline_headroom_ms: float = 0.0,
                 retries: int = 0,
                 retry_backoff_ms: float = 1.0,
                 retry_jitter: float = 0.5,
                 retry_seed: int = 0):
        if getattr(engine, "_multiproc", False):
            # same hazard class as hedging under SPMD: each process's
            # batcher thread would form groups from its own wall-clock
            # linger/scheduling, so dispatch sequences (and collective
            # schedules) diverge across workers and the fleet deadlocks.
            # Multi-process serving drives score_coalesced directly in
            # lockstep (repro.dist.runner).
            raise ValueError(
                "CoalescingBatcher cannot wrap a multi-process sharded "
                "engine: group formation is timing-dependent and would "
                "desynchronize the SPMD collective schedule")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.engine = engine
        self.linger_ms = linger_ms
        self.max_coalesce = max_coalesce
        self.deadline_linger_frac = deadline_linger_frac
        self.continuous = continuous
        self.max_inflight = max_inflight
        self.admission = admission
        self.shed_queue_depth = shed_queue_depth
        self.degrade_queue_depth = degrade_queue_depth
        self.degrade_frac = degrade_frac
        self.deadline_headroom_ms = deadline_headroom_ms
        self.retries = retries
        self._retry_policy = RetryPolicy(retries=retries,
                                         backoff_ms=retry_backoff_ms,
                                         jitter=retry_jitter)
        self._retry_rng = random.Random(retry_seed)
        # the engine's fault injector (None in production): the batcher
        # owns exactly one site — worker_loop, poked at group formation —
        # so chaos schedules can kill the dispatch loop deterministically
        self._injector = getattr(engine, "fault_injector", None)
        self._q: queue.PriorityQueue = queue.PriorityQueue()
        self._seq = 0
        self._stop = threading.Event()
        self._lock = threading.Lock()     # serializes submit vs close
        self._worker: threading.Thread | None = None
        # worker-loop state held at instance level so the crash supervisor
        # can see exactly what the dispatch loop was holding when it died
        self._inflight: deque = deque()   # (claimed items, handle), FIFO
        self._forming: list = []          # dequeued, not yet launched
        self._queued = 0              # admitted, not yet claimed by the worker
        self.batches = 0              # engine handoffs
        self.coalesced_requests = 0   # requests scored in a >1-request group
        self.requests = 0
        self.deadline_requests = 0    # submitted with the deadline SLO
        self.shed_requests = 0        # failed fast by admission control
        self.shed_best_effort = 0     # ... of the best_effort class
        self.shed_deadline = 0        # ... of the deadline class (infeasible)
        self.degraded_requests = 0    # admitted with a truncated pool
        self.retries_attempted = 0    # individual re-scores after a failure
        self.retries_exhausted = 0    # requests failed after all retries
        self.worker_crashes = 0       # dispatch-loop escapes caught
        self.worker_respawns = 0      # dispatch-loop restarts (same thread)
        # observability (repro.obs): the engine's tracer (None when
        # plan.obs.trace is off) and metrics registry. Queue wait and
        # request latency are recorded as log-bucketed histograms —
        # Histogram.record is locked, so the worker's observes and
        # stats() reads can no longer race (the old cumulative
        # queue_wait_ms was an unlocked float mutated on the worker
        # thread and read bare by RankingService.stats()); a private
        # registry keeps the histograms alive when engine metrics are
        # off, so the queue_wait_ms compat property always works.
        self.tracer = getattr(engine, "tracer", None)
        self.metrics = getattr(engine, "metrics", None) or MetricsRegistry()
        self.queue_wait = self.metrics.histogram("queue_wait_ms")
        self.request_latency = self.metrics.histogram("request_latency_ms")
        for name in ("requests", "batches", "coalesced_requests",
                     "deadline_requests", "shed_requests",
                     "shed_best_effort", "shed_deadline",
                     "degraded_requests", "retries_attempted",
                     "retries_exhausted", "worker_crashes",
                     "worker_respawns"):
            self.metrics.gauge(name, lambda n=name: getattr(self, n))
        if auto_start:
            self.start()

    @property
    def queue_wait_ms(self) -> float:
        """Cumulative submit->handoff wait — the queueing share of
        end-to-end latency that the engine's StageProfiler cannot see.
        Kept for compat as the derived total of the ``queue_wait_ms``
        histogram (which carries the p50/p99 tail the total hides)."""
        return self.queue_wait.total

    @classmethod
    def from_plan(cls, engine: ServingEngine, batch, ft=None,
                  *, auto_start: bool = True) -> "CoalescingBatcher":
        """Build a batcher from a ``BatchPlan`` (the ``ServePlan`` spine's
        batch section) — the one wiring every entry point shares. The
        optional ``ft`` (the plan's ``FaultPlan`` section) carries the
        retry knobs; omitted, retries are off."""
        kw: dict = {}
        if ft is not None:
            kw = dict(retries=ft.retries,
                      retry_backoff_ms=ft.retry_backoff_ms,
                      retry_jitter=ft.retry_jitter,
                      retry_seed=ft.seed)
        return cls(engine, linger_ms=batch.linger_ms,
                   max_coalesce=batch.max_coalesce,
                   deadline_linger_frac=batch.deadline_linger_frac,
                   continuous=batch.continuous,
                   max_inflight=batch.max_inflight,
                   admission=batch.admission,
                   shed_queue_depth=batch.shed_queue_depth,
                   degrade_queue_depth=batch.degrade_queue_depth,
                   degrade_frac=batch.degrade_frac,
                   deadline_headroom_ms=batch.deadline_headroom_ms,
                   auto_start=auto_start, **kw)

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="coalescing-batcher", daemon=True)
        self._worker.start()

    def close(self, timeout: float = 60.0) -> None:
        """Stop the worker AFTER the queue drains: every admitted request
        is still scored (with zero linger) and every in-flight group
        collected. Only requests stranded by a dead or hung worker are
        failed — with ``BatcherClosedError``, so no waiter blocks
        forever."""
        with self._lock:              # no submit can interleave past here
            self._stop.set()
            self._q.put(_Item(prio=2, seq=self._next_seq()))  # wake worker
        if self._worker is not None:
            self._worker.join(timeout=timeout)
            self._worker = None
        # backstop only: with a live worker the drain loop above has
        # emptied the queue before exiting
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if (item.fut is not None
                    and item.fut.set_running_or_notify_cancel()):
                item.fut.set_exception(
                    BatcherClosedError("batcher closed before this request "
                                       "was scored"))

    def __enter__(self) -> "CoalescingBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------------
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _shed(self, fut: Future, slo: str, reason: str) -> Future:
        self.shed_requests += 1
        if slo == SLO_DEADLINE:
            self.shed_deadline += 1
        else:
            self.shed_best_effort += 1
        if self.tracer is not None:
            self.tracer.instant("admission_shed", slo=slo,
                                depth=self._queued, reason=reason)
        # claim-then-fail: the waiter sees the typed error immediately —
        # a shed future must never hang
        fut.set_running_or_notify_cancel()
        fut.set_exception(AdmissionError(
            f"request shed by admission control: {reason}",
            slo=slo, queue_depth=self._queued))
        return fut

    def _degrade(self, req: ServeRequest) -> ServeRequest | None:
        n = self._candidate_rows(req)
        keep = max(1, math.ceil(n * self.degrade_frac))
        if keep >= n:
            return None
        return dataclasses.replace(
            req, candidate_feeds={k: v[:keep]
                                  for k, v in req.candidate_feeds.items()})

    def submit(self, req: ServeRequest, *, slo: str = SLO_BEST_EFFORT,
               deadline_ms: float | None = None) -> "Future[ServeResult]":
        """Enqueue a request; resolves once its group has been scored.

        ``slo="deadline"`` marks it latency-critical: it jumps ahead of
        queued best-effort requests and shrinks its group's linger.
        ``deadline_ms`` (optional, implies the deadline class) additionally
        caps the linger by the remaining budget.

        With ``admission=True`` an overloaded queue sheds (typed
        ``AdmissionError``, failed fast) or degrades (truncated candidate
        pool) best-effort work per the class docstring; the returned
        future always resolves either way.
        """
        if deadline_ms is not None:
            slo = SLO_DEADLINE
        if slo not in _PRIO:
            raise ValueError(f"unknown SLO class {slo!r}")
        with self._lock:              # atomic vs the close() shutdown decision
            if (self._stop.is_set() or self._worker is None
                    or not self._worker.is_alive()):
                raise RuntimeError("batcher is not running (call start())")
            fut: Future = Future()
            self.requests += 1
            if slo == SLO_DEADLINE:
                self.deadline_requests += 1
            degraded = False
            if self.admission:
                if slo == SLO_DEADLINE:
                    # deadline work is never shed by depth — only when its
                    # own budget is already infeasible (a late answer is
                    # worth less than an immediate, typed refusal)
                    if (deadline_ms is not None
                            and deadline_ms < self.deadline_headroom_ms):
                        return self._shed(
                            fut, slo,
                            f"deadline budget {deadline_ms:g}ms is below "
                            f"the {self.deadline_headroom_ms:g}ms headroom "
                            f"floor")
                else:
                    if (self.shed_queue_depth is not None
                            and self._queued >= self.shed_queue_depth):
                        return self._shed(
                            fut, slo,
                            f"queue depth {self._queued} >= shed threshold "
                            f"{self.shed_queue_depth} (best_effort)")
                    if (self.degrade_queue_depth is not None
                            and self._queued >= self.degrade_queue_depth):
                        slim = self._degrade(req)
                        if slim is not None:
                            req = slim
                            degraded = True
                            self.degraded_requests += 1
                            if self.tracer is not None:
                                self.tracer.instant(
                                    "admission_degrade",
                                    depth=self._queued, user=req.user_id)
            now = time.perf_counter()
            deadline_at = (now + deadline_ms / 1e3
                           if deadline_ms is not None else None)
            self._queued += 1
            seq = self._next_seq()
            if self.tracer is not None and self.tracer.sampled(seq):
                # req=seq is the request's trace identity: queue_claim /
                # group_launch / resolve events carry the same seq, and
                # group_launch links it to the engine's group id
                self.tracer.instant("submit", req=seq, slo=slo,
                                    user=req.user_id, degraded=degraded)
            self._q.put(_Item(prio=_PRIO[slo], seq=seq,
                              req=req, fut=fut, deadline_at=deadline_at,
                              submitted_at=now, degraded=degraded))
        return fut

    def score_many(self, reqs: Sequence[ServeRequest],
                   slo: str = SLO_BEST_EFFORT) -> list[ServeResult]:
        """Submit a burst of concurrent requests; wait for all results."""
        futs = [self.submit(r, slo=slo) for r in reqs]
        return [f.result() for f in futs]

    # -- worker -------------------------------------------------------------
    def _candidate_rows(self, req: ServeRequest) -> int:
        return next(iter(req.candidate_feeds.values())).shape[0]

    def _linger_until(self, item: _Item, now: float) -> float:
        """Group-close time implied by one member: full linger for
        best-effort, the shrunken deadline linger (further capped by the
        request's remaining budget) for deadline-class requests."""
        if item.prio == _PRIO[SLO_DEADLINE]:
            until = now + self.linger_ms * self.deadline_linger_frac / 1e3
            if item.deadline_at is not None:
                until = min(until, item.deadline_at)
            return until
        return now + self.linger_ms / 1e3

    def _run(self) -> None:
        """Worker-thread entry: a supervisor around the dispatch loop.

        An exception escaping ``_run_loop`` is a *worker crash*. The
        supervisor resolves everything the dead loop was holding — the
        group being formed is failed-or-retried with a typed
        ``WorkerCrashedError``, every in-flight group is collected — then
        restarts the dispatch loop on this same thread. No admitted
        future ever rides a dead loop.
        """
        stop_crashes = 0
        while True:
            try:
                self._run_loop()
                return                # clean exit: stop set, queue drained
            except BaseException as e:
                self.worker_crashes += 1
                if self.tracer is not None:
                    self.tracer.instant("worker_crash",
                                        error=type(e).__name__)
                self._on_worker_crash(e)
                if self._stop.is_set():
                    # crash-looping during drain: give up after a few
                    # restarts — close()'s backstop fails the remainder
                    # with a typed BatcherClosedError (typed, not hung)
                    stop_crashes += 1
                    if stop_crashes >= 3:
                        return
                self.worker_respawns += 1
                if self.tracer is not None:
                    self.tracer.instant("worker_respawn",
                                        respawns=self.worker_respawns)

    def _on_worker_crash(self, exc: BaseException) -> None:
        """Resolve everything the dead dispatch loop was holding."""
        forming, self._forming = self._forming, []
        if forming:
            err = WorkerCrashedError(
                f"batcher worker crashed during group formation: "
                f"{type(exc).__name__}: {exc}")
            err.__cause__ = exc
            self._fail_or_retry(forming, err)
        while self._inflight:
            self._collect_one(self._inflight)

    def _run_loop(self) -> None:
        """The dispatch loop.

        Continuous mode keeps up to ``max_inflight`` launched groups
        outstanding: with work queued, the next group is formed and
        launched (host-side packing into per-pack transfer buffers)
        while the previous group still executes on device — stage 2 never
        waits on the host. Groups are collected oldest-first: eagerly as
        soon as their results are ready (``_harvest``), or blocking when
        the queue momentarily empties / the in-flight budget is reached. Lockstep
        mode (``continuous=False``, or an engine without the two-phase
        API) scores each group to completion before the next.

        On ``close()`` the loop drains: remaining queued requests are
        scored with zero linger and all in-flight groups collected before
        the thread exits — an admitted future is never abandoned.
        """
        inflight = self._inflight     # (claimed items, engine handle), FIFO
        continuous = (self.continuous
                      and hasattr(self.engine, "begin_coalesced"))
        prof = getattr(self.engine, "profiler", None)
        while True:
            t_idle = None
            try:
                if inflight:
                    try:
                        item = self._q.get_nowait()
                    except queue.Empty:
                        # queue momentarily dry: harvest the oldest group
                        # (device time, not idle time)
                        self._collect_one(inflight)
                        continue
                else:
                    t_idle = time.perf_counter()
                    item = self._q.get(timeout=0.05)
            except queue.Empty:
                if prof is not None:
                    prof.add("queue_idle", time.perf_counter() - t_idle)
                if self._stop.is_set():
                    return
                continue
            if t_idle is not None and prof is not None:
                # partial wait before this arrival: nothing was in flight,
                # so the device sat idle for it
                idle = time.perf_counter() - t_idle
                if idle > 1e-4:
                    prof.add("queue_idle", idle)
            if item.req is None:      # wake marker (close() or stale)
                continue
            group = self._form_group(item, inflight)
            try:
                self._launch_group(group, inflight, continuous, prof)
            finally:
                # launched (or resolved): the crash supervisor no longer
                # owns these items
                self._forming = []
            while len(inflight) >= self.max_inflight:
                self._collect_one(inflight)
            self._harvest(inflight)

    def _form_group(self, item: _Item, inflight: deque) -> list[_Item]:
        with self._lock:
            self._queued -= 1
        # crash-visible formation state: if the loop dies past this line,
        # the supervisor owns every item in the list and resolves it
        group = self._forming = [item]
        if self._injector is not None:
            self._injector.poke("worker_loop", req=item.seq)
        rows = self._candidate_rows(item.req)
        # draining after close(): no linger — ship everything, fast
        deadline = (time.perf_counter() if self._stop.is_set()
                    else self._linger_until(item, time.perf_counter()))
        while (len(group) < self.max_coalesce
               and rows < self.engine.max_batch):
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            if inflight:
                # linger in short slices so a previous group whose device
                # results finish MID-linger is harvested immediately — its
                # waiters must not sit out this group's window
                self._harvest(inflight)
                timeout = min(timeout, 5e-4)
            try:
                nxt = self._q.get(timeout=timeout)
            except queue.Empty:
                continue
            if nxt.req is None:
                continue
            with self._lock:
                self._queued -= 1
            group.append(nxt)
            rows += self._candidate_rows(nxt.req)
            # a deadline request joining an open group truncates the
            # remaining linger to its own (shrunken) window
            deadline = min(deadline,
                           self._linger_until(nxt, time.perf_counter()))
        return group

    def _launch_group(self, group: list[_Item], inflight: deque,
                      continuous: bool, prof) -> None:
        # claim each future before doing work: a waiter that cancelled while
        # its request sat queued is dropped here, and a claimed (RUNNING)
        # future can no longer be cancelled — so set_result below cannot
        # race a cancel and kill the worker with InvalidStateError
        now = time.perf_counter()
        trc = self.tracer
        for it in group:
            if it.submitted_at is None:
                continue
            wait_ms = (now - it.submitted_at) * 1e3
            self.queue_wait.record(wait_ms)
            if trc is not None and trc.sampled(it.seq):
                trc.instant("queue_claim", req=it.seq,
                            wait_ms=round(wait_ms, 3))
        claimed = [it for it in group
                   if it.fut.set_running_or_notify_cancel()]
        if not claimed:
            return
        reqs = [it.req for it in claimed]
        if not continuous:
            if trc is not None:
                trc.instant("group_launch",
                            reqs=[it.seq for it in claimed])
            try:
                results = self.engine.score_coalesced(reqs)
            except BaseException as e:      # propagate to every waiter
                self._fail_or_retry(claimed, e)
                return
            self._resolve(claimed, results)
            return
        overlapped = bool(inflight)
        t0 = time.perf_counter()
        try:
            handle = self.engine.begin_coalesced(reqs)
        except BaseException as e:
            self._fail_or_retry(claimed, e)
            return
        if trc is not None:
            # request -> group linkage: each member seq joins the engine
            # group id the two-phase API assigned this launch
            trc.instant("group_launch", group=getattr(handle, "gid", None),
                        reqs=[it.seq for it in claimed],
                        overlapped=overlapped)
        if overlapped and prof is not None:
            # host work done UNDER a still-executing previous group — the
            # time the continuous loop hides beneath device compute
            prof.add("overlap", time.perf_counter() - t0)
        inflight.append((claimed, handle))

    def _harvest(self, inflight: deque) -> None:
        """Collect (oldest-first) every in-flight group whose device
        results are already materialized — non-blocking, via the engine's
        ``poll``. Keeps result latency flat at low load, where groups
        finish long before the in-flight budget forces a collect."""
        poll = getattr(self.engine, "poll", None)
        while inflight and poll is not None and poll(inflight[0][1]):
            self._collect_one(inflight)

    def _collect_one(self, inflight: deque) -> None:
        claimed, handle = inflight.popleft()
        try:
            results = self.engine.collect(handle)
        except BaseException as e:
            self._fail_or_retry(claimed, e)
            return
        self._resolve(claimed, results)

    # -- failure resolution and retry ---------------------------------------
    def _fail_or_retry(self, items: list[_Item],
                       exc: BaseException) -> None:
        """Resolve each item after a failure: typed refusals (and
        already-exhausted retries) fail the future immediately; anything
        else is re-scored per request when retries are configured. Every
        future resolves one way or the other — none hang."""
        retryable = (self.retries > 0
                     and not isinstance(exc, (AdmissionError,
                                              BatcherClosedError,
                                              RetryExhausted)))
        for it in items:
            if it.fut.done():
                continue
            if (not it.fut.running()
                    and not it.fut.set_running_or_notify_cancel()):
                continue          # cancelled while queued / forming
            if not retryable:
                it.fut.set_exception(exc)
                continue
            self._retry_one(it, exc)

    def _retry_one(self, it: _Item, first_exc: BaseException) -> None:
        """Re-score one request with exponential backoff + jitter, every
        attempt bounded by the request's remaining deadline budget — a
        backoff that would overrun the deadline stops the retry loop.
        Resolves the future with a result or a typed ``RetryExhausted``
        carrying the last error as ``__cause__``."""
        trc = self.tracer
        last = first_exc
        attempts = 0
        for attempt in range(self.retries):
            delay_s = self._retry_policy.backoff_s(attempt,
                                                   rng=self._retry_rng)
            if (it.deadline_at is not None
                    and it.deadline_at - time.perf_counter() <= delay_s):
                break             # remaining budget cannot cover the wait
            if delay_s > 0:
                time.sleep(delay_s)
            attempts += 1
            self.retries_attempted += 1
            if trc is not None:
                trc.instant("retry", req=it.seq, attempt=attempts,
                            error=type(last).__name__)
            try:
                res = self.engine.score_coalesced([it.req])[0]
            except (AdmissionError, BatcherClosedError) as e:
                last = e
                break             # typed refusal: retrying cannot help
            except BaseException as e:
                last = e
                continue
            if it.degraded:
                res.degraded = True
            if it.submitted_at is not None:
                self.request_latency.record(
                    (time.perf_counter() - it.submitted_at) * 1e3)
            if trc is not None and trc.sampled(it.seq):
                trc.instant("resolve", req=it.seq, retried=attempts)
            it.fut.set_result(res)
            return
        self.retries_exhausted += 1
        if trc is not None:
            trc.instant("retry_exhausted", req=it.seq, attempts=attempts,
                        error=type(last).__name__)
        err = RetryExhausted(
            f"request failed after {attempts} retry attempt(s): "
            f"{type(last).__name__}: {last}", attempts=attempts)
        err.__cause__ = last
        it.fut.set_exception(err)

    def _resolve(self, claimed: list[_Item], results) -> None:
        self.batches += 1
        if len(claimed) > 1:
            self.coalesced_requests += len(claimed)
        now = time.perf_counter()
        trc = self.tracer
        for it, res in zip(claimed, results):
            if it.degraded:
                res.degraded = True
            if it.submitted_at is not None:
                self.request_latency.record((now - it.submitted_at) * 1e3)
            if trc is not None and trc.sampled(it.seq):
                trc.instant("resolve", req=it.seq)
            it.fut.set_result(res)
