"""Typed error taxonomy for the serving runtime.

One hierarchy rooted at ``ServeError`` so callers can catch the whole
serving failure surface with a single except clause, or pick off the
specific failure class they can handle:

* ``AdmissionError``     — request shed by the SLO admission controller
  (fails fast at ``submit``, never reaches the engine);
* ``BatcherClosedError`` — request stranded by ``close()`` (the drain
  backstop: never silently abandoned);
* ``FaultInjected``      — a deterministic fault fired at a named
  injection site (``repro.ft.faults``), or injected corruption was
  detected at collect;
* ``RetryExhausted``     — every retry attempt failed or the request's
  remaining deadline budget could not fund another backoff sleep; the
  last underlying failure rides on ``__cause__``;
* ``CircuitOpenError``   — the stage-2 circuit breaker is open and the
  guarded fast path refused the call (the engine normally routes around
  this via the re-stacking fallback rather than surfacing it);
* ``WorkerCrashedError`` — the batcher worker thread died mid-flight;
  the supervisor resolves every affected future with this (or retries
  it) and respawns the loop.

``AdmissionError`` and ``BatcherClosedError`` predate this module and
remain importable from ``repro.serve.batcher`` (back-compat re-exports).
This module is stdlib-only — ``repro.ft`` imports it lazily so fault
primitives stay importable without jax.
"""
from __future__ import annotations

__all__ = [
    "ServeError",
    "AdmissionError",
    "BatcherClosedError",
    "FaultInjected",
    "RetryExhausted",
    "CircuitOpenError",
    "WorkerCrashedError",
]


class ServeError(RuntimeError):
    """Base class for every typed serving-runtime failure."""


class AdmissionError(ServeError):
    """Request shed by the admission controller (never scored).

    Carries the SLO class and the queue depth at shed time so callers
    can distinguish load shedding from infeasible deadlines.
    """

    def __init__(self, msg: str, *, slo: str = "best_effort",
                 queue_depth: int = 0):
        super().__init__(msg)
        self.slo = slo
        self.queue_depth = queue_depth


class BatcherClosedError(ServeError):
    """Request stranded by ``close()``: the batcher shut down before it
    could be scored."""


class FaultInjected(ServeError):
    """A deterministic fault fired at a named injection site."""

    def __init__(self, msg: str, *, site: str | None = None):
        super().__init__(msg)
        self.site = site


class RetryExhausted(ServeError):
    """All retry attempts failed, or the deadline budget ran out.

    The last underlying failure is chained on ``__cause__``.
    """

    def __init__(self, msg: str, *, attempts: int = 0):
        super().__init__(msg)
        self.attempts = attempts


class CircuitOpenError(ServeError):
    """The circuit breaker is open: the guarded path refused the call."""


class WorkerCrashedError(ServeError):
    """The batcher worker thread died while this request was in flight
    or queued; the supervisor resolved the future instead of hanging it."""
