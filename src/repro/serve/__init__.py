"""Serving runtime for ranking graphs — the inference workflow of Fig. 2
grown into an async, multi-user subsystem:

* ``engine``  — ``ServingEngine``: per-request orchestration. Stage 1 (the
  user-only precompute subgraph of ``repro.core.split``) runs once per
  (user, feature_version) and its outputs are cached; stage 2 (the batched
  residual) is ONE row-wise executable family — each candidate row gathers
  its own user's cached reps via a per-row user index — so a single request
  (U=1) and a cross-user coalesced batch run the same code and produce
  bit-identical scores. Options: fused Pallas ``mari_dense`` dispatch
  (optionally with the kernel-side user-rep gather), build-time
  grouped-weight pre-concatenation, and candidate-axis sharding on the
  ``repro.dist`` 'cand' mesh — single-process ``jax.sharding`` or SPMD
  across ``jax.distributed`` worker processes (rep tables replicated,
  shard-aligned buckets, optional int8-compressed score gather).
* ``batcher`` — ``CoalescingBatcher``: async request queue that packs
  candidate chunks from different users into shared power-of-two stage-2
  buckets (cross-user batching), with SLO classes — deadline-tagged
  requests jump the FIFO and shrink the linger window. Dispatch is a
  continuous loop: group k+1 is formed and launched (two-phase engine
  API) while group k executes on device, and SLO-tiered admission
  control sheds (typed ``AdmissionError``) or degrades best_effort work
  before deadline work under overload.
* ``cache``   — ``UserRepCache``: bounded LRU user-representation store
  with eviction accounting, removal listeners, byte accounting and
  per-user invalidation; ``DeviceRepStore``: the slot-allocated
  device-resident tier over it — one live (capacity, ...) device table
  per stage-2 boundary, donated single-row writes, slot recycling — so
  the coalesced hot path feeds persistent tables + per-row slot indices
  instead of re-stacking reps every call (``CachePlan.device_resident``).
* ``profile`` — ``StageProfiler``: per-phase wall-clock taxonomy of the
  hot path (stage1/pack/dispatch/device/unpack, plus the loop-level
  queue_idle/overlap phases), threaded through the engine and surfaced
  by ``RankingService.stats()`` and the serve bench's breakdown rows.
* ``hedging`` — ``HedgePolicy`` (rolling-p99 decision) + ``HedgedRunner``
  (real duplicate execution of straggling chunks, first result wins).
* ``plan``    — ``ServePlan``: the frozen, validated, JSON-serializable
  serving configuration (nested Graph/Kernel/Batch/Shard/Cache sections,
  cross-field validation with a documented resolution table, named
  presets) — the config spine every entry point shares.
* ``service`` — ``RankingService``: multi-scenario router hosting several
  registry models behind one ``submit(scenario, request)`` API, with a
  shared rep-cache budget across scenario engines.
* ``errors``  — the serving error taxonomy (``ServeError`` and its typed
  subclasses), stdlib-only so fault specs and recovery policies import
  without the JAX stack.

Fault tolerance rides the plan spine as well (``FaultPlan``, the ``ft``
section): deterministic fault injection at named sites
(``repro.ft.FaultInjector``), per-request retries with
deadline-budgeted backoff, a circuit breaker on stage-2 device-tier
dispatch that routes packs through the bit-identical re-stacking
fallback while open, device-tier quarantine on failed donated writes,
and batcher worker supervision — see ``serve/README.md`` § Failure
handling.

The memory hierarchy rides the plan spine as the ``mem`` section
(``MemPlan``, backed by ``repro.mem``): ``mem__cold_tier=True`` adds a
byte-budgeted host-RAM cold arena UNDER the hot LRU — evictions demote
into it instead of discarding, a hot miss with a cold hit serves from
one arena read (no stage-1 recompute, no device slot), an async worker
promotes only users touched ``promote_touches`` times within
``promote_window_s`` back to hot, and ``ServingEngine.warm`` /
``RankingService.warm`` bulk-precompute reps straight into the arena —
see ``serve/README.md`` § Memory hierarchy.

Observability rides the plan spine too (``ObsPlan``): ``obs__trace=True``
threads a ``repro.obs.Tracer`` through engine/batcher/cache (request and
group timelines, exported to Perfetto via ``repro.obs.export``), and
``obs__metrics`` (on by default) backs ``RankingService.stats()``'s
p50/p99 request-latency and queue-wait histograms.
"""
from repro.serve.batcher import (  # noqa: F401
    SLO_BEST_EFFORT,
    SLO_DEADLINE,
    CoalescingBatcher,
)
from repro.serve.cache import DeviceRepStore, UserRepCache  # noqa: F401
from repro.serve.errors import (  # noqa: F401
    AdmissionError,
    BatcherClosedError,
    CircuitOpenError,
    FaultInjected,
    RetryExhausted,
    ServeError,
    WorkerCrashedError,
)
from repro.serve.engine import (  # noqa: F401
    ServeRequest,
    ServeResult,
    ServingEngine,
)
from repro.serve.hedging import HedgedRunner, HedgePolicy  # noqa: F401
from repro.serve.profile import StageProfiler  # noqa: F401
from repro.serve.plan import (  # noqa: F401
    PRESETS,
    BatchPlan,
    CachePlan,
    FaultPlan,
    GraphPlan,
    KernelPlan,
    MemPlan,
    ObsPlan,
    PlanError,
    PlanResolutionWarning,
    ServePlan,
    ShardPlan,
)
from repro.serve.service import RankingService  # noqa: F401
