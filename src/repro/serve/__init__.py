from repro.serve.engine import ServingEngine, ServeRequest, ServeResult  # noqa: F401
