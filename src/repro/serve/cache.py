"""Bounded user-representation store for the serving runtime.

Stage-1 outputs (user activations + per-``mari_dense`` partials +
decomposed-attention one-shot tensors) are cached per
``(user_id, feature_version)`` so repeat users skip the user tower. The
seed engine kept these in an unbounded dict — at "millions of users" scale
that is an OOM, not a cache. ``UserRepCache`` is the replacement:

* **LRU bound** — ``max_users`` caps live entries; inserting past the cap
  evicts the least-recently-*scored* user and bumps ``evictions`` (surfaced
  on the engine for capacity monitoring).
* **version supersede** — one live entry per user: putting a new
  ``feature_version`` frees every older version of that user immediately
  (feature updates must not accumulate stale representations).
* **invalidation** — ``invalidate_user`` drops all versions of a user
  (logout, feature backfill, GDPR delete).
* **thread safety** — the async batcher's worker thread and callers of
  ``ServingEngine.score`` touch the cache concurrently; every mutation is
  taken under one lock.
* **removal listeners** — ``subscribe`` registers callbacks fired whenever
  a user's entry leaves the cache for ANY reason (LRU eviction, version
  supersede, invalidation, clear). The device tier below uses this to
  recycle its slots in lockstep with the host tier;
  ``subscribe_removal`` delivers the full removal record
  ``(user_id, version, reps, reason)`` — the cold tier (``repro.mem``)
  uses it to demote evicted reps instead of discarding them. Listener
  snapshots are taken under the SAME lock acquisition as the mutation
  and callbacks fire strictly after release: listeners are free to take
  their own locks (the cold-tier arena lock, the device-store lock)
  without any lock-order inversion against the cache lock.

``DeviceRepStore`` is the *device tier*: instead of re-stacking cached
per-user rows into a fresh ``(U, ...)`` table on every bucket dispatch
(a ``jnp.concatenate`` per boundary per call — the dominant host cost the
benchmarks exposed), it holds ONE persistent stacked ``(capacity, ...)``
jax array per boundary and writes a single row per new user via a donated
``.at[slot].set`` update. Stage 2 then consumes the persistent tables with
per-row *slot indices*; freeing a user merely recycles its slot integer —
the stale row stays in the table but is never referenced, and the
engine's ``mode="clip"`` gathers make even an out-of-range index safe.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Mapping, Sequence

Key = tuple[Hashable, Hashable]          # (user_id, feature_version)

# removal reasons delivered to subscribe_removal listeners
EVICT = "evict"            # LRU-bound eviction (reps still valid: demotable)
SUPERSEDE = "supersede"    # newer feature_version replaced the entry
INVALIDATE = "invalidate"  # explicit invalidate_user (GDPR/logout/backfill)
CLEAR = "clear"            # cache.clear()

# one removal: (user_id, feature_version, reps, reason)
Removal = tuple[Hashable, Hashable, Mapping[str, Any], str]


def _reps_nbytes(reps: Mapping[str, Any]) -> dict[str, int]:
    """Per-boundary byte sizes of one user's rep pytree (best effort)."""
    out = {}
    for k, v in reps.items():
        out[k] = int(getattr(v, "nbytes", 0))
    return out


class UserRepCache:
    """LRU mapping (user_id, feature_version) -> stage-1 output pytree.

    Stored keyed by user_id with the live version alongside, so the
    one-live-entry-per-user invariant costs O(1) per insert — a key scan
    per put would be O(cache size) and melt under miss traffic at the
    intended scale.
    """

    def __init__(self, max_users: int | None = None):
        if max_users is not None and max_users < 1:
            raise ValueError(f"max_users must be >= 1, got {max_users}")
        self.max_users = max_users
        # user_id -> (feature_version, reps); insertion order == LRU order
        self._entries: OrderedDict[
            Hashable, tuple[Hashable, Mapping[str, Any]]] = OrderedDict()
        self._lock = threading.Lock()
        self.evictions = 0               # LRU-bound evictions only
        self.hits = 0
        self.misses = 0
        self._listeners: list[Callable[[Hashable], None]] = []
        self._removal_listeners: list[Callable[..., None]] = []
        self._tracer = None              # repro.obs.Tracer, when tracing

    def set_tracer(self, tracer) -> None:
        """Attach a ``repro.obs.Tracer``: every removal (eviction,
        supersede, invalidate, clear) emits a ``cache_evict`` instant.
        The tracer's lock is a leaf, so emitting is deadlock-free from
        any caller."""
        self._tracer = tracer

    def subscribe(self, on_remove: Callable[[Hashable], None]) -> None:
        """Register a callback fired with ``user_id`` whenever that user's
        entry leaves the cache (eviction, supersede, invalidate, clear).
        Callbacks run outside the cache lock (snapshot taken inside the
        mutating acquisition, fired after release), so they may take
        their own locks. Registration takes the cache lock: with a
        shared cache, one scenario may subscribe while another is
        serving (and notifying)."""
        with self._lock:
            self._listeners.append(on_remove)

    def subscribe_removal(self, on_remove: Callable[..., None]) -> None:
        """Like ``subscribe`` but the callback receives the FULL removal
        record ``(user_id, feature_version, reps, reason)`` with reason
        one of ``evict`` / ``supersede`` / ``invalidate`` / ``clear``.
        Only ``evict`` removals carry reps that are still the live value
        for their key — the cold tier demotes those; the other reasons
        mean the reps are stale and must not be re-served."""
        with self._lock:
            self._removal_listeners.append(on_remove)

    def _snapshot_listeners(self) -> tuple[tuple, tuple]:
        """Caller must hold ``_lock`` — the one mutating acquisition."""
        return tuple(self._listeners), tuple(self._removal_listeners)

    def _fire(self, removed: Sequence[Removal],
              listeners: tuple, removal_listeners: tuple) -> None:
        """Deliver removal callbacks strictly OUTSIDE the cache lock, on
        the snapshots taken inside the mutating acquisition (no second
        acquisition — rules out lock-order inversion against listener
        locks such as the cold-tier arena lock)."""
        if not removed:
            return
        trc = self._tracer
        for uid, ver, reps, reason in removed:
            if trc is not None:
                trc.instant("cache_evict", user=uid, reason=reason)
            for cb in listeners:
                cb(uid)
            for cb in removal_listeners:
                cb(uid, ver, reps, reason)

    def get(self, key: Key) -> Mapping[str, Any] | None:
        user_id, version = key
        with self._lock:
            entry = self._entries.get(user_id)
            if entry is None or entry[0] != version:
                self.misses += 1
                return None
            self._entries.move_to_end(user_id)
            self.hits += 1
            return entry[1]

    def put(self, key: Key, reps: Mapping[str, Any]) -> None:
        user_id, version = key
        removed: list[Removal] = []
        with self._lock:
            # one live entry per user: a newer feature_version overwrites
            # (and frees) the old reps rather than accumulating beside them
            prev = self._entries.get(user_id)
            if prev is not None and prev[0] != version:
                removed.append((user_id, prev[0], prev[1], SUPERSEDE))
            self._entries[user_id] = (version, reps)
            self._entries.move_to_end(user_id)
            while self.max_users is not None and len(self._entries) > self.max_users:
                evicted, (ever, ereps) = self._entries.popitem(last=False)
                self.evictions += 1
                removed.append((evicted, ever, ereps, EVICT))
            listeners, removal_listeners = self._snapshot_listeners()
        self._fire(removed, listeners, removal_listeners)

    def invalidate_user(self, user_id: Hashable) -> int:
        """Drop the cached entry of ``user_id``; returns entries removed."""
        removed: list[Removal] = []
        with self._lock:
            entry = self._entries.pop(user_id, None)
            if entry is not None:
                removed.append((user_id, entry[0], entry[1], INVALIDATE))
            listeners, removal_listeners = self._snapshot_listeners()
        self._fire(removed, listeners, removal_listeners)
        return len(removed)

    def clear(self) -> None:
        with self._lock:
            removed = [(uid, ver, reps, CLEAR)
                       for uid, (ver, reps) in self._entries.items()]
            self._entries.clear()
            listeners, removal_listeners = self._snapshot_listeners()
        self._fire(removed, listeners, removal_listeners)

    def stats(self) -> dict:
        """Occupancy + byte accounting of the host tier.

        ``bytes`` is the total live-rep footprint; ``boundary_bytes`` maps
        each boundary tensor name to its summed bytes across users — the
        number to look at when sizing ``CachePlan.device_slots`` (the
        device tier costs ``capacity * bytes_per_user`` up front).
        """
        with self._lock:
            boundary: dict[str, int] = {}
            for _ver, reps in self._entries.values():
                for k, n in _reps_nbytes(reps).items():
                    boundary[k] = boundary.get(k, 0) + n
            return {
                "users": len(self._entries),
                "max_users": self.max_users,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "bytes": sum(boundary.values()),
                "boundary_bytes": boundary,
            }

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Key) -> bool:
        user_id, version = key
        with self._lock:
            entry = self._entries.get(user_id)
            return entry is not None and entry[0] == version

    def keys(self) -> list[Key]:
        with self._lock:
            return [(uid, ver) for uid, (ver, _) in self._entries.items()]


class DeviceRepStore:
    """Slot-allocated persistent device tables for stage-1 reps.

    One stacked ``(capacity, ...)`` jax array per boundary tensor, lazily
    allocated from the first user row (shapes validated against
    ``boundary_specs`` when provided). ``ensure_rows`` maps
    ``(user, version)`` keys to slot indices, writing at most one row per
    new user via a jitted donated updater — the table buffer is reused in
    place, so steady-state serving allocates nothing.

    Slot lifecycle: ``drop`` (wired to ``UserRepCache.subscribe``) returns
    a user's slot to the free list without touching table contents; the
    dead row is simply unreferenced until a later user recycles the slot.
    When every slot is pinned by the current bucket (``protect``) and none
    is free, ``ensure_rows`` yields ``None`` for the overflow users and the
    engine falls back to the re-stacking path for that pack.

    NOT thread-safe against concurrent *dispatch*: callers must finish all
    ``ensure_rows`` writes for a batch before launching executables that
    read the tables (the donated writer deletes the previous table buffer).
    ``ServingEngine`` serializes exactly this way. To write while OLDER
    launches are still executing, arm ``fork_next_write`` first — the
    write then copies the table into a fresh generation instead of
    donating, leaving the in-flight buffer intact.
    """

    def __init__(self, capacity: int,
                 boundary_specs: Mapping[str, tuple[int, ...]] | None = None,
                 shardings: Mapping[str, Any] | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._specs = dict(boundary_specs) if boundary_specs else None
        self._shardings = dict(shardings) if shardings else None
        self._tables: dict[str, Any] | None = None
        self._writer = None
        self._writer_cow = None
        self._fork_pending = False
        # user -> (version, slot); insertion order == LRU order
        self._map: OrderedDict[Hashable, tuple[Hashable, int]] = OrderedDict()
        self._free: list[int] = list(range(capacity - 1, -1, -1))
        self._lock = threading.Lock()
        self.writes = 0      # row writes (new user or version supersede)
        self.hits = 0        # ensure_rows served from a live slot
        self.recycles = 0    # LRU slot steals (capacity pressure)
        self.drops = 0       # slots returned via drop()
        self.overflows = 0   # ensure_rows rows that could not get a slot
        self.forks = 0       # copy-on-write generation forks (writes armed
        #                      by fork_next_write under in-flight launches)
        self.quarantines = 0  # generation invalidations (failed write/fork)
        self._tracer = None  # repro.obs.Tracer, when tracing
        self._injector = None  # repro.ft.FaultInjector, when injecting

    def set_tracer(self, tracer) -> None:
        """Attach a ``repro.obs.Tracer`` for slot-lifecycle instants
        (``slot_steal`` / ``table_fork`` / ``slot_drop``). Emitted under
        the store lock — the tracer's lock is a leaf, so that is safe."""
        self._tracer = tracer

    def set_fault_injector(self, injector) -> None:
        """Attach a ``repro.ft.FaultInjector``: row writes poke the
        ``slot_write`` site (plus ``table_fork`` when a copy-on-write
        fork is armed). An injected error rides the existing failed-write
        path (the claimed slot is returned, the exception propagates to
        the engine, which quarantines the generation); the ``corrupt``
        sentinel NaN-poisons the written row so detection happens at
        collect, never at serve."""
        self._injector = injector

    # -- allocation ---------------------------------------------------------
    def _alloc(self, row: Mapping[str, Any]) -> None:
        import jax
        import jax.numpy as jnp

        tables = {}
        for k, v in row.items():
            per_row = tuple(v.shape[1:])
            if self._specs is not None:
                spec = self._specs.get(k)
                if spec is not None and per_row != tuple(spec):
                    raise ValueError(
                        f"boundary {k!r}: rep row shape {per_row} does not "
                        f"match the split's boundary spec {tuple(spec)}")
            tables[k] = jnp.zeros((self.capacity,) + per_row, dtype=v.dtype)
        if self._shardings is not None:
            tables = {k: jax.device_put(t, self._shardings[k])
                      if k in self._shardings else t
                      for k, t in tables.items()}

        def _write(tabs, reps, slot):
            return {k: tabs[k].at[slot].set(reps[k][0]) for k in tabs}

        kwargs = {}
        if self._shardings is not None:
            kwargs["out_shardings"] = {
                k: self._shardings.get(k) for k in tables}
        # donate_argnums=0: the previous table generation is consumed in
        # place — a row write costs one row's bandwidth, not a table copy
        self._writer = jax.jit(_write, donate_argnums=0, **kwargs)
        # the same update WITHOUT donation: builds a fresh generation and
        # leaves the previous buffer alive for in-flight executables still
        # reading it (see fork_next_write)
        self._writer_cow = jax.jit(_write, **kwargs)
        self._tables = tables

    # -- slot resolution ----------------------------------------------------
    def ensure_rows(self, items: Sequence[tuple[Hashable, Hashable,
                                                Mapping[str, Any]]],
                    protect: Sequence[Hashable] = ()) -> list[int | None]:
        """Resolve ``(user, version, reps)`` triples to device slots.

        Live ``(user, version)`` entries are LRU-bumped and reused without
        a write; new users take a free slot (or steal the LRU slot not in
        ``protect``) and get exactly one donated row write. Returns one
        slot per item, ``None`` where capacity ran out.

        MUST complete before any executable that reads ``tables`` is
        launched for this batch — see the class docstring.
        """
        import numpy as np
        protected = set(protect)
        slots: list[int | None] = []
        with self._lock:
            for user, version, reps in items:
                entry = self._map.get(user)
                if entry is not None and entry[0] == version:
                    self._map.move_to_end(user)
                    self.hits += 1
                    slots.append(entry[1])
                    continue
                if entry is not None:
                    # version supersede: rewrite the user's own slot
                    slot = entry[1]
                elif self._free:
                    slot = self._free.pop()
                else:
                    slot = self._steal_lru(protected)
                    if slot is None:
                        self.overflows += 1
                        slots.append(None)
                        continue
                try:
                    if self._injector is not None:
                        act = self._injector.poke("slot_write", user=user,
                                                  slot=slot)
                        if self._fork_pending:
                            act = (self._injector.poke("table_fork",
                                                       user=user, slot=slot)
                                   or act)
                        if act == "corrupt":
                            # detectable corruption: NaN-poison the row
                            # being written — it propagates to any score
                            # gathered from this slot and is caught at
                            # collect (clean reps stay in the host LRU,
                            # so the post-quarantine rebuild is clean)
                            reps = {k: np.full_like(np.asarray(v), np.nan)
                                    if np.issubdtype(np.asarray(v).dtype,
                                                     np.floating)
                                    else v for k, v in reps.items()}
                    if self._tables is None:
                        self._alloc(reps)
                    if self._fork_pending:
                        # copy-on-write: in-flight executables keep the
                        # generation they were handed; writes after this
                        # one donate the (not-yet-published) fork in place
                        self._tables = self._writer_cow(
                            self._tables, dict(reps), np.int32(slot))
                        self._fork_pending = False
                        self.forks += 1
                        if self._tracer is not None:
                            self._tracer.instant("table_fork", user=user,
                                                 slot=slot)
                    else:
                        self._tables = self._writer(self._tables,
                                                    dict(reps),
                                                    np.int32(slot))
                except Exception:
                    # a failed alloc/write (e.g. a rep row violating the
                    # boundary spec) must not leak the slot it claimed; a
                    # version supersede keeps its old entry (the previous
                    # row is still intact — the writer is all-or-nothing)
                    if entry is None:
                        self._free.append(slot)
                    raise
                self.writes += 1
                self._map[user] = (version, slot)
                self._map.move_to_end(user)
                protected.add(user)
                slots.append(slot)
        return slots

    def _steal_lru(self, protected: set) -> int | None:
        for user in self._map:          # iterates LRU -> MRU
            if user not in protected:
                _, slot = self._map.pop(user)
                self.recycles += 1
                if self._tracer is not None:
                    self._tracer.instant("slot_steal", user=user, slot=slot)
                return slot
        return None

    # -- lifecycle ----------------------------------------------------------
    def drop(self, user: Hashable) -> None:
        """Recycle ``user``'s slot (cache eviction/invalidation hook).
        The table row is left as-is: dead slots are never referenced, and
        stage-2 gathers clamp, so no zeroing pass is needed."""
        with self._lock:
            entry = self._map.pop(user, None)
            if entry is not None:
                self._free.append(entry[1])
                self.drops += 1
                if self._tracer is not None:
                    self._tracer.instant("slot_drop", user=user,
                                         slot=entry[1])

    def slot_of(self, user: Hashable) -> int | None:
        with self._lock:
            entry = self._map.get(user)
            return None if entry is None else entry[1]

    def quarantine(self, reason: str = "") -> None:
        """Invalidate the current table generation wholesale.

        A failed donated write or fork leaves no guarantee about the
        generation's contents (the writer may have consumed the previous
        buffer before failing), so nothing in it may ever be served
        again: the slot map clears, every slot returns to the free list,
        and the tables drop — they rebuild lazily from the host LRU on
        the next ``ensure_rows`` (one row write per user, exactly like a
        cold start). The host tier is untouched: quarantine costs
        re-WRITES, never re-COMPUTES. Any in-flight executable keeps the
        generation it was handed alive via its own reference, so this is
        safe under the continuous loop."""
        with self._lock:
            self._map.clear()
            self._free = list(range(self.capacity - 1, -1, -1))
            self._tables = None
            self._fork_pending = False
            self.quarantines += 1
            if self._tracer is not None:
                self._tracer.instant("quarantine", reason=reason[:120])

    def fork_next_write(self) -> None:
        """Arm copy-on-write for the NEXT row write: instead of donating
        the current table generation in place, it builds a fresh one and
        leaves the old buffer intact. The continuous dispatch loop arms
        this when launches are still in flight — their executables hold
        (and keep alive) the generation they were handed at launch, while
        this call and everything after it read the fork. Later writes in
        the same resolution donate again: they consume the fork, which no
        in-flight executable has seen. Disarm with ``clear_fork_mark`` if
        the anticipated write never materializes (e.g. every pack fell
        back to re-stacking)."""
        with self._lock:
            self._fork_pending = True

    def clear_fork_mark(self) -> None:
        with self._lock:
            self._fork_pending = False

    def is_live(self, user: Hashable, version: Hashable) -> bool:
        """True iff ``(user, version)`` already holds a slot, i.e. an
        ``ensure_rows`` call for it would be a pure hit — no row write, no
        LRU steal. The continuous dispatch loop uses this to decide whether
        a call needs the copy-on-write fork before launching over in-flight
        executables (hits read the current table generation freely; a miss
        means a row write, and a donated write would delete the generation
        an in-flight executable is reading)."""
        with self._lock:
            entry = self._map.get(user)
            return entry is not None and entry[0] == version

    @property
    def tables(self) -> dict[str, Any] | None:
        """The live per-boundary ``(capacity, ...)`` tables (None until the
        first write). Callers must treat the dict and its arrays as
        read-only and must not retain them across ``ensure_rows`` calls —
        the donated writer deletes superseded buffers."""
        return self._tables

    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def stats(self) -> dict:
        with self._lock:
            boundary = ({k: int(t.nbytes) for k, t in self._tables.items()}
                        if self._tables is not None else {})
            return {
                "capacity": self.capacity,
                "resident": len(self._map),
                "free_slots": len(self._free),
                "writes": self.writes,
                "hits": self.hits,
                "recycles": self.recycles,
                "drops": self.drops,
                "overflows": self.overflows,
                "forks": self.forks,
                "quarantines": self.quarantines,
                "bytes": sum(boundary.values()),
                "boundary_bytes": boundary,
            }
