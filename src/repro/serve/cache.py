"""Bounded user-representation store for the serving runtime.

Stage-1 outputs (user activations + per-``mari_dense`` partials +
decomposed-attention one-shot tensors) are cached per
``(user_id, feature_version)`` so repeat users skip the user tower. The
seed engine kept these in an unbounded dict — at "millions of users" scale
that is an OOM, not a cache. ``UserRepCache`` is the replacement:

* **LRU bound** — ``max_users`` caps live entries; inserting past the cap
  evicts the least-recently-*scored* user and bumps ``evictions`` (surfaced
  on the engine for capacity monitoring).
* **version supersede** — one live entry per user: putting a new
  ``feature_version`` frees every older version of that user immediately
  (feature updates must not accumulate stale representations).
* **invalidation** — ``invalidate_user`` drops all versions of a user
  (logout, feature backfill, GDPR delete).
* **thread safety** — the async batcher's worker thread and callers of
  ``ServingEngine.score`` touch the cache concurrently; every mutation is
  taken under one lock.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable, Mapping

Key = tuple[Hashable, Hashable]          # (user_id, feature_version)


class UserRepCache:
    """LRU mapping (user_id, feature_version) -> stage-1 output pytree.

    Stored keyed by user_id with the live version alongside, so the
    one-live-entry-per-user invariant costs O(1) per insert — a key scan
    per put would be O(cache size) and melt under miss traffic at the
    intended scale.
    """

    def __init__(self, max_users: int | None = None):
        if max_users is not None and max_users < 1:
            raise ValueError(f"max_users must be >= 1, got {max_users}")
        self.max_users = max_users
        # user_id -> (feature_version, reps); insertion order == LRU order
        self._entries: OrderedDict[
            Hashable, tuple[Hashable, Mapping[str, Any]]] = OrderedDict()
        self._lock = threading.Lock()
        self.evictions = 0               # LRU-bound evictions only
        self.hits = 0
        self.misses = 0

    def get(self, key: Key) -> Mapping[str, Any] | None:
        user_id, version = key
        with self._lock:
            entry = self._entries.get(user_id)
            if entry is None or entry[0] != version:
                self.misses += 1
                return None
            self._entries.move_to_end(user_id)
            self.hits += 1
            return entry[1]

    def put(self, key: Key, reps: Mapping[str, Any]) -> None:
        user_id, version = key
        with self._lock:
            # one live entry per user: a newer feature_version overwrites
            # (and frees) the old reps rather than accumulating beside them
            self._entries[user_id] = (version, reps)
            self._entries.move_to_end(user_id)
            while self.max_users is not None and len(self._entries) > self.max_users:
                self._entries.popitem(last=False)
                self.evictions += 1

    def invalidate_user(self, user_id: Hashable) -> int:
        """Drop the cached entry of ``user_id``; returns entries removed."""
        with self._lock:
            return 0 if self._entries.pop(user_id, None) is None else 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Key) -> bool:
        user_id, version = key
        with self._lock:
            entry = self._entries.get(user_id)
            return entry is not None and entry[0] == version

    def keys(self) -> list[Key]:
        with self._lock:
            return [(uid, ver) for uid, (ver, _) in self._entries.items()]
