"""Stage-boundary timing taxonomy for the serving hot path.

The dispatch-overhead war is fought in microseconds of *host* work per
call, and you cannot win a war you cannot see. ``StageProfiler`` splits a
``score``/``score_coalesced`` call into the phases that matter for a
two-stage ranker:

* ``stage1``   — user-tower compute on cache miss (device, blocking);
* ``pack``     — host-side bucket assembly: transfer-buffer fills, slot
  resolution, device-table row writes;
* ``dispatch`` — enqueueing stage-2 executables (host time only when the
  async-unpack path is active; includes device time on the blocking
  hedged path);
* ``device``   — waiting on stage-2 results (``block_until_ready``);
* ``unpack``   — materializing scores to host and slicing per-request
  views out of the bucket;
* ``queue_idle`` — continuous-loop time with the device idle AND the
  request queue empty (nothing to overlap — true starvation, not loop
  overhead);
* ``overlap``  — host time spent forming-and-launching group k+1 while
  group k was still executing on device (the work the continuous loop
  hides under device compute; lockstep dispatch reports zero here).

Phases are cumulative wall-clock totals plus call counts, cheap enough to
stay on permanently (~two ``perf_counter`` calls per phase). The engine
threads one profiler through its lifetime; ``RankingService.stats()`` and
``benchmarks/run.py``'s ``serve/<mode>/breakdown`` rows read snapshots.

Thread safety: totals are mutated under a lock because the coalescing
batcher's worker thread and direct ``score`` callers may profile
concurrently against one engine.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Iterator

PHASES = ("stage1", "pack", "dispatch", "device", "unpack",
          "queue_idle", "overlap")


class StageProfiler:
    """Cumulative per-phase wall-clock accounting for the serve hot path."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._total_s: dict[str, float] = {p: 0.0 for p in PHASES}
        self._calls: dict[str, int] = {p: 0 for p in PHASES}

    @contextmanager
    def phase(self, name: str) -> Iterator[None]:
        """Time one phase occurrence (``with prof.phase("pack"): ...``)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def add(self, name: str, seconds: float) -> None:
        if name not in self._total_s:
            raise KeyError(f"unknown profile phase {name!r}; "
                           f"expected one of {PHASES}")
        with self._lock:
            self._total_s[name] += seconds
            self._calls[name] += 1

    def snapshot(self, reset: bool = False) -> dict[str, dict[str, float]]:
        """Per-phase ``{total_ms, calls, mean_us}`` (zero-safe).

        ``reset=True`` zeroes the totals under the SAME lock acquisition
        — the atomic read-and-clear bench loops need. A separate
        ``snapshot(); reset()`` pair loses every phase event recorded
        between the two calls (the batcher worker profiles concurrently),
        silently shrinking the next window's denominator."""
        with self._lock:
            out = {}
            for p in PHASES:
                calls = self._calls[p]
                total = self._total_s[p]
                out[p] = {
                    "total_ms": total * 1e3,
                    "calls": calls,
                    "mean_us": (total / calls * 1e6) if calls else 0.0,
                }
            if reset:
                for p in PHASES:
                    self._total_s[p] = 0.0
                    self._calls[p] = 0
            return out

    def reset(self) -> None:
        with self._lock:
            for p in PHASES:
                self._total_s[p] = 0.0
                self._calls[p] = 0
