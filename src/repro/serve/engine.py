"""Per-request orchestration for the serving runtime (Fig. 2 made a system).

``ServingEngine`` compiles a (MaRI-rewritten) ranking graph into the
two-stage pipeline of ``repro.core.split`` and scores candidate pools
against cached user representations. This module is the *orchestration*
layer of the serve subsystem — queueing/coalescing lives in
``repro.serve.batcher``, the bounded rep store in ``repro.serve.cache``,
and straggler hedging in ``repro.serve.hedging``.

Execution model — ONE row-wise stage-2 executable for everything:

  stage2(params, rep_table (U, ...), user_index (B,), candidate_feeds (B, ...))
      = residual_graph(params, {reps[user_index], candidates})

* a single request is the degenerate case U = 1 (``user_index`` all zero);
* a cross-user coalesced batch stacks the U users' cached stage-1 outputs
  into a rep table and lets each candidate row gather its own user's reps.

Because BOTH paths run the identical executable family, coalesced scores
are bit-identical to per-request scores (proven by test) — row results of
the row-parallel residual graph do not depend on batch size, packing
position, or rep-table size.

Configuration is a ``repro.serve.plan.ServePlan`` — the frozen, validated,
JSON-serializable config spine shared by every entry point::

    engine = ServingEngine(graph, params, plan=ServePlan.preset("paper"))
    engine = ServingEngine(graph, params,
                           plan=ServePlan().evolve(graph__mode="uoi"))

``plan.graph`` picks the paradigm and MaRI-rewrite shape, ``plan.kernel``
the Pallas dispatch (fused ``mari_dense``, rep-table ``kernel_gather`` at
accumulator-init load, gather-at-load ``gather_attention`` boundaries),
``plan.batch`` the bucketing/coalescing/hedging envelope, ``plan.shard``
candidate-axis sharding on the ``repro.dist`` 'cand' mesh (single-process
``jax.sharding`` or SPMD across ``jax.distributed`` workers, optional int8
score gather), and ``plan.cache`` the bounded LRU user-rep store. Invalid
combinations are rejected or auto-resolved AT PLAN CONSTRUCTION (see the
resolution table in ``repro.serve.plan``) instead of failing late or
silently no-oping inside the engine.

Legacy keyword construction — ``ServingEngine(graph, params, mode=...,
use_pallas=..., ...)`` — still works as a thin shim that builds the
equivalent plan and emits a ``DeprecationWarning``; scores are identical
to the plan path by construction (proven by test).

Two runtime-dependent adjustments stay here rather than in the plan: a
multi-process 'cand' mesh forces ``hedging`` off (per-process duplicates
would desynchronize the SPMD collective schedule), and a sharded engine
rounds ``max_batch`` down to a shard-divisible power of two.

Hot-path dispatch (``plan.cache.device_resident``) — the allocation-free
stage-2 pipeline:

* **device-resident rep tables** — cached stage-1 reps live in a
  slot-allocated ``DeviceRepStore``: ONE persistent ``(capacity, ...)``
  jax array per boundary, new users written as single donated
  ``.at[slot].set`` rows, evicted users merely recycling their slot
  integer. ``score_coalesced`` passes the persistent tables plus per-row
  *device slots* instead of re-concatenating a fresh ``(U, ...)`` table
  per bucket; the engine's ``mode="clip"`` gathers make dead or stale
  slots safe by construction.
* **donated bucket buffers** — candidate rows and the user index are
  filled into private per-pack host buffers (padding is one masked tail
  write), transferred, and donated to the stage-2 executable
  (``donate_argnums``), so steady-state serving performs zero fresh
  device allocations. Donated arguments are consumed: callers must never
  retain them, which is why ``device_resident`` forces ``hedging`` off
  (a hedged duplicate would replay deleted buffers — resolved at plan
  construction).
* **async unpack** — launches are non-blocking and the call is a
  pipeline: after the table-write barrier, each pack is prepared and
  launched in turn, so the host packs bucket k+1 while the device
  computes bucket k; no result is blocked on until every pack is in
  flight, and scores materialize only when the reply is assembled.
* **stage profiler** — ``repro.serve.profile.StageProfiler`` splits every
  call into stage1 / pack / dispatch / device / unpack, surfaced via
  ``RankingService.stats()`` and the ``serve/<mode>/breakdown`` benchmark
  rows.

Ordering contract: every device-table row write of a call completes
before any stage-2 launch of that call — so the donated table writer can
never delete a buffer an in-flight executable still reads. Concurrent
direct callers must serialize ``score``/``score_coalesced`` themselves
(the batcher's single worker thread already does).

Two-phase dispatch (the continuous batching loop's engine contract):
``begin_coalesced(reqs)`` runs stage 1 + packing + the table-write
barrier and launches every pack WITHOUT blocking, returning an opaque
in-flight handle; ``collect(handle)`` blocks, materializes, and slices
the per-request results. ``score_coalesced`` is exactly
``collect(begin_coalesced(reqs))``, so the lockstep and continuous paths
share one implementation and stay bit-identical by construction. The
engine tracks outstanding handles: a ``begin_coalesced`` call whose
users are all already resident overlaps freely (its packs read the
current table generation, which in-flight executables also hold). A call
that needs ANY device-table row write arms the store's copy-on-write
fork (``pipeline_forks`` counts these): the first write builds a NEW
table generation instead of donating the old one in place, so in-flight
executables keep reading the buffer they were handed while this call
reads the fork — overlap survives cold users at the cost of one table
copy. Either way the pipeline never drains mid-stream; results are
bit-identical because both generations carry identical rows for every
user a pack references.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import next_pow2 as _next_pow2, prev_pow2
from repro.core.mari import mari_rewrite, convert_params
from repro.core.split import split_two_stage
from repro.ft.faults import CORRUPT, FaultInjector
from repro.ft.recovery import CircuitBreaker
from repro.graph.executor import Executor, USER_INDEX_FEED
from repro.graph.ir import Graph
from repro.mem import ColdRepStore, PromotionWorker, RepWarmer
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import DEFAULT_CAPACITY, Tracer
from repro.serve.cache import EVICT, DeviceRepStore, UserRepCache
from repro.serve.errors import FaultInjected
from repro.serve.hedging import HedgedRunner, HedgePolicy
from repro.serve.plan import ServePlan
from repro.serve.profile import StageProfiler


@dataclasses.dataclass
class ServeRequest:
    user_id: int
    user_feeds: Mapping[str, jax.Array]      # leading dim 1
    candidate_feeds: Mapping[str, jax.Array]  # leading dim = n_candidates
    feature_version: int = 0                 # bump to invalidate cached reps


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray
    latency_ms: float            # wall time of the (possibly shared) batch
    n_batches: int               # stage-2 dispatches this request took part in
    user_cache_hit: bool
    hedged: int = 0              # dispatches that launched a duplicate
    stage1_ms: float = 0.0       # 0 when cached / single-stage
    coalesced: bool = False      # scored inside a cross-user batch
    degraded: bool = False       # candidate pool truncated under overload
    cold_hit: bool = False       # served from the host-RAM cold tier (no
    #                              stage-1 recompute, no hot/device slot)


def _precat_mari_weights(graph: Graph, params: dict) -> dict:
    """Pre-concatenate each ``mari_dense``'s batched-group weight blocks.

    The executor (and the Pallas kernel's ops layer) stream the batched
    side as ONE matmul ``concat(x_g) @ concat(W_g)``; without this, the
    weight concat is re-emitted inside every jitted call. Building the
    concatenated block once at engine-build time (stored as ``w_cat``
    beside the original blocks) removes it from the hot path. Scores are
    bit-identical either way — the streamed operand values are unchanged.
    """
    out = dict(params)
    for n in graph.nodes.values():
        if n.op != "mari_dense":
            continue
        p = params[n.name]
        if n.attrs.get("fragment"):
            if not n.attrs.get("precomputed_user"):
                continue          # batch-1-ness varies per segment: no fusion
            ws = [p[f"w_seg{i}"] for i in n.attrs["seg_param_idx"]]
        else:
            labels = [lab for lab, _ in n.attrs["groups"] if lab != "user"]
            ws = [p[f"w_{lab}"] for lab in labels]
        if len(ws) < 2:
            continue              # single block: nothing to concatenate
        out[n.name] = dict(p, w_cat=jnp.concatenate(ws, axis=0))
    return out


@dataclasses.dataclass
class _ReqInfo:                   # per-request working state inside a batch
    reps: Mapping[str, jax.Array]
    hit: bool
    stage1_ms: float
    chunks: list[tuple[dict, int]]
    slot_key: object
    cold_hit: bool = False        # reps came from the cold arena read


@dataclasses.dataclass(eq=False)
class _InFlight:
    """Opaque handle for a launched-but-uncollected ``begin_coalesced``
    call. ``eq=False``: identity semantics — the engine's outstanding list
    must distinguish two handles even for identical request batches."""
    reqs: Sequence[ServeRequest]
    infos: list
    packs: list
    launched: list                # per pack: (outs, hedged, blocked)
    t0: float
    gid: int = 0                  # engine-wide group id (trace context)
    track: str | None = None      # synthetic trace track while outstanding
    slot: int = -1                # track slot, freed at collect
    slots_mask: list = dataclasses.field(default_factory=list)
    #                               per pack: True = device-slot fast path
    #                               (breaker outcome accounting at collect)


class ServingEngine:
    def __init__(self, graph: Graph, params: dict,
                 plan: ServePlan | str | None = None, *,
                 hedge_policy: HedgePolicy | None = None,
                 cache: UserRepCache | None = None,
                 cache_scope: Hashable | None = None,
                 **legacy_kwargs):
        """Compile ``graph`` for two-stage serving per ``plan``.

        ``plan`` is a ``ServePlan`` (or a preset name). ``cache`` /
        ``cache_scope`` let a host (``RankingService``) inject a SHARED
        ``UserRepCache``: cache keys are namespaced by ``cache_scope`` so
        several scenario engines can split one LRU budget without key
        collisions. ``hedge_policy`` stays a constructor argument (it is a
        live object, not serializable plan material).

        Passing the old keyword knobs instead of ``plan`` still works: the
        legacy shim builds the equivalent plan (fail-fast validation
        included) and emits a ``DeprecationWarning``.
        """
        if plan is not None and legacy_kwargs:
            raise TypeError(
                f"pass plan= OR legacy keyword knobs, not both "
                f"(got plan and {sorted(legacy_kwargs)})")
        if isinstance(plan, str):
            plan = ServePlan.preset(plan)
        if plan is None:
            if legacy_kwargs:
                warnings.warn(
                    "ServingEngine keyword knobs are deprecated — pass "
                    "plan=ServePlan(...) (repro.serve.plan; "
                    "ServePlan.from_legacy_kwargs maps old names)",
                    DeprecationWarning, stacklevel=2)
            plan = ServePlan.from_legacy_kwargs(**legacy_kwargs)
        self.plan = plan
        mode = plan.graph.mode
        reparam_attention = plan.graph.reparam_attention
        fragment = plan.graph.fragment
        group_by_domain = plan.graph.group_by_domain
        two_stage = plan.graph.two_stage
        use_pallas = plan.kernel.use_pallas
        kernel_gather = plan.kernel.kernel_gather
        gather_attention = plan.kernel.gather_attention
        precat_weights = plan.kernel.precat_weights
        max_batch = plan.batch.max_batch
        hedging = plan.batch.hedging
        shard_candidates = plan.shard.shard_candidates
        compress_scores = plan.shard.compress_scores

        self.mode = mode
        self.max_batch = max_batch
        self.min_bucket = plan.batch.min_bucket
        self.max_users_per_batch = plan.batch.max_users_per_batch
        if mode == "mari":
            conv = mari_rewrite(graph, reparam_attention=reparam_attention,
                                fragment=fragment,
                                group_by_domain=group_by_domain)
            self.graph = conv.graph
            self.params = convert_params(conv, params)
            self.conversion = conv
            exec_mode = "uoi"
        else:
            self.graph = graph
            self.params = params
            self.conversion = None
            exec_mode = mode
        # vani tiles user feeds into the batch — there is no user-only
        # subgraph to peel, so it stays single-stage.
        self.two_stage = (exec_mode == "uoi") if two_stage is None else two_stage
        self.outputs = list(self.graph.outputs)
        self._user_inputs = [n.name for n in self.graph.input_nodes()
                             if n.attrs.get("domain") == "user"]

        if self.two_stage:
            split = split_two_stage(self.graph)
            # The request contract partitions feeds by domain: user_feeds
            # carries exactly the domain=="user" inputs. A stage-1 input
            # outside that set (an uncolored, domain-less input pulled into
            # the user closure) could never be fed, so the split is not
            # servable for this graph.
            unservable = [n.name for n in split.stage1.input_nodes()
                          if n.attrs.get("domain") != "user"]
            if unservable and two_stage:
                raise ValueError(
                    f"two_stage=True but stage-1 needs non-user feeds "
                    f"{unservable}; give these inputs domain='user' or "
                    f"serve single-stage")
            if unservable:
                self.two_stage = False

        # -- candidate-axis sharding (stage 2): candidate rows + user index
        # split across shards, params and rep tables replicated. The mesh
        # and specs come from repro.dist; a single process over local
        # devices is the degenerate case of the multi-process topology. --
        self.shard_candidates = bool(shard_candidates)
        self._in_shardings = self._out_shardings = None
        self._n_shards = 1
        self._multiproc = False
        self.compress_scores = False
        # compress_scores without shard_candidates is rejected at plan
        # construction (PlanError) — no late engine check needed
        if shard_candidates:
            from repro.dist.sharding import candidate_pspecs
            from repro.dist.topology import candidate_mesh
            n_shards = (None if shard_candidates is True
                        else int(shard_candidates))
            # never shard wider than the caller's row budget allows: a
            # dispatch must give every shard >= 1 row within max_batch
            cap = prev_pow2(max_batch)
            self.mesh = candidate_mesh(cap if n_shards is None
                                       else min(n_shards, cap))
            self._n_shards = int(self.mesh.devices.size)
            self._multiproc = len({d.process_index
                                   for d in self.mesh.devices.flat}) > 1
            if self._multiproc:
                # SPMD lockstep: every process must issue the identical
                # dispatch sequence, so a per-process duplicate execution
                # (hedging) would desynchronize the collective schedule.
                hedging = False
            # buckets stay multiples of the shard count (pow2 / pow2):
            # no shard ever receives a ragged tail. The row cap itself must
            # divide evenly over the mesh, so a non-pow2 max_batch rounds
            # DOWN to the nearest power of two — never above the caller's
            # cap (the mesh was clamped to prev_pow2(max_batch) shards).
            if self._n_shards > 1:
                self.max_batch = prev_pow2(self.max_batch)
            self.min_bucket = min(max(self.min_bucket, self._n_shards),
                                  self.max_batch)
            self.compress_scores = compress_scores
            self._in_shardings, self._out_shardings = candidate_pspecs(
                self.mesh, replicate_out=(True if self._multiproc else None))
            if self.compress_scores:
                # the closing gather itself moves int8: stage 2 leaves its
                # scores device-sharded and the compressed all-gather (one
                # quantized collective) replicates them to every host
                from jax.sharding import NamedSharding, PartitionSpec as P
                self._out_shardings = NamedSharding(self.mesh, P("cand"))
        else:
            self.mesh = None

        if self.two_stage:
            self.split = split
            # rep-table contract: every user-side stage-2 input must be a
            # value stage 1 produces (boundary_specs names them) — a split
            # violating this could never be fed from the cache
            s2_user = {n.name for n in split.stage2.input_nodes()
                       if n.attrs.get("domain") == "user"}
            missing = s2_user - set(split.boundary_specs)
            if missing:
                raise ValueError(
                    f"stage-2 user inputs {sorted(missing)} are not in the "
                    f"split's boundary_specs — stage 1 cannot supply them")
            self._stage1 = jax.jit(Executor(self.split.stage1, "uoi").run)
            self._stage1_inputs = {
                n.name for n in self.split.stage1.input_nodes()}
            batched_graph = self.split.stage2
            if self._in_shardings is not None:
                # the rep-table arg's shardings come from the split's own
                # boundary contract (per-entry rank-matched replication)
                # rather than a blanket spec — the table dict keys are
                # exactly the boundary names
                from repro.dist.sharding import named, rep_table_pspecs
                self._in_shardings = (
                    self._in_shardings[0],
                    named(self.mesh, rep_table_pspecs(split.boundary_specs)),
                    self._in_shardings[2], self._in_shardings[3])
        else:
            self.split = None
            self._stage1 = None
            self._stage1_inputs = None
            batched_graph = self.graph
        self.precat_weights = precat_weights
        if precat_weights:
            self.params = _precat_mari_weights(batched_graph, self.params)
        # kernel_gather without use_pallas was auto-resolved to False at
        # plan construction (with a PlanResolutionWarning), so no silent
        # `and use_pallas` masking is needed here anymore
        self.kernel_gather = kernel_gather
        # gather-aware attention works with or without Pallas: the executor
        # falls back to the jnp.take oracle off-TPU, so scores are identical
        # either way — only the memory profile needs the kernel
        self.gather_attention = gather_attention

        # -- rep cache + device tier (before _build_rowwise: stage-2 buffer
        # donation is only sound on the device-resident path) --
        # single-stage serving has no stage-1 outputs to reuse — the
        # "representation" is the raw feed dict, rebuilt per request — so
        # cache get/put there is pure bookkeeping overhead on the hot path
        # (BENCH_serve showed vani hit at 0.97x of cold); make it a no-op
        self.cache_user_reps = plan.cache.cache_user_reps and self.two_stage
        # an injected cache is SHARED (RankingService budget); cache_scope
        # namespaces this engine's keys inside it so same-valued user ids
        # from different scenarios cannot collide on wrong-shaped reps
        self.cache = cache if cache is not None else UserRepCache(
            max_users=plan.cache.max_cached_users)
        self._cache_scope = cache_scope
        # multi-process SPMD: every process would need the identical global
        # table state across asynchronous per-process writes — the device
        # tier stays off and packs re-stack replicated tables as before
        self.device_resident = (plan.cache.device_resident
                                and self.cache_user_reps
                                and not self._multiproc)
        self._device_store = None
        if self.device_resident:
            capacity = (plan.cache.device_slots
                        if plan.cache.device_slots is not None
                        else (plan.cache.max_cached_users or 64))
            table_shardings = (self._in_shardings[1]
                               if self._in_shardings is not None else None)
            self._device_store = DeviceRepStore(
                capacity, boundary_specs=self.split.boundary_specs,
                shardings=table_shardings)
            # recycle device slots in lockstep with the host tier: any
            # removal (LRU eviction, version supersede, invalidate, clear)
            # frees the user's slot for the next resident user
            self.cache.subscribe(self._device_store.drop)
        self._donate_stage2 = self.device_resident
        if self._donate_stage2:
            # plan construction already resolves device_resident+hedging
            # to hedging=False; enforce it here too (mirroring the
            # multi-process override) so a plan that slipped past
            # resolution can never hand HedgedRunner donated uidx/cand
            # buffers — a hedged duplicate would replay consumed arrays
            hedging = False

        self._stage2 = self._build_rowwise(batched_graph, exec_mode,
                                           use_pallas)
        # multi-process: stage 2 consumes params as a globalized replica on
        # the cross-host mesh; stage 1 keeps the process-local copy
        self._params_s2 = self.params
        if self._multiproc:
            repl = self._in_shardings[0]
            self._params_s2 = jax.tree_util.tree_map(
                lambda v: self._globalize(v, repl), self.params)
        self._cgather = None
        if self.compress_scores:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.dist.compress import compressed_all_gather
            # check_rep off: the all-gathered result IS replicated, but the
            # checker can't prove it through the per-shard scale arithmetic
            self._cgather = jax.jit(shard_map(
                lambda x: compressed_all_gather(x, "cand"), mesh=self.mesh,
                in_specs=P("cand"), out_specs=P(), check_rep=False))

        self.stage1_calls = 0                 # trace counter for the split test
        self.stage2_calls = 0                 # total row-wise dispatches
        self.coalesced_calls = 0              # dispatches mixing >1 user slot
        self.pipeline_forks = 0               # copy-on-write table forks
        #                                       (begin_coalesced needed a row
        #                                       write while launches were in
        #                                       flight)
        self._inflight: list[_InFlight] = []  # launched, not yet collected
        self._batch_shapes: set[tuple[int, int]] = set()  # (U_dim, bucket)
        # first-seen candidate-feed signature {name: (dtype, row shape)} —
        # pack transfer buffers are shaped from it, so a later request
        # drifting from it must fail fast (see _chunk), not be silently
        # cast (or raise mid-call) by the buffer fill
        self._feed_sig: dict[str, tuple] | None = None
        self.profiler = StageProfiler()

        # -- observability (plan.obs): ring-buffer tracing + histogram
        # metrics (repro.obs). Tracing off keeps the hot path at a
        # `tracer is None` check; the cache tiers get the tracer for
        # eviction / slot-steal / fork instants. --
        self.tracer: Tracer | None = None
        if plan.obs.trace:
            self.tracer = Tracer(
                capacity=plan.obs.trace_capacity or DEFAULT_CAPACITY,
                sample_every=plan.obs.sample_every)
            self.cache.set_tracer(self.tracer)
            if self._device_store is not None:
                self._device_store.set_tracer(self.tracer)
        self.metrics: MetricsRegistry | None = None
        if plan.obs.metrics:
            self.metrics = MetricsRegistry()
            # the scattered counters, unified behind one snapshot():
            # gauges are sampled lazily, so registration costs nothing
            # on the hot path
            for name, fn in (
                    ("cache_hits", lambda: self.cache.hits),
                    ("cache_misses", lambda: self.cache.misses),
                    ("cache_evictions", lambda: self.cache.evictions),
                    ("stage1_calls", lambda: self.stage1_calls),
                    ("stage2_calls", lambda: self.stage2_calls),
                    ("coalesced_calls", lambda: self.coalesced_calls),
                    ("pipeline_forks", lambda: self.pipeline_forks)):
                self.metrics.gauge(name, fn)
            self._group_wall_hist = self.metrics.histogram("group_wall_ms")
        else:
            self._group_wall_hist = None
        self._group_seq = 0           # begin_coalesced calls (group ids)
        self._group_slots: set[int] = set()  # outstanding trace tracks
        self._trace_req_seq = 0       # engine-side request sampling seq
        self.hedge_policy = hedge_policy or HedgePolicy()
        self.hedging = hedging
        self._hedged = (HedgedRunner(self._dispatch, self.hedge_policy)
                        if hedging else None)

        # -- fault tolerance (plan.ft): deterministic injection + the
        # stage-2 circuit breaker + device-tier quarantine. Off by default:
        # the hot path pays one `injector is None` check per site. --
        ftp = plan.ft
        self.fault_injector: FaultInjector | None = None
        if ftp.inject and ftp.sites:
            self.fault_injector = FaultInjector(
                ftp.sites, seed=ftp.seed, tracer=self.tracer)
            if self._device_store is not None:
                self._device_store.set_fault_injector(self.fault_injector)
        self.breaker: CircuitBreaker | None = None
        if ftp.breaker_failures > 0 and self._device_store is not None:
            self.breaker = CircuitBreaker(
                failures=ftp.breaker_failures,
                cooldown_ms=ftp.breaker_cooldown_ms,
                probes=ftp.breaker_probes,
                on_transition=self._on_breaker_transition)
        self.fallback_packs = 0       # packs the open breaker re-routed
        self.corruptions_detected = 0  # NaN-poisoned scores caught at collect
        if self.metrics is not None:
            for name, fn in (
                    ("faults_injected",
                     lambda: (self.fault_injector.total_fired
                              if self.fault_injector is not None else 0)),
                    ("breaker_opens",
                     lambda: (self.breaker.opens
                              if self.breaker is not None else 0)),
                    ("breaker_closes",
                     lambda: (self.breaker.closes
                              if self.breaker is not None else 0)),
                    ("breaker_fallback_packs", lambda: self.fallback_packs),
                    ("corruptions_detected",
                     lambda: self.corruptions_detected),
                    ("quarantines",
                     lambda: (self._device_store.quarantines
                              if self._device_store is not None else 0))):
                self.metrics.gauge(name, fn)

        # -- hierarchical memory tier (plan.mem, repro.mem): host-RAM cold
        # store + async promotion + bulk warming. Off by default. The cold
        # tier only makes sense under a live hot cache (plan resolution
        # already drops cold_tier without cache_user_reps; single-stage
        # engines force caching off above, which drops it here too). --
        self.cold_tier = plan.mem.cold_tier and self.cache_user_reps
        self._cold: ColdRepStore | None = None
        self._promoter: PromotionWorker | None = None
        self._warmer: RepWarmer | None = None
        self.cold_hits = 0            # requests served from the arena read
        self.cold_misses = 0          # full misses past an armed cold tier
        self.demotions = 0            # hot-LRU evictions caught by the arena
        if self.cold_tier:
            self._cold = ColdRepStore(plan.mem.cold_bytes)
            self._promoter = PromotionWorker(
                self._cold, self.cache,
                touches=plan.mem.promote_touches,
                window_s=plan.mem.promote_window_s, tracer=self.tracer)
            self._warmer = RepWarmer(self._warm_stage1, self._cold,
                                     batch=plan.mem.warm_batch,
                                     tracer=self.tracer)
            # hot-LRU evictions DEMOTE into the arena instead of being
            # discarded (fired outside the cache lock — see cache.py —
            # so the arena's leaf lock can never invert against it)
            self.cache.subscribe_removal(self._on_cache_removal)
            if self.metrics is not None:
                for name, fn in (
                        ("cold_hits", lambda: self.cold_hits),
                        ("cold_misses", lambda: self.cold_misses),
                        ("demotions", lambda: self.demotions),
                        ("promotions", lambda: self._promoter.promotions),
                        ("warmed_users", lambda: self._warmer.warmed),
                        ("cold_users", lambda: len(self._cold)),
                        ("cold_tier_bytes",
                         lambda: self._cold.stats()["bytes"])):
                    self.metrics.gauge(name, fn)

    # -- hierarchical memory tier hooks --------------------------------------
    def _warm_stage1(self, params, feeds):
        """The warmer dispatches the engine's OWN jitted stage-1 executable
        at the live path's (1, ...) feed shapes — warmed reps are
        bit-identical to what a request would have computed."""
        return self._stage1(params, {k: v for k, v in feeds.items()
                                     if k in self._stage1_inputs})

    def _on_cache_removal(self, user_id, version, reps, reason) -> None:
        """Hot-cache removal listener: evictions demote into the cold
        arena; supersede/invalidate/clear drop any cold copy too (a stale
        version must never be re-promoted). Runs outside the cache lock."""
        if self._cold is None:
            return
        if self._cache_scope is not None and not (
                isinstance(user_id, tuple) and len(user_id) == 2
                and user_id[0] == self._cache_scope):
            return                    # another scenario's keys in a shared
            #                           cache: not this arena's layout
        if reason == EVICT:
            self._cold.put((user_id, version), reps)
            self.demotions += 1
            if self.tracer is not None:
                self.tracer.instant("demote", user=user_id)
        else:
            self._cold.drop(user_id)

    def warm(self, items, feature_version: int = 0) -> int:
        """Bulk-precompute stage-1 reps straight into the cold tier.

        ``items`` is an iterable of ``(user_id, user_feeds)`` pairs (feeds
        at leading dim 1, same dict a ``ServeRequest`` would carry); a
        warmed user's first live request is a cold hit — one arena read,
        no stage-1 recompute. Returns the number of users warmed."""
        if not self.cold_tier:
            raise RuntimeError(
                "warm() requires plan.mem.cold_tier=True (and a two-stage "
                "engine with cache_user_reps)")
        triples = [(self._scoped_uid(uid), feature_version, feeds)
                   for uid, feeds in items]
        return self._warmer.warm(triples, self.params)

    def flush_promotions(self, timeout: float | None = 10.0) -> None:
        """Block until every cold-hit touch recorded so far has been
        processed by the promotion worker (deterministic tests/benches)."""
        if self._promoter is not None:
            self._promoter.flush(timeout)

    def mem_stats(self) -> dict:
        """One snapshot of the memory hierarchy (all tiers)."""
        if not self.cold_tier:
            return {"cold_tier": False}
        return {
            "cold_tier": True,
            "cold_hits": self.cold_hits,
            "cold_misses": self.cold_misses,
            "demotions": self.demotions,
            "cold": self._cold.stats(),
            "promote": self._promoter.stats(),
            "warm": {"warmed": self._warmer.warmed,
                     "stage1_launches": self._warmer.stage1_launches},
        }

    def _on_breaker_transition(self, old: str, new: str) -> None:
        trc = self.tracer
        if trc is not None:
            trc.instant({"open": "breaker_open",
                         "half_open": "breaker_half_open",
                         "closed": "breaker_close"}[new], previous=old)

    def _poke(self, site: str, **ctx):
        """Fault-injection hook: no-op unless the plan armed an injector."""
        inj = self.fault_injector
        if inj is None:
            return None
        return inj.poke(site, **ctx)

    def _quarantine_device_tier(self, reason: str) -> None:
        """A failed donated write/fork (or detected corruption) poisons
        the current table generation: invalidate it wholesale — the slot
        map clears, slots recycle, tables rebuild lazily from the host
        LRU on the next resolve — so a stale row is never served. Counts
        as one device-tier failure toward the breaker."""
        if self._device_store is not None:
            self._device_store.quarantine(reason=reason)
        if self.breaker is not None:
            self.breaker.record_failure()

    # -- build-time compilation helpers -------------------------------------
    @staticmethod
    def _globalize(x, sharding):
        """Lift a host value onto a (possibly cross-process) mesh: every
        process passes the identical global value and contributes its
        addressable shards."""
        x = np.asarray(x)
        return jax.make_array_from_callback(x.shape, sharding,
                                            lambda idx: x[idx])

    def _build_rowwise(self, graph: Graph, exec_mode: str, use_pallas: bool):
        """Jit the row-wise batched executable:
        (params, rep_table (U, ...), user_index (B,), cand (B, ...)) -> outs.

        ``rep_table`` holds stage-1 outputs (two-stage) or raw user feeds
        (single-stage fallback); every entry is gathered per candidate row,
        so row b computes against user ``user_index[b]``'s representations.
        With ``kernel_gather`` the entries feeding a Pallas ``mari_dense``
        accumulator init skip the explicit gather — the kernel indexes the
        stacked table by ``user_index`` at accumulator-init load time, so
        the gathered (B, units) block never materializes. With
        ``gather_attention`` the same applies to the decomposed-attention
        boundary tensors (keys / u_part / T): ``kernels.gather_einsum``
        indexes the stacked tables inside the contractions.
        """
        ex = Executor(graph, exec_mode, use_pallas=use_pallas,
                      kernel_gather=self.kernel_gather,
                      gather_attention=self.gather_attention)
        lazy = self.lazy_gather_inputs = ex.lazy_gather_inputs

        def fn(params, table, user_index, cand):
            # clip: padded rows carry a synthesized index (see _run_pack);
            # clamping guarantees even a garbage value reads a real slot
            # instead of wrapping (numpy) or NaN-filling (jax default)
            gathered = {k: (v if k in lazy
                            else jnp.take(v, user_index, axis=0,
                                          mode="clip"))
                        for k, v in table.items()}
            feeds = {**gathered, **cand}
            if lazy:
                feeds[USER_INDEX_FEED] = user_index
            return ex.run(params, feeds)

        kwargs = {}
        if self._in_shardings is not None:
            kwargs = dict(in_shardings=self._in_shardings,
                          out_shardings=self._out_shardings)
        if self._donate_stage2:
            # donated bucket buffers: user_index + candidate feeds are
            # single-use transfers under the device-resident path,
            # so XLA may alias their device buffers for outputs/temporaries
            # (zero fresh allocations in steady state). params and the
            # persistent rep tables are never donated — they outlive calls.
            kwargs["donate_argnums"] = (2, 3)
        return jax.jit(fn, **kwargs)

    # -- candidate mini-batching --------------------------------------------
    def _bucket(self, n: int) -> int:
        """Smallest power-of-two bucket >= n, clamped to
        [min_bucket, max_batch] and kept a multiple of the shard count —
        every pool size maps onto a small, fixed set of compiled shapes and
        no shard receives a ragged tail (repro.dist.topology)."""
        from repro.dist.topology import bucket_for
        return bucket_for(n, self._n_shards, min_bucket=self.min_bucket,
                          max_batch=self.max_batch)

    def _chunk(self, feeds: Mapping[str, jax.Array]) -> list[tuple[dict, int]]:
        """Split a candidate pool into raw (chunk, n_valid) pieces of at most
        ``max_batch`` rows. Chunks are host numpy views — packing copies
        them straight into each pack's transfer buffers, so no per-chunk
        device arrays are ever created. Padding happens per *pack*
        (possibly shared with other users' chunks), not per chunk.

        The candidate-feed signature (names, row shapes, dtypes) is
        pinned by the first request the engine sees: the per-pack
        transfer buffers are shaped from it, and a numpy slice
        assignment would silently cast a drifting dtype (or raise on a
        trailing-shape mismatch only after earlier packs launched) — so
        drift is rejected here, before any pack of the call launches."""
        arrs = {k: np.asarray(v) for k, v in feeds.items()}
        sig = {k: (v.dtype, tuple(v.shape[1:])) for k, v in arrs.items()}
        if self._feed_sig is None:
            self._feed_sig = sig
        elif sig != self._feed_sig:
            drift = sorted(k for k in sig.keys() | self._feed_sig.keys()
                           if sig.get(k) != self._feed_sig.get(k))
            raise ValueError(
                f"candidate feed signature drifted from the engine's "
                f"first request on {drift}: expected "
                f"{ {k: self._feed_sig.get(k) for k in drift} }, got "
                f"{ {k: sig.get(k) for k in drift} } — per-engine "
                f"candidate feeds must keep stable names, row shapes "
                f"and dtypes (transfer buffers are shaped from the "
                f"first request's signature)")
        n = next(iter(arrs.values())).shape[0]
        out = []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            out.append(({k: v[lo:hi] for k, v in arrs.items()}, hi - lo))
        return out

    @property
    def stage2_compilations(self) -> int:
        """Number of compiled batched-stage executables (distinct
        (rep-table, bucket) shape pairs)."""
        try:
            return self._stage2._cache_size()
        except AttributeError:  # older/newer jax: fall back to shape count
            return len(self._batch_shapes)

    @property
    def cache_evictions(self) -> int:
        """User-rep entries dropped by the LRU bound (capacity signal)."""
        return self.cache.evictions

    @property
    def device_store(self) -> DeviceRepStore | None:
        """The device rep tier (None unless ``device_resident`` is live)."""
        return self._device_store

    # -- stage 1: user-side partial evaluation ------------------------------
    def _scoped_uid(self, user_id: Hashable) -> Hashable:
        """Namespace a user id for the (possibly shared) rep cache."""
        return (user_id if self._cache_scope is None
                else (self._cache_scope, user_id))

    def _user_reps(self, req: ServeRequest
                   ) -> tuple[Mapping[str, jax.Array], bool, float, bool]:
        key = (self._scoped_uid(req.user_id), req.feature_version)
        if self.cache_user_reps:
            reps = self.cache.get(key)
            if reps is not None:
                return reps, True, 0.0, False
            if self._cold is not None:
                creps = self._cold.get(key)
                if creps is not None:
                    # cold hit: serve straight from the arena read — no
                    # stage-1 recompute, no hot put (the async promotion
                    # worker decides residency OFF the request path, so a
                    # one-shot tail user never evicts a hot user), no
                    # device slot (cold-served packs take the re-stacking
                    # route — see _resolve_device_slots)
                    self.cold_hits += 1
                    self._promoter.touch(key)
                    return creps, False, 0.0, True
                self.cold_misses += 1
        if self.two_stage:
            self._poke("stage1", user=req.user_id)
            t0 = time.perf_counter()
            feeds = {k: v for k, v in req.user_feeds.items()
                     if k in self._stage1_inputs}
            reps = self._stage1(self.params, feeds)
            jax.block_until_ready(reps)
            self.stage1_calls += 1
            ms = (time.perf_counter() - t0) * 1e3
            self.profiler.add("stage1", ms / 1e3)
            if self.tracer is not None:
                self.tracer.complete("stage1", t0, ms / 1e3,
                                     user=req.user_id)
        else:
            # single-stage: the "representation" is the raw user feed dict
            # (never cached — cache_user_reps is forced off above: there is
            # nothing to reuse, so cache bookkeeping was pure overhead)
            reps, ms = dict(req.user_feeds), 0.0
        if self.cache_user_reps:
            self.cache.put(key, reps)
        return reps, False, ms, False

    # -- scoring ------------------------------------------------------------
    def score(self, req: ServeRequest) -> ServeResult:
        """Score one request — the U=1 degenerate case of the coalesced path
        (same executable family, hence bit-identical to batched scoring)."""
        return self.score_coalesced([req])[0]

    def score_coalesced(self, reqs: Sequence[ServeRequest]
                        ) -> list[ServeResult]:
        """Score several users' requests, coalescing candidate chunks that
        share a power-of-two bucket into single cross-user stage-2 calls.

        The call runs as a write barrier followed by a pipeline: ALL
        device-table row writes happen first (so donated table
        generations are never deleted under an in-flight executable),
        then packs are prepared-and-launched one by one — launches are
        non-blocking, so the host packs bucket k+1 while the device
        computes bucket k — and a final collect sweep blocks,
        materializes, and slices per-request views (async unpack).

        This is exactly ``collect(begin_coalesced(reqs))`` — the lockstep
        degenerate case of the two-phase API, so lockstep and continuous
        dispatch share one implementation and stay bit-identical."""
        return self.collect(self.begin_coalesced(reqs))

    def begin_coalesced(self, reqs: Sequence[ServeRequest]) -> _InFlight:
        """Phase 1 of the two-phase dispatch: stage 1 + packing + the
        table-write barrier, then launch every pack WITHOUT blocking.

        Returns an in-flight handle for ``collect``. While a handle is
        outstanding, further ``begin_coalesced`` calls overlap with it
        freely: all-resident calls (the Zipf-hot steady state) read the
        same table generation the in-flight executables hold; a call that
        needs a device-table row write arms the store's copy-on-write
        fork (``pipeline_forks``) — the write builds a NEW generation
        instead of donating the old buffer, which in-flight executables
        are still reading, so cold users cost one table copy instead of
        a pipeline drain."""
        t0 = time.perf_counter()
        trc = self.tracer
        self._group_seq += 1
        gid = self._group_seq
        g_slot, g_track = -1, None
        if trc is not None:
            # one synthetic trace track per OUTSTANDING group: the lowest
            # free slot, released at collect — two overlapped groups land
            # on two tracks, so their concurrency is visible in Perfetto
            # (begin/collect are serialized by the engine contract, so the
            # slot set needs no lock)
            g_slot = 0
            while g_slot in self._group_slots:
                g_slot += 1
            self._group_slots.add(g_slot)
            g_track = f"group:{g_slot}"
            trc.begin("group", track=g_track, group=gid, reqs=len(reqs))
        try:
            return self._begin_coalesced_body(reqs, t0, gid, g_track, g_slot)
        except BaseException:
            # close the group span on ANY failure after it opened — stage 1,
            # packing, or launch — so traces stay B/E-balanced and the
            # synthetic track slot is released for the next group
            if trc is not None:
                trc.end("group", track=g_track, group=gid, error=True)
                self._group_slots.discard(g_slot)
            raise

    def _begin_coalesced_body(self, reqs: Sequence[ServeRequest], t0: float,
                              gid: int, g_track: str | None, g_slot: int
                              ) -> _InFlight:
        prof = self.profiler
        trc = self.tracer
        infos: list[_ReqInfo] = []
        for ri, req in enumerate(reqs):
            reps, hit, s1ms, chit = self._user_reps(req)
            if trc is not None:
                self._trace_req_seq += 1
                if trc.sampled(self._trace_req_seq):
                    trc.instant("cache_hit" if hit
                                else "cold_hit" if chit else "cache_miss",
                                group=gid, user=req.user_id)
                    if not hit and not chit and self._cold is not None:
                        trc.instant("cold_miss", group=gid,
                                    user=req.user_id)
            infos.append(_ReqInfo(
                reps=reps, hit=hit, stage1_ms=s1ms, cold_hit=chit,
                chunks=self._chunk(req.candidate_feeds),
                # slot dedup follows the cache: with it on, every request
                # with one (user, version) key resolves to the SAME cached
                # reps, so they can share a rep-table slot. Without a cache
                # (incl. single-stage engines) reps are per-request values
                # with no canonical copy per key — per-request slots keep
                # coalesced == per-request bit-identity unconditionally, at
                # the cost of repeat users occupying one slot per request.
                slot_key=((req.user_id, req.feature_version)
                          if self.cache_user_reps else ri)))

        # greedy packing in arrival order: a pack holds chunks from as many
        # requests as fit the row budget and the slot budget
        items = [(ri, chunk, n) for ri, info in enumerate(infos)
                 for chunk, n in info.chunks]
        # (items w/ slot idx, slot reps, slot cache keys)
        packs: list[tuple[list, list, list]] = []
        cur: list = []
        cur_rows = 0
        cur_slots: dict = {}                   # slot_key -> slot index
        cur_reps: list = []                    # slot index -> reps
        cur_keys: list = []                    # slot index -> slot_key
        for ri, chunk, n in items:
            key = infos[ri].slot_key
            full = cur and (
                cur_rows + n > self.max_batch
                or (key not in cur_slots
                    and len(cur_slots) >= self.max_users_per_batch))
            if full:
                packs.append((cur, cur_reps, cur_keys))
                cur, cur_rows, cur_slots = [], 0, {}
                cur_reps, cur_keys = [], []
            if key not in cur_slots:
                cur_slots[key] = len(cur_reps)
                cur_reps.append(infos[ri].reps)
                cur_keys.append(key)
            cur.append((ri, cur_slots[key], chunk, n))
            cur_rows += n
        if cur:
            packs.append((cur, cur_reps, cur_keys))

        # continuous-loop write-under-flight guard: if ANY slot key of this
        # call is not already resident, the write barrier below will issue
        # a table-row write — and a DONATED write would delete the
        # generation every outstanding executable is still reading. Arm the
        # store's copy-on-write fork instead: the first write builds a new
        # generation (old buffer stays alive for the in-flight launches),
        # later writes of this call donate the unpublished fork in place.
        # All-resident calls (the Zipf-hot steady state) skip even the copy.
        cold_keys = {info.slot_key for info in infos if info.cold_hit}
        forked = False
        if self._device_store is not None and self._inflight:
            # cold-served keys never get a table-row write (their packs
            # re-stack), so they cannot trigger the fork
            keys = {info.slot_key for info in infos} - cold_keys
            if any(not self._device_store.is_live(self._scoped_uid(u), v)
                   for u, v in keys):
                self.pipeline_forks += 1
                self._device_store.fork_next_write()
                forked = True
                if trc is not None:
                    trc.instant("fork_armed", group=gid,
                                inflight=len(self._inflight))

        # write barrier: EVERY table-row write of the call happens here,
        # before any launch — in-place donated writes must never run under
        # an in-flight executable (the fork above covers the case where
        # launches ARE outstanding)
        with prof.phase("pack"):
            dslots = self._resolve_device_slots(packs, cold_keys)
        if forked:
            # the anticipated write may never have happened (e.g. every
            # pack fell back to re-stacking): a stale mark must not fork
            # some later, unrelated write
            self._device_store.clear_fork_mark()

        # pipelined prepare+launch: launches are non-blocking (unless
        # hedging owns the dispatch), so the buffer fill + transfer of
        # pack k+1 overlaps the device compute of pack k. Each pack owns
        # its transfer buffers (_prepare_pack) — pack k's host->device
        # copy may still be pending on the device stream here.
        launched = []
        try:
            for (pack_items, slot_reps, _), ds in zip(packs, dslots):
                t_pk = time.perf_counter()
                with prof.phase("pack"):
                    prep = self._prepare_pack(pack_items, slot_reps, ds)
                t_ds = time.perf_counter()
                launched.append(self._launch_pack(prep,
                                                  on_slots=ds is not None))
                if trc is not None:
                    total = sum(n for _, _, _, n in pack_items)
                    bucket = int(prep[1].shape[0])     # uidx rows
                    trc.complete(
                        "pack", t_pk, t_ds - t_pk, group=gid,
                        bucket=bucket, rows=total, pad=bucket - total,
                        users=len(slot_reps),
                        path="slots" if ds is not None else "restack")
                    trc.complete("dispatch", t_ds,
                                 time.perf_counter() - t_ds, group=gid,
                                 bucket=bucket)
        except BaseException:
            # never leave untracked launches behind: a later call's table
            # write could otherwise run under them
            for out, _, blocked in launched:
                if not blocked:
                    jax.block_until_ready(out)
            raise

        handle = _InFlight(reqs=reqs, infos=infos, packs=packs,
                           launched=launched, t0=t0, gid=gid,
                           track=g_track, slot=g_slot,
                           slots_mask=[ds is not None for ds in dslots])
        self._inflight.append(handle)
        if trc is not None:
            trc.complete("begin_coalesced", t0, time.perf_counter() - t0,
                         group=gid, reqs=len(reqs), packs=len(packs))
        return handle

    def _drain_inflight(self) -> None:
        """Block until every outstanding launch has finished executing.
        Handles stay collectible — their results are simply already
        materialized when ``collect`` runs."""
        for h in self._inflight:
            for out, _, blocked in h.launched:
                if not blocked:
                    jax.block_until_ready(out)

    def poll(self, handle: _InFlight) -> bool:
        """Non-blocking readiness probe: True when ``collect(handle)``
        would not wait on the device (every non-blocked launch's outputs
        are ready). Conservatively False on backends whose arrays expose
        no readiness — callers fall back to collecting at the blocking
        points. This is what lets the continuous loop harvest a finished
        group the moment it completes instead of holding its results
        through the next group's linger window."""
        for out, _, blocked in handle.launched:
            if blocked:
                continue
            for leaf in jax.tree_util.tree_leaves(out):
                ready = getattr(leaf, "is_ready", None)
                if ready is None or not ready():
                    return False
        return True

    def collect(self, handle: _InFlight) -> list[ServeResult]:
        """Phase 2 of the two-phase dispatch: block on the handle's
        launches, materialize scores to host, and slice per-request
        results. Handles may be collected in any order; each exactly
        once."""
        trc = self.tracer
        t0c = time.perf_counter()
        try:
            self._inflight.remove(handle)
        except ValueError:
            raise RuntimeError(
                "collect() on a handle that is not in flight (already "
                "collected, or from another engine)") from None
        try:
            return self._collect_body(handle, t0c)
        except BaseException:
            # a mid-sweep failure (injected fault, detected corruption)
            # must not leave untracked launches behind, and the group
            # trace span must close so traces stay B/E-balanced
            for out, _, blocked in handle.launched:
                if not blocked:
                    jax.block_until_ready(out)
            if trc is not None and handle.track is not None:
                trc.end("group", track=handle.track, group=handle.gid,
                        error=True)
                self._group_slots.discard(handle.slot)
            raise

    def _collect_body(self, handle: _InFlight, t0c: float
                      ) -> list[ServeResult]:
        prof = self.profiler
        trc = self.tracer
        reqs, infos, packs, launched = (handle.reqs, handle.infos,
                                        handle.packs, handle.launched)
        slots_mask = handle.slots_mask or [False] * len(packs)
        detect = self.fault_injector is not None

        # collect sweep: block on device, materialize, slice per request
        per_req_scores: list[list[np.ndarray]] = [[] for _ in reqs]
        per_req_packs = [0] * len(reqs)
        per_req_hedged = [0] * len(reqs)
        for (pack_items, _, _), (out, hedged, blocked), on_slots in zip(
                packs, launched, slots_mask):
            total = sum(n for _, _, _, n in pack_items)
            if not blocked:
                with prof.phase("device"):
                    jax.block_until_ready(out)
            act = self._poke("collect", group=handle.gid)
            with prof.phase("unpack"):
                scores = np.concatenate(
                    [np.asarray(out[o]) for o in self.outputs],
                    axis=-1)[:total]
            if act is CORRUPT:
                scores = np.full_like(scores, np.nan)
            if detect and not np.isfinite(scores).all():
                # corruption detection: NaN-poisoned payloads (injected
                # at transfer_copy / slot_write / collect) surface here —
                # the corrupted response is failed typed, never served
                self.corruptions_detected += 1
                if trc is not None:
                    trc.instant("corruption_detected", group=handle.gid,
                                path="slots" if on_slots else "restack")
                if on_slots:
                    # the device tier may hold the poisoned row: wipe the
                    # generation so a retry rebuilds from the host LRU
                    self._quarantine_device_tier(
                        "corrupted scores detected at collect")
                raise FaultInjected(
                    "corrupted stage-2 scores detected at collect",
                    site="collect")
            if on_slots and self.breaker is not None:
                self.breaker.record_success()
            touched = set()
            offset = 0
            for ri, _, _, n in pack_items:
                per_req_scores[ri].append(scores[offset:offset + n])
                offset += n
                touched.add(ri)
            for ri in touched:
                per_req_packs[ri] += 1
                per_req_hedged[ri] += hedged

        wall_ms = (time.perf_counter() - handle.t0) * 1e3
        if self._group_wall_hist is not None:
            self._group_wall_hist.record(wall_ms)
        if trc is not None:
            trc.complete("collect", t0c, time.perf_counter() - t0c,
                         group=handle.gid, packs=len(packs))
            if handle.track is not None:
                trc.end("group", track=handle.track, group=handle.gid)
                self._group_slots.discard(handle.slot)
        return [ServeResult(
            scores=np.concatenate(per_req_scores[ri], axis=0),
            latency_ms=wall_ms, n_batches=per_req_packs[ri],
            user_cache_hit=infos[ri].hit, hedged=per_req_hedged[ri],
            stage1_ms=infos[ri].stage1_ms, coalesced=len(reqs) > 1,
            cold_hit=infos[ri].cold_hit)
            for ri in range(len(reqs))]

    # -- pack preparation ----------------------------------------------------
    def _resolve_device_slots(self, packs: list,
                              cold_keys: set = frozenset()
                              ) -> list[list[int] | None]:
        """Map every pack's slot keys to device-table slots (one donated
        row write per user not already resident). ``None`` per pack when
        the device tier is off or that pack overflowed capacity — the pack
        then falls back to the re-stacking path, bit-identically.

        A user appearing under TWO feature versions in one call also
        forces every pack carrying that user onto the fallback: the
        device store keeps one slot per user, so resolving the second
        version would rewrite the slot the first version's rows read —
        within a pack (both keys collapsing to one slot) and across packs
        (a later barrier write clobbering a row an earlier pack
        references). Re-stacking keeps per-version tables, preserving
        the bit-identity contract through version bumps.

        Every device-resolved user of the CALL is protected while
        resolving: a later pack's write may never steal a slot an
        earlier (already prepared) pack still references.

        ``cold_keys`` are slot keys served from the cold tier this call:
        their packs also fall back — a cold-served (by policy, tail) user
        must not cost a device-table row write or steal a hot user's
        slot, and with no hot-cache entry there is no eviction listener
        to ever free the slot in lockstep."""
        if self._device_store is None:
            return [None] * len(packs)
        if self.breaker is not None and not self.breaker.allow():
            # breaker open: route every pack through the bit-identical
            # re-stacking fallback instead of touching the device tier;
            # after the cooldown, allow() itself flips to half-open and
            # lets probe traffic back onto the fast path
            self.fallback_packs += len(packs)
            if self.tracer is not None:
                self.tracer.instant("breaker_fallback", packs=len(packs))
            return [None] * len(packs)
        ver_of: dict = {}
        conflicted = set()
        for _, _, slot_keys in packs:
            # with the device tier live, cache_user_reps is on, so every
            # slot key is a (user_id, feature_version) cache key
            for uid, ver in slot_keys:
                if ver_of.setdefault(uid, ver) != ver:
                    conflicted.add(uid)
        per_pack = []
        protect: list = []
        for _, slot_reps, slot_keys in packs:
            if (any(uid in conflicted for uid, _ in slot_keys)
                    or (cold_keys
                        and any(k in cold_keys for k in slot_keys))):
                per_pack.append(None)
                continue
            triples = [(self._scoped_uid(uid), ver, reps)
                       for (uid, ver), reps in zip(slot_keys, slot_reps)]
            per_pack.append(triples)
            protect.extend(u for u, _, _ in triples)
        out = []
        poisoned = False
        for triples in per_pack:
            if triples is None or poisoned:
                out.append(None)
                continue
            try:
                slots = self._device_store.ensure_rows(triples,
                                                       protect=protect)
            except Exception as e:
                # a failed donated write/fork may have left the current
                # table generation inconsistent: quarantine it (slots
                # recycle, tables rebuild lazily from the host LRU) and
                # route this call's remaining packs through the
                # re-stacking fallback — the request still succeeds,
                # bit-identically, while the breaker accumulates the
                # failure
                self._quarantine_device_tier(
                    f"ensure_rows failed: {type(e).__name__}: {e}")
                poisoned = True
                out.append(None)
                continue
            out.append(slots if all(s is not None for s in slots) else None)
        return out

    def _prepare_pack(self, pack_items: list, slot_reps: list,
                      dslots: list[int] | None):
        """Assemble one stage-2 call's arguments.

        ``pack_items`` is a list of (req idx, slot idx, cand chunk,
        n_valid); ``slot_reps`` maps slot idx -> that user's rep dict;
        ``dslots`` maps slot idx -> persistent device-table slot (or None
        for the re-stacking path). Candidate rows and the user index are
        filled into a PRIVATE per-pack host buffer — padding is one
        masked tail write — then transferred. The buffer must be private:
        the host->device copy executes asynchronously on the device
        stream, behind every in-flight executable, so a shared buffer
        refilled by a later pack races the pending copy (see the transfer
        comment below)."""
        self._poke("pack")
        total = sum(n for _, _, _, n in pack_items)
        bucket = self._bucket(total)
        n_slots = len(slot_reps)

        if dslots is not None:
            # device-resident: pass the persistent (capacity, ...) tables;
            # rows address their user's live device slot directly
            table = self._device_store.tables
            u_dim = self._device_store.capacity
            slot_ids = dslots
        else:
            # re-stack a fresh table: one row-block per slot, padded to a
            # pow2 slot count so the executable family stays small
            u_dim = _next_pow2(n_slots)
            if n_slots == 1 and u_dim == 1:
                table = dict(slot_reps[0])
            else:
                padded = slot_reps + [slot_reps[0]] * (u_dim - n_slots)
                table = {k: jnp.concatenate([r[k] for r in padded], axis=0)
                         for k in slot_reps[0]}
            slot_ids = list(range(n_slots))

        sample_chunk = pack_items[0][2]
        uidx_buf = np.empty((bucket,), np.int32)
        cand_bufs = {k: np.empty((bucket,) + tuple(v.shape[1:]), v.dtype)
                     for k, v in sample_chunk.items()}
        offset = 0
        for _, slot, chunk, n in pack_items:
            uidx_buf[offset:offset + n] = slot_ids[slot]
            for k, buf in cand_bufs.items():
                buf[offset:offset + n] = chunk[k]
            offset += n
        if offset < bucket:
            # padding rows duplicate the LAST real row exactly — user slot
            # and candidate row — in one masked tail write per buffer, so
            # pad scores are copies of a real score (a cross-user slot-0 /
            # tail-candidate combination could exceed max|real score| and
            # inflate the compress_scores int8 quantization scale past the
            # verified error bound)
            uidx_buf[offset:] = uidx_buf[offset - 1]
            for buf in cand_bufs.values():
                buf[offset:] = buf[offset - 1]

        # the buffers above are PRIVATE to this pack — nothing may mutate
        # them after this point. jnp.array's owning host->device copy is
        # enqueued on the device stream and executes asynchronously,
        # behind every in-flight executable; the runtime keeps the source
        # buffer alive until then, but it cannot protect it from being
        # overwritten. A shared per-bucket staging buffer here let the
        # next same-bucket pack's refill win that race under the
        # continuous loop, silently swapping candidate rows between
        # overlapped groups (caught by the bit-identity suite). One
        # buffer allocation per pack is the price of the async dispatch.
        if self._poke("transfer_copy") is CORRUPT:
            # detectable-corruption sentinel: NaN-poison the float
            # candidate buffers — NaN propagates through the stage-2
            # matmuls into the scores and is caught at collect, so a
            # corrupted transfer is never silently served
            for buf in cand_bufs.values():
                if np.issubdtype(buf.dtype, np.floating):
                    buf.fill(np.nan)
        if self._multiproc:
            # SPMD: every process holds the identical host values; lift
            # them onto the cross-process mesh (replicated tables, sharded
            # candidate rows + index)
            repl, _, shard, _ = self._in_shardings
            table = {k: self._globalize(v, repl) for k, v in table.items()}
            cand = {k: self._globalize(v, shard)
                    for k, v in cand_bufs.items()}
            uidx_arr = self._globalize(uidx_buf, shard)
        else:
            cand = {k: jnp.array(v) for k, v in cand_bufs.items()}
            uidx_arr = jnp.array(uidx_buf)

        # first call at a new (rep-table, bucket) signature compiles — that
        # is not a straggler, so hedging would only duplicate the compile
        first_shape = (u_dim, bucket) not in self._batch_shapes
        self._batch_shapes.add((u_dim, bucket))
        return table, uidx_arr, cand, n_slots, first_shape

    # -- dispatch ------------------------------------------------------------
    def _launch_pack(self, prep, on_slots: bool = False
                     ) -> tuple[dict, int, bool]:
        """Launch one prepared pack. Returns (outputs, hedged count,
        blocked) — ``blocked`` marks results already materialized (the
        hedging path owns its own latency observation and must see final
        latencies, so it stays blocking). ``on_slots`` marks the
        device-resident fast path: a failed launch there counts toward
        the circuit breaker."""
        table, uidx_arr, cand, n_slots, first_shape = prep
        self.stage2_calls += 1
        if n_slots > 1:
            self.coalesced_calls += 1
        prof = self.profiler
        try:
            self._poke("stage2_dispatch")
        except Exception:
            if on_slots and self.breaker is not None:
                self.breaker.record_failure()
            raise
        if self._hedged is not None and not first_shape:
            with prof.phase("dispatch"):
                out, outcome = self._hedged.run(
                    self._params_s2, table, uidx_arr, cand)
            return out, int(outcome.hedged), True
        with prof.phase("dispatch"):
            try:
                out = self._execute(self._params_s2, table, uidx_arr, cand)
            except Exception:
                if on_slots and self.breaker is not None:
                    self.breaker.record_failure()
                raise
        if self._hedged is not None:
            # compile call of a hedging engine: block here (latency would
            # poison the policy window, so it is not observed either)
            with prof.phase("device"):
                jax.block_until_ready(out)
            return out, 0, True
        return out, 0, False

    def _execute(self, params, table, uidx, cand):
        """Enqueue stage 2 (+ optional compressed gather) WITHOUT blocking:
        results stay on device until the collect sweep materializes them."""
        out = self._stage2(params, table, uidx, cand)
        if self._cgather is not None:
            # opt-in int8 result collection: the only cross-shard movement
            # of the step runs quantized (repro.dist.compress)
            out = {k: self._cgather(v) for k, v in out.items()}
        return out

    def _dispatch(self, params, table, uidx, cand):
        out = self._execute(params, table, uidx, cand)
        jax.block_until_ready(out)
        return out

    def invalidate_user(self, user_id: int) -> None:
        self.cache.invalidate_user(self._scoped_uid(user_id))
        if self._cold is not None:
            # a warmed-but-never-promoted user lives ONLY in the cold
            # arena — the hot cache fires no removal listener for it
            self._cold.drop(self._scoped_uid(user_id))

    def close(self) -> None:
        # uncollected begin_coalesced launches must not outlive the engine
        self._drain_inflight()
        self._inflight.clear()
        if self._promoter is not None:
            self._promoter.stop()
        if self._hedged is not None:
            self._hedged.close()
