"""Serving runtime for ranking graphs.

Implements the inference workflow of Fig. 2: a request arrives with one
user's features and a candidate item set; the engine
  (1) optionally reuses a cached user-side representation (the one-shot
      user computation is content-addressed by user id + feature version),
  (2) splits oversized candidate pools into fixed-size mini-batches
      (padding the tail) so every call hits a pre-compiled executable,
  (3) scores under VanI / UOI / MaRI — MaRI engines hold the rewritten
      graph + re-parameterized weights from ``repro.core.mari``,
  (4) hedges straggling mini-batches per repro.ft.HedgePolicy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mari import mari_rewrite, convert_params
from repro.ft.failures import HedgePolicy
from repro.graph.executor import Executor
from repro.graph.ir import Graph


@dataclasses.dataclass
class ServeRequest:
    user_id: int
    user_feeds: Mapping[str, jax.Array]      # leading dim 1
    candidate_feeds: Mapping[str, jax.Array]  # leading dim = n_candidates


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray
    latency_ms: float
    n_batches: int
    user_cache_hit: bool
    hedged: int = 0


class ServingEngine:
    def __init__(self, graph: Graph, params: dict, *, mode: str = "mari",
                 max_batch: int = 4096, cache_user_reps: bool = True):
        if mode not in ("vani", "uoi", "mari"):
            raise ValueError(mode)
        self.mode = mode
        self.max_batch = max_batch
        if mode == "mari":
            conv = mari_rewrite(graph)
            self.graph = conv.graph
            self.params = convert_params(conv, params)
            self.conversion = conv
            exec_mode = "uoi"
        else:
            self.graph = graph
            self.params = params
            self.conversion = None
            exec_mode = mode
        self._ex = Executor(self.graph, exec_mode)
        self._step = jax.jit(self._ex.run)
        self.outputs = list(self.graph.outputs)
        self._user_inputs = [n.name for n in self.graph.input_nodes()
                             if n.attrs.get("domain") == "user"]
        self._user_cache: dict[int, Mapping[str, jax.Array]] = {}
        self.cache_user_reps = cache_user_reps
        self.hedge = HedgePolicy()

    # -- candidate mini-batching --------------------------------------------
    def _split(self, feeds: Mapping[str, jax.Array]) -> list[dict]:
        n = next(iter(feeds.values())).shape[0]
        out = []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            chunk = {k: v[lo:hi] for k, v in feeds.items()}
            if hi - lo < self.max_batch and n > self.max_batch:
                pad = self.max_batch - (hi - lo)
                chunk = {k: jnp.concatenate(
                    [v, jnp.broadcast_to(v[-1:], (pad,) + v.shape[1:])])
                    for k, v in chunk.items()}
            out.append((chunk, hi - lo))
        return out

    def score(self, req: ServeRequest) -> ServeResult:
        t0 = time.perf_counter()
        cache_hit = False
        user_feeds = dict(req.user_feeds)
        if self.cache_user_reps and req.user_id in self._user_cache:
            user_feeds = self._user_cache[req.user_id]
            cache_hit = True
        elif self.cache_user_reps:
            self._user_cache[req.user_id] = user_feeds

        chunks = self._split(req.candidate_feeds)
        scores, hedged = [], 0
        for chunk, valid in chunks:
            tb = time.perf_counter()
            out = self._step(self.params, {**user_feeds, **chunk})
            s = np.asarray(jnp.concatenate(
                [out[o] for o in self.outputs], axis=-1))[:valid]
            lat_ms = (time.perf_counter() - tb) * 1e3
            if self.hedge.should_hedge(lat_ms):
                hedged += 1  # single-host stand-in: record the decision
            self.hedge.observe(lat_ms)
            scores.append(s)
        return ServeResult(
            scores=np.concatenate(scores, axis=0),
            latency_ms=(time.perf_counter() - t0) * 1e3,
            n_batches=len(chunks), user_cache_hit=cache_hit, hedged=hedged)

    def invalidate_user(self, user_id: int) -> None:
        self._user_cache.pop(user_id, None)
