"""Serving runtime for ranking graphs.

Implements the inference workflow of Fig. 2 as a two-stage compiled
pipeline; a request arrives with one user's features and a candidate set:

  (1) **stage 1 (user-side partial evaluation)** — the user-only precompute
      subgraph (``repro.core.split``) runs at batch 1 and produces the user
      activations, the per-``mari_dense`` partials ``x_user @ w_user`` and
      the decomposed-attention one-shot tensors. Its outputs are cached per
      ``(user_id, feature_version)``: a repeat user skips the user tower
      entirely — no user-only node is re-executed.
  (2) **stage 2 (batched residual)** — the candidate-side subgraph, jitted
      separately, consumes the cached stage-1 outputs as batch-1 inputs.
      Candidate pools are split into power-of-two *batch buckets* (tail
      padded up), so every pool size hits one of at most
      log2(max_batch / min_bucket) + 1 pre-compiled executables instead of
      recompiling per distinct size.
  (3) modes: VanI / UOI / MaRI — MaRI engines hold the rewritten graph +
      re-parameterized weights from ``repro.core.mari``; ``use_pallas``
      routes each ``mari_dense`` through the fused Pallas kernel
      (interpret mode off-TPU).
  (4) straggling mini-batches are hedged per repro.ft.HedgePolicy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mari import mari_rewrite, convert_params
from repro.core.split import split_two_stage
from repro.ft.failures import HedgePolicy
from repro.graph.executor import Executor
from repro.graph.ir import Graph


@dataclasses.dataclass
class ServeRequest:
    user_id: int
    user_feeds: Mapping[str, jax.Array]      # leading dim 1
    candidate_feeds: Mapping[str, jax.Array]  # leading dim = n_candidates
    feature_version: int = 0                 # bump to invalidate cached reps


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray
    latency_ms: float
    n_batches: int
    user_cache_hit: bool
    hedged: int = 0
    stage1_ms: float = 0.0                   # 0 when cached / single-stage


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ServingEngine:
    def __init__(self, graph: Graph, params: dict, *, mode: str = "mari",
                 max_batch: int = 4096, cache_user_reps: bool = True,
                 two_stage: bool | None = None, min_bucket: int = 128,
                 use_pallas: bool = False, reparam_attention: bool = False):
        if mode not in ("vani", "uoi", "mari"):
            raise ValueError(mode)
        self.mode = mode
        self.max_batch = max_batch
        self.min_bucket = min(min_bucket, max_batch)
        if mode == "mari":
            conv = mari_rewrite(graph, reparam_attention=reparam_attention)
            self.graph = conv.graph
            self.params = convert_params(conv, params)
            self.conversion = conv
            exec_mode = "uoi"
        else:
            self.graph = graph
            self.params = params
            self.conversion = None
            exec_mode = mode
        # vani tiles user feeds into the batch — there is no user-only
        # subgraph to peel, so it stays single-stage.
        self.two_stage = (exec_mode == "uoi") if two_stage is None else two_stage
        self.outputs = list(self.graph.outputs)
        self._user_inputs = [n.name for n in self.graph.input_nodes()
                             if n.attrs.get("domain") == "user"]
        if self.two_stage:
            split = split_two_stage(self.graph)
            # The request contract partitions feeds by domain: user_feeds
            # carries exactly the domain=="user" inputs. A stage-1 input
            # outside that set (an uncolored, domain-less input pulled into
            # the user closure) could never be fed, so the split is not
            # servable for this graph.
            unservable = [n.name for n in split.stage1.input_nodes()
                          if n.attrs.get("domain") != "user"]
            if unservable and two_stage:
                raise ValueError(
                    f"two_stage=True but stage-1 needs non-user feeds "
                    f"{unservable}; give these inputs domain='user' or "
                    f"serve single-stage")
            if unservable:
                self.two_stage = False
        if self.two_stage:
            self.split = split
            self._stage1 = jax.jit(
                Executor(self.split.stage1, "uoi").run)
            self._stage2 = jax.jit(
                Executor(self.split.stage2, "uoi", use_pallas=use_pallas).run)
            self._stage1_inputs = {
                n.name for n in self.split.stage1.input_nodes()}
            self._step = None
        else:
            self.split = None
            self._stage1 = self._stage2 = None
            ex = Executor(self.graph, exec_mode, use_pallas=use_pallas)
            self._step = jax.jit(ex.run)
        self.stage1_calls = 0                 # trace counter for the split test
        self._batch_shapes: set[int] = set()  # distinct bucketed chunk sizes
        self._user_cache: dict[tuple[int, int], Mapping[str, jax.Array]] = {}
        self.cache_user_reps = cache_user_reps
        self.hedge = HedgePolicy()

    # -- candidate mini-batching --------------------------------------------
    def _bucket(self, n: int) -> int:
        """Smallest power-of-two bucket >= n, clamped to
        [min_bucket, max_batch] — every pool size maps onto a small, fixed
        set of compiled shapes."""
        return min(self.max_batch, _next_pow2(max(n, self.min_bucket)))

    def _split(self, feeds: Mapping[str, jax.Array]) -> list[tuple[dict, int]]:
        n = next(iter(feeds.values())).shape[0]
        out = []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            chunk = {k: v[lo:hi] for k, v in feeds.items()}
            bucket = self._bucket(hi - lo)
            if hi - lo < bucket:
                pad = bucket - (hi - lo)
                chunk = {k: jnp.concatenate(
                    [v, jnp.broadcast_to(v[-1:], (pad,) + v.shape[1:])])
                    for k, v in chunk.items()}
            self._batch_shapes.add(bucket)
            out.append((chunk, hi - lo))
        return out

    @property
    def stage2_compilations(self) -> int:
        """Number of compiled batched-stage executables (distinct buckets)."""
        fn = self._stage2 if self.two_stage else self._step
        try:
            return fn._cache_size()
        except AttributeError:  # older/newer jax: fall back to shape count
            return len(self._batch_shapes)

    def _cache_put(self, key: tuple[int, int], reps: Mapping) -> None:
        """One live entry per user: a new feature_version supersedes (and
        frees) older versions."""
        for stale in [k for k in self._user_cache
                      if k[0] == key[0] and k != key]:
            del self._user_cache[stale]
        self._user_cache[key] = reps

    # -- stage 1: user-side partial evaluation ------------------------------
    def _user_reps(self, req: ServeRequest) -> tuple[Mapping, bool, float]:
        key = (req.user_id, req.feature_version)
        if self.cache_user_reps and key in self._user_cache:
            return self._user_cache[key], True, 0.0
        t0 = time.perf_counter()
        feeds = {k: v for k, v in req.user_feeds.items()
                 if k in self._stage1_inputs}
        reps = self._stage1(self.params, feeds)
        jax.block_until_ready(reps)
        self.stage1_calls += 1
        ms = (time.perf_counter() - t0) * 1e3
        if self.cache_user_reps:
            self._cache_put(key, reps)
        return reps, False, ms

    def score(self, req: ServeRequest) -> ServeResult:
        t0 = time.perf_counter()
        stage1_ms = 0.0
        if self.two_stage:
            base_feeds, cache_hit, stage1_ms = self._user_reps(req)
            step = self._stage2
        else:
            cache_hit = False
            base_feeds = dict(req.user_feeds)
            key = (req.user_id, req.feature_version)
            if self.cache_user_reps and key in self._user_cache:
                base_feeds = self._user_cache[key]
                cache_hit = True
            elif self.cache_user_reps:
                self._cache_put(key, base_feeds)
            step = self._step

        chunks = self._split(req.candidate_feeds)
        scores, hedged = [], 0
        for chunk, valid in chunks:
            tb = time.perf_counter()
            out = step(self.params, {**base_feeds, **chunk})
            s = np.asarray(jnp.concatenate(
                [out[o] for o in self.outputs], axis=-1))[:valid]
            lat_ms = (time.perf_counter() - tb) * 1e3
            if self.hedge.should_hedge(lat_ms):
                hedged += 1  # single-host stand-in: record the decision
            self.hedge.observe(lat_ms)
            scores.append(s)
        return ServeResult(
            scores=np.concatenate(scores, axis=0),
            latency_ms=(time.perf_counter() - t0) * 1e3,
            n_batches=len(chunks), user_cache_hit=cache_hit, hedged=hedged,
            stage1_ms=stage1_ms)

    def invalidate_user(self, user_id: int) -> None:
        for key in [k for k in self._user_cache if k[0] == user_id]:
            self._user_cache.pop(key, None)
