"""``ServePlan`` — the declarative, serializable serving configuration.

Four PRs of serving-scale work grew ``ServingEngine.__init__`` into ~16
accreted boolean/kwarg knobs that every entry point re-threaded by hand,
with invalid combinations failing late or silently no-oping. ``ServePlan``
replaces that flag soup with ONE frozen, validated, JSON-serializable
config object — the spine every entry point (``launch/serve.py``,
``dist/runner.py``, ``benchmarks/run.py``, examples, ``RankingService``)
shares, and the surface future scale-out PRs extend.

Sections (each its own frozen dataclass):

* ``GraphPlan``  — inference paradigm + MaRI rewrite shape: ``mode``
  (vani/uoi/mari), ``reparam_attention``, ``fragment``,
  ``group_by_domain``, ``two_stage``;
* ``KernelPlan`` — Pallas dispatch: ``use_pallas``, ``kernel_gather``,
  ``gather_attention``, ``precat_weights``;
* ``BatchPlan``  — bucketing/coalescing/SLO/hedging plus the continuous
  dispatch loop and admission control: ``max_batch``, ``min_bucket``,
  ``max_users_per_batch``, ``hedging``, ``linger_ms``, ``max_coalesce``,
  ``deadline_linger_frac``, ``continuous``, ``max_inflight``,
  ``admission``, ``shed_queue_depth``, ``degrade_queue_depth``,
  ``degrade_frac``, ``deadline_headroom_ms``;
* ``ShardPlan``  — candidate-axis sharding: ``shard_candidates``
  (False / True / shard count), ``compress_scores``;
* ``CachePlan``  — user-rep store: ``cache_user_reps``,
  ``max_cached_users``, ``device_resident`` (persistent slot-allocated
  device rep tables + donated stage-2 buffers), ``device_slots``;
* ``ObsPlan``    — observability (``repro.obs``): ``trace`` (ring-buffer
  request/group tracing, off by default), ``trace_capacity``,
  ``sample_every`` (per-request event thinning), ``metrics``
  (log-bucketed latency/queue-wait histograms + unified counter
  snapshot);
* ``MemPlan``    — hierarchical memory tier (``repro.mem``): ``cold_tier``
  (host-RAM slab arena under the hot LRU — eviction demotes instead of
  discarding, off by default), ``cold_bytes`` (arena byte budget),
  ``promote_touches`` / ``promote_window_s`` (async cold->hot promotion
  requires k touches within a sliding window — Zipf tail users never
  thrash the hot/device tiers), ``warm_batch`` (chunk size of the bulk
  offline ``warm()`` feed into the cold arena);
* ``FaultPlan``  — fault tolerance (``repro.ft``, section key ``ft``):
  ``inject`` + ``seed`` + ``sites`` (deterministic fault injection,
  off by default — each site spec is ``site:kind[:k=v,...]``, see
  ``repro.ft.faults``), ``retries`` / ``retry_backoff_ms`` /
  ``retry_jitter`` (per-request retry with exponential backoff bounded
  by the remaining deadline budget), ``breaker_failures`` /
  ``breaker_cooldown_ms`` / ``breaker_probes`` (circuit breaker on the
  stage-2 device-resident fast path; open routes packs through the
  bit-identical re-stacking fallback).

Validation happens AT CONSTRUCTION — an invalid combination is either
rejected (``PlanError``) or auto-resolved with a ``PlanResolutionWarning``
naming the documented resolution. The resolution table:

====================================================  =======================
combination                                           resolution
====================================================  =======================
``mode`` outside vani/uoi/mari                        reject (``PlanError``)
``compress_scores`` without ``shard_candidates``      reject — the int8 wire
                                                      IS the cross-shard
                                                      score gather
``two_stage=True`` with ``mode="vani"``               reject — vani tiles
                                                      user feeds into the
                                                      batch; there is no
                                                      user-only stage
non-positive ``max_batch`` / ``min_bucket`` /         reject
``max_users_per_batch`` / ``max_coalesce`` /
``max_cached_users`` / ``device_slots`` /
``max_inflight`` / ``shed_queue_depth`` /
``degrade_queue_depth``; negative
``linger_ms`` / ``deadline_headroom_ms`` /
shard count; ``deadline_linger_frac`` outside
[0, 1]; ``degrade_frac`` outside (0, 1]
``degrade_queue_depth > shed_queue_depth``            reject — requests
(both set)                                            would be shed outright
                                                      before the cheaper
                                                      degrade tier ever
                                                      engaged
admission thresholds (``shed_queue_depth`` /          drop them + warn (the
``degrade_queue_depth`` / positive                    controller only runs
``deadline_headroom_ms``) without                     with ``admission=
``admission=True``                                    True``)
``device_resident`` without ``cache_user_reps``       drop
                                                      ``device_resident``
                                                      + warn (the device
                                                      tier mirrors cached
                                                      reps; with no cache
                                                      there is nothing to
                                                      keep resident)
``device_resident`` with ``hedging``                  drop ``hedging`` +
                                                      warn — hedged
                                                      duplicates replay
                                                      arguments the donated
                                                      stage-2 buffers have
                                                      already consumed
``device_slots`` without ``device_resident``          drop ``device_slots``
                                                      + warn (it sizes the
                                                      device tier only)
``kernel_gather`` without ``use_pallas``              drop ``kernel_gather``
                                                      + warn (the rep-table
                                                      gather only exists
                                                      inside Pallas
                                                      ``mari_matmul``)
``gather_attention`` without decomposed attention     drop
(``mode!="mari"`` or no ``reparam_attention``)        ``gather_attention``
                                                      + warn
``reparam_attention``/``fragment``/                   drop them + warn (they
``group_by_domain`` with ``mode != "mari"``           parameterize the MaRI
                                                      rewrite only)
``min_bucket > max_batch``                            clamp ``min_bucket``
                                                      to ``max_batch``
                                                      (silent normalization
                                                      — same contract the
                                                      engine always had)
non-positive ``trace_capacity`` / ``sample_every``    reject
non-positive ``mem.cold_bytes`` /                     reject
``mem.promote_touches`` / ``mem.promote_window_s``
/ ``mem.warm_batch``
``mem.cold_tier`` without ``cache.cache_user_reps``   drop ``cold_tier`` +
                                                      warn — the cold tier
                                                      catches hot-LRU
                                                      evictions and feeds
                                                      promotions back into
                                                      the hot cache; with
                                                      no hot cache there is
                                                      nothing to demote
                                                      from or promote into
``mem.cold_bytes`` / ``promote_touches`` /            drop them + warn (they
``promote_window_s`` / ``warm_batch``                 parameterize the cold
(non-default) without ``mem.cold_tier``               tier only)
``trace_capacity`` / ``sample_every != 1`` without    drop them + warn (they
``trace=True``                                        parameterize the
                                                      tracer only)
malformed ``ft.sites`` spec (unknown site/kind/       reject — a typo'd
param, bad value)                                     chaos schedule must
                                                      fail at construction,
                                                      not mid-run
negative ``ft.retries`` / ``ft.retry_backoff_ms``     reject
/ ``ft.breaker_failures`` /
``ft.breaker_cooldown_ms``; ``ft.retry_jitter``
outside [0, 1]; ``ft.breaker_probes < 1``
``ft.sites`` / ``ft.seed`` without                    drop them + warn (the
``ft.inject=True``                                    injector only arms
                                                      when inject is on)
``ft.retry_backoff_ms`` / ``ft.retry_jitter``         drop them + warn (they
(non-default) without ``ft.retries > 0``              shape the retry
                                                      schedule only)
``ft.breaker_failures > 0`` without                   drop breaker + warn —
``cache.device_resident``                             the breaker guards the
                                                      device-resident fast
                                                      path; with no device
                                                      tier every pack
                                                      already re-stacks
``ft.breaker_cooldown_ms`` / ``ft.breaker_probes``    drop them + warn (they
(non-default) without ``ft.breaker_failures > 0``     parameterize the
                                                      breaker only)
====================================================  =======================

Round-trip: ``ServePlan.from_json(plan.to_json()) == plan``. Named presets
(``ServePlan.preset("paper")`` …) capture the serving shapes the repo's
benchmarks and recipes use. ``plan.evolve(graph__mode="uoi", ...)``
derives a new plan with section fields replaced (double-underscore
addresses ``<section>__<field>``).

Runtime-dependent interactions stay in the engine: a multi-process 'cand'
mesh forces ``hedging`` off (per-process duplicate execution would
desynchronize the SPMD collective schedule), and a sharded engine rounds
``max_batch`` down to a shard-divisible power of two — both depend on the
device world at construction time, which a serialized plan cannot know.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any, Mapping

from repro.ft.faults import parse_fault_spec

MODES = ("vani", "uoi", "mari")


class PlanError(ValueError):
    """An invalid ``ServePlan`` combination that cannot be auto-resolved."""


class PlanResolutionWarning(UserWarning):
    """An invalid combination was auto-resolved per the resolution table."""


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    """Inference paradigm and MaRI-rewrite shape."""
    mode: str = "mari"                 # "vani" | "uoi" | "mari"
    reparam_attention: bool = False    # mari: decompose eligible attention
    fragment: bool = False             # mari: fragmented-layout rewrite
    group_by_domain: bool = False      # mari: group weight blocks by domain
    two_stage: bool | None = None      # None = infer (uoi/mari split)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Pallas kernel dispatch (interpret mode off-TPU)."""
    use_pallas: bool = False           # fused mari_dense / gather_einsum
    kernel_gather: bool = False        # rep-table gather at acc-init load
    gather_attention: bool = False     # gather-at-load attention boundaries
    precat_weights: bool = True        # build-time grouped-weight concat


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Bucketing, cross-user coalescing, SLO linger, hedging, the
    continuous dispatch loop, and SLO-tiered admission control."""
    max_batch: int = 4096              # stage-2 row budget per dispatch
    min_bucket: int = 128              # smallest pow2 candidate bucket
    max_users_per_batch: int = 8       # rep-table slot budget per pack
    hedging: bool = True               # duplicate straggling dispatches
    linger_ms: float = 2.0             # batcher window for co-arrivals
    max_coalesce: int = 64             # request budget per batcher group
    deadline_linger_frac: float = 0.25  # linger shrink for deadline SLO
    continuous: bool = True            # pack group k+1 while k executes
    max_inflight: int = 2              # launched-but-uncollected groups
    admission: bool = False            # SLO-tiered admission controller
    shed_queue_depth: int | None = None    # best_effort shed threshold
    degrade_queue_depth: int | None = None  # best_effort degrade threshold
    degrade_frac: float = 0.5          # candidate fraction kept on degrade
    deadline_headroom_ms: float = 0.0  # shed infeasible deadline budgets


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Candidate-axis sharding on the ``repro.dist`` 'cand' mesh."""
    shard_candidates: bool | int = False   # False | True (all) | shard count
    compress_scores: bool = False          # int8 cross-shard score gather


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """Bounded LRU user-representation store + optional device tier."""
    cache_user_reps: bool = True
    max_cached_users: int | None = None    # None = unbounded
    device_resident: bool = False          # persistent device rep tables +
    #                                        donated stage-2 buffers
    device_slots: int | None = None        # device-tier capacity; None =
    #                                        max_cached_users (or 64)


@dataclasses.dataclass(frozen=True)
class ObsPlan:
    """Observability: request/group tracing + histogram metrics
    (``repro.obs``)."""
    trace: bool = False                # ring-buffer span/instant tracing
    trace_capacity: int | None = None  # ring size; None = obs default
    sample_every: int = 1              # trace every Nth request's events
    metrics: bool = True               # latency/queue-wait histograms +
    #                                    unified counter snapshot()


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Fault tolerance: deterministic injection + self-healing recovery
    (``repro.ft``)."""
    inject: bool = False               # arm the fault injector
    seed: int = 0                      # per-site deterministic RNG seed
    sites: tuple = ()                  # "site:kind[:k=v,...]" spec strings
    retries: int = 0                   # per-request retry budget (0 = off)
    retry_backoff_ms: float = 1.0      # attempt k sleeps backoff * 2**k
    retry_jitter: float = 0.5          # multiplicative jitter in [0, 1]
    breaker_failures: int = 0          # consecutive device-tier failures
    #                                    that open the breaker (0 = off)
    breaker_cooldown_ms: float = 100.0  # open -> half-open wait
    breaker_probes: int = 1            # half-open successes to close


@dataclasses.dataclass(frozen=True)
class MemPlan:
    """Hierarchical memory tier: host-RAM cold store + async promotion +
    bulk warming (``repro.mem``)."""
    cold_tier: bool = False            # arm the host-RAM cold rep arena
    cold_bytes: int = 1 << 28          # arena byte budget (256 MiB)
    promote_touches: int = 2           # cold hits needed to promote ...
    promote_window_s: float = 60.0     # ... within this sliding window
    warm_batch: int = 256              # bulk-warm chunk between dev syncs


_SECTIONS: dict[str, type] = {"graph": GraphPlan, "kernel": KernelPlan,
                              "batch": BatchPlan, "shard": ShardPlan,
                              "cache": CachePlan, "obs": ObsPlan,
                              "mem": MemPlan, "ft": FaultPlan}

# legacy ServingEngine kwarg -> (section, field). The shim in
# ``ServingEngine.__init__`` routes deprecated keyword construction here.
_LEGACY_KWARGS: dict[str, tuple[str, str]] = {
    "mode": ("graph", "mode"),
    "reparam_attention": ("graph", "reparam_attention"),
    "fragment": ("graph", "fragment"),
    "group_by_domain": ("graph", "group_by_domain"),
    "two_stage": ("graph", "two_stage"),
    "use_pallas": ("kernel", "use_pallas"),
    "kernel_gather": ("kernel", "kernel_gather"),
    "gather_attention": ("kernel", "gather_attention"),
    "precat_weights": ("kernel", "precat_weights"),
    "max_batch": ("batch", "max_batch"),
    "min_bucket": ("batch", "min_bucket"),
    "max_users_per_batch": ("batch", "max_users_per_batch"),
    "hedging": ("batch", "hedging"),
    "shard_candidates": ("shard", "shard_candidates"),
    "compress_scores": ("shard", "compress_scores"),
    "cache_user_reps": ("cache", "cache_user_reps"),
    "max_cached_users": ("cache", "max_cached_users"),
}


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise PlanError(msg)


# per-field type contracts, checked BEFORE the range/combination rules so a
# hand-edited plan file with a wrong-typed scalar (e.g. a quoted number)
# fails with the documented PlanError, not a bare TypeError. A trailing "?"
# allows None; "int" excludes bool (True is not a row budget).
_FIELD_TYPES: dict[str, dict[str, str]] = {
    "graph": {"mode": "str", "reparam_attention": "bool",
              "fragment": "bool", "group_by_domain": "bool",
              "two_stage": "bool?"},
    "kernel": {"use_pallas": "bool", "kernel_gather": "bool",
               "gather_attention": "bool", "precat_weights": "bool"},
    "batch": {"max_batch": "int", "min_bucket": "int",
              "max_users_per_batch": "int", "hedging": "bool",
              "linger_ms": "num", "max_coalesce": "int",
              "deadline_linger_frac": "num", "continuous": "bool",
              "max_inflight": "int", "admission": "bool",
              "shed_queue_depth": "int?", "degrade_queue_depth": "int?",
              "degrade_frac": "num", "deadline_headroom_ms": "num"},
    "shard": {"shard_candidates": "bool_or_int", "compress_scores": "bool"},
    "cache": {"cache_user_reps": "bool", "max_cached_users": "int?",
              "device_resident": "bool", "device_slots": "int?"},
    "obs": {"trace": "bool", "trace_capacity": "int?",
            "sample_every": "int", "metrics": "bool"},
    "mem": {"cold_tier": "bool", "cold_bytes": "int",
            "promote_touches": "int", "promote_window_s": "num",
            "warm_batch": "int"},
    "ft": {"inject": "bool", "seed": "int", "sites": "strs",
           "retries": "int", "retry_backoff_ms": "num",
           "retry_jitter": "num", "breaker_failures": "int",
           "breaker_cooldown_ms": "num", "breaker_probes": "int"},
}


def _type_ok(kind: str, v: Any) -> bool:
    if kind.endswith("?"):
        if v is None:
            return True
        kind = kind[:-1]
    if kind == "str":
        return isinstance(v, str)
    if kind == "bool":
        return isinstance(v, bool)
    if kind == "int":
        return isinstance(v, int) and not isinstance(v, bool)
    if kind == "num":
        return isinstance(v, (int, float)) and not isinstance(v, bool)
    if kind == "bool_or_int":
        return isinstance(v, int)          # bool is a subtype of int
    if kind == "strs":                     # tuple of str (lists were
        return (isinstance(v, tuple)       # normalized before this check)
                and all(isinstance(x, str) for x in v))
    raise AssertionError(kind)


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Frozen, validated, JSON-serializable serving configuration.

    Construction validates cross-field combinations per the module
    docstring's resolution table: contradictions raise ``PlanError``;
    resolvable combos are rewritten with a ``PlanResolutionWarning`` (the
    resolved plan is what ``to_json`` serializes, so resolution is
    idempotent and round-trips cleanly). Sections may be given as dicts —
    ``ServePlan(graph={"mode": "uoi"})`` — which ``from_json`` relies on.
    """
    graph: GraphPlan = GraphPlan()
    kernel: KernelPlan = KernelPlan()
    batch: BatchPlan = BatchPlan()
    shard: ShardPlan = ShardPlan()
    cache: CachePlan = CachePlan()
    obs: ObsPlan = ObsPlan()
    mem: MemPlan = MemPlan()
    ft: FaultPlan = FaultPlan()

    # -- validation ---------------------------------------------------------
    def __post_init__(self):
        for name, cls in _SECTIONS.items():
            v = getattr(self, name)
            if isinstance(v, Mapping):
                unknown = set(v) - {f.name for f in dataclasses.fields(cls)}
                _require(not unknown,
                         f"unknown {name}-plan fields {sorted(unknown)}; "
                         f"known: {[f.name for f in dataclasses.fields(cls)]}")
                object.__setattr__(self, name, cls(**v))
            elif not isinstance(v, cls):
                raise PlanError(
                    f"plan section {name!r} must be a {cls.__name__} or a "
                    f"dict, got {type(v).__name__}")
        # JSON carries tuples as lists: normalize ft.sites before the type
        # check so a round-tripped plan compares equal to the original
        if isinstance(self.ft.sites, list):
            object.__setattr__(
                self, "ft",
                dataclasses.replace(self.ft, sites=tuple(self.ft.sites)))
        for name, fields in _FIELD_TYPES.items():
            section = getattr(self, name)
            for field, kind in fields.items():
                v = getattr(section, field)
                _require(_type_ok(kind, v),
                         f"{name}.{field} must be {kind.rstrip('?')}"
                         f"{' or None' if kind.endswith('?') else ''}, "
                         f"got {type(v).__name__} ({v!r})")
        g, k, b, s, c, o, m, f = (self.graph, self.kernel, self.batch,
                                  self.shard, self.cache, self.obs,
                                  self.mem, self.ft)

        # hard errors: contradictions with no meaningful resolution
        _require(g.mode in MODES,
                 f"unknown mode {g.mode!r}; known: {list(MODES)}")
        _require(not (g.two_stage is True and g.mode == "vani"),
                 "two_stage=True with mode='vani': vani tiles user feeds "
                 "into the candidate batch — there is no user-only stage to "
                 "precompute; drop two_stage or pick uoi/mari")
        _require(b.max_batch >= 1, f"max_batch must be >= 1, got "
                 f"{b.max_batch}")
        _require(b.min_bucket >= 1, f"min_bucket must be >= 1, got "
                 f"{b.min_bucket}")
        _require(b.max_users_per_batch >= 1,
                 f"max_users_per_batch must be >= 1, got "
                 f"{b.max_users_per_batch}")
        _require(b.max_coalesce >= 1,
                 f"max_coalesce must be >= 1, got {b.max_coalesce}")
        _require(b.linger_ms >= 0, f"linger_ms must be >= 0, got "
                 f"{b.linger_ms}")
        _require(0.0 <= b.deadline_linger_frac <= 1.0,
                 f"deadline_linger_frac must be in [0, 1], got "
                 f"{b.deadline_linger_frac}")
        _require(b.max_inflight >= 1,
                 f"max_inflight must be >= 1, got {b.max_inflight}")
        _require(b.shed_queue_depth is None or b.shed_queue_depth >= 1,
                 f"shed_queue_depth must be >= 1 (or None for no shedding), "
                 f"got {b.shed_queue_depth}")
        _require(b.degrade_queue_depth is None or b.degrade_queue_depth >= 1,
                 f"degrade_queue_depth must be >= 1 (or None for no "
                 f"degrading), got {b.degrade_queue_depth}")
        _require(0.0 < b.degrade_frac <= 1.0,
                 f"degrade_frac must be in (0, 1], got {b.degrade_frac}")
        _require(b.deadline_headroom_ms >= 0,
                 f"deadline_headroom_ms must be >= 0, got "
                 f"{b.deadline_headroom_ms}")
        _require(not (b.shed_queue_depth is not None
                      and b.degrade_queue_depth is not None
                      and b.degrade_queue_depth > b.shed_queue_depth),
                 f"degrade_queue_depth ({b.degrade_queue_depth}) > "
                 f"shed_queue_depth ({b.shed_queue_depth}): requests would "
                 f"be shed outright before the cheaper degrade tier ever "
                 f"engaged — order the thresholds degrade <= shed")
        _require(not (isinstance(s.shard_candidates, int)
                      and not isinstance(s.shard_candidates, bool)
                      and s.shard_candidates < 0),
                 f"shard_candidates count must be >= 0, got "
                 f"{s.shard_candidates}")
        _require(not (s.compress_scores and not s.shard_candidates),
                 "compress_scores is the int8 cross-shard score gather — it "
                 "requires shard_candidates")
        _require(c.max_cached_users is None or c.max_cached_users >= 1,
                 f"max_cached_users must be >= 1 (or None for unbounded), "
                 f"got {c.max_cached_users}")
        _require(c.device_slots is None or c.device_slots >= 1,
                 f"device_slots must be >= 1 (or None to follow "
                 f"max_cached_users), got {c.device_slots}")
        _require(o.trace_capacity is None or o.trace_capacity >= 1,
                 f"trace_capacity must be >= 1 (or None for the obs "
                 f"default), got {o.trace_capacity}")
        _require(o.sample_every >= 1,
                 f"sample_every must be >= 1, got {o.sample_every}")
        _require(m.cold_bytes >= 1,
                 f"mem.cold_bytes must be >= 1, got {m.cold_bytes}")
        _require(m.promote_touches >= 1,
                 f"mem.promote_touches must be >= 1, got "
                 f"{m.promote_touches}")
        _require(m.promote_window_s > 0,
                 f"mem.promote_window_s must be > 0, got "
                 f"{m.promote_window_s}")
        _require(m.warm_batch >= 1,
                 f"mem.warm_batch must be >= 1, got {m.warm_batch}")
        _require(f.retries >= 0, f"retries must be >= 0, got {f.retries}")
        _require(f.retry_backoff_ms >= 0,
                 f"retry_backoff_ms must be >= 0, got {f.retry_backoff_ms}")
        _require(0.0 <= f.retry_jitter <= 1.0,
                 f"retry_jitter must be in [0, 1], got {f.retry_jitter}")
        _require(f.breaker_failures >= 0,
                 f"breaker_failures must be >= 0 (0 disables the breaker), "
                 f"got {f.breaker_failures}")
        _require(f.breaker_cooldown_ms >= 0,
                 f"breaker_cooldown_ms must be >= 0, got "
                 f"{f.breaker_cooldown_ms}")
        _require(f.breaker_probes >= 1,
                 f"breaker_probes must be >= 1, got {f.breaker_probes}")
        for spec in f.sites:
            try:
                parse_fault_spec(spec)
            except ValueError as e:
                raise PlanError(f"invalid ft.sites spec {spec!r}: {e}") \
                    from None

        # auto-resolutions: drop the no-op knob and say why (the previously
        # SILENT combos of the pre-plan engine)
        notes = []
        if k.kernel_gather and not k.use_pallas:
            notes.append(
                "kernel_gather without use_pallas: the rep-table gather at "
                "accumulator-init load only exists inside the Pallas "
                "mari_matmul — resolved to kernel_gather=False (set "
                "use_pallas=True to keep it)")
            object.__setattr__(self, "kernel",
                               dataclasses.replace(self.kernel,
                                                   kernel_gather=False))
        if k.gather_attention and not (g.mode == "mari"
                                       and g.reparam_attention):
            notes.append(
                "gather_attention without decomposed attention (needs "
                "mode='mari' AND reparam_attention=True): there are no "
                "stacked attention boundary tables to gather from — "
                "resolved to gather_attention=False")
            object.__setattr__(self, "kernel",
                               dataclasses.replace(self.kernel,
                                                   gather_attention=False))
        rewrite_knobs = [n for n in ("reparam_attention", "fragment",
                                     "group_by_domain")
                         if getattr(g, n)]
        if rewrite_knobs and g.mode != "mari":
            notes.append(
                f"{'/'.join(rewrite_knobs)} with mode={g.mode!r}: these "
                f"parameterize the MaRI rewrite, which only runs under "
                f"mode='mari' — resolved to False")
            object.__setattr__(
                self, "graph",
                dataclasses.replace(self.graph,
                                    **{n: False for n in rewrite_knobs}))
        adm_knobs = [n for n, v in
                     (("shed_queue_depth", b.shed_queue_depth),
                      ("degrade_queue_depth", b.degrade_queue_depth),
                      ("deadline_headroom_ms",
                       b.deadline_headroom_ms or None))
                     if v is not None]
        if adm_knobs and not b.admission:
            notes.append(
                f"{'/'.join(adm_knobs)} without admission=True: the "
                f"admission controller only runs when admission is enabled "
                f"— resolved to defaults (set admission=True to keep them)")
            object.__setattr__(
                self, "batch",
                dataclasses.replace(self.batch, shed_queue_depth=None,
                                    degrade_queue_depth=None,
                                    deadline_headroom_ms=0.0))
            b = self.batch
        if c.device_resident and not c.cache_user_reps:
            notes.append(
                "device_resident without cache_user_reps: the device tier "
                "mirrors cached stage-1 reps — with caching off there is "
                "nothing to keep resident; resolved to device_resident="
                "False")
            object.__setattr__(self, "cache",
                               dataclasses.replace(self.cache,
                                                   device_resident=False))
            c = self.cache
        if c.device_resident and b.hedging:
            notes.append(
                "device_resident with hedging: hedged duplicates replay "
                "arguments that the donated stage-2 buffers have already "
                "consumed — resolved to hedging=False")
            object.__setattr__(self, "batch",
                               dataclasses.replace(self.batch,
                                                   hedging=False))
            b = self.batch
        if c.device_slots is not None and not c.device_resident:
            notes.append(
                "device_slots without device_resident: it sizes the device "
                "rep tier only — resolved to device_slots=None")
            object.__setattr__(self, "cache",
                               dataclasses.replace(self.cache,
                                                   device_slots=None))
            c = self.cache
        if m.cold_tier and not c.cache_user_reps:
            notes.append(
                "mem.cold_tier without cache.cache_user_reps: the cold tier "
                "catches hot-LRU evictions and feeds promotions back into "
                "the hot cache — with no hot cache there is nothing to "
                "demote from or promote into; resolved to cold_tier=False")
            object.__setattr__(self, "mem",
                               dataclasses.replace(self.mem,
                                                   cold_tier=False))
            m = self.mem
        mem_knobs = [n for n, v in
                     (("cold_bytes",
                       None if m.cold_bytes == 1 << 28 else m.cold_bytes),
                      ("promote_touches",
                       None if m.promote_touches == 2 else m.promote_touches),
                      ("promote_window_s",
                       None if m.promote_window_s == 60.0 else
                       m.promote_window_s),
                      ("warm_batch",
                       None if m.warm_batch == 256 else m.warm_batch))
                     if v is not None]
        if mem_knobs and not m.cold_tier:
            notes.append(
                f"mem.{'/'.join(mem_knobs)} without mem.cold_tier=True: "
                f"they parameterize the cold tier only — resolved to "
                f"defaults (set cold_tier=True to keep them)")
            object.__setattr__(self, "mem",
                               dataclasses.replace(self.mem,
                                                   cold_bytes=1 << 28,
                                                   promote_touches=2,
                                                   promote_window_s=60.0,
                                                   warm_batch=256))
            m = self.mem
        trc_knobs = [n for n, v in
                     (("trace_capacity", o.trace_capacity),
                      ("sample_every",
                       o.sample_every if o.sample_every != 1 else None))
                     if v is not None]
        if trc_knobs and not o.trace:
            notes.append(
                f"{'/'.join(trc_knobs)} without trace=True: they "
                f"parameterize the ring-buffer tracer only — resolved to "
                f"defaults (set trace=True to keep them)")
            object.__setattr__(self, "obs",
                               dataclasses.replace(self.obs,
                                                   trace_capacity=None,
                                                   sample_every=1))
        inj_knobs = [n for n, v in (("sites", f.sites or None),
                                    ("seed", f.seed or None))
                     if v is not None]
        if inj_knobs and not f.inject:
            notes.append(
                f"ft.{'/'.join(inj_knobs)} without ft.inject=True: the "
                f"fault injector only arms when inject is on — resolved to "
                f"defaults (set inject=True to keep them)")
            object.__setattr__(self, "ft",
                               dataclasses.replace(self.ft, sites=(),
                                                   seed=0))
            f = self.ft
        retry_knobs = [n for n, v in
                       (("retry_backoff_ms",
                         None if f.retry_backoff_ms == 1.0 else
                         f.retry_backoff_ms),
                        ("retry_jitter",
                         None if f.retry_jitter == 0.5 else f.retry_jitter))
                       if v is not None]
        if retry_knobs and not f.retries:
            notes.append(
                f"ft.{'/'.join(retry_knobs)} without ft.retries > 0: they "
                f"shape the retry schedule only — resolved to defaults")
            object.__setattr__(self, "ft",
                               dataclasses.replace(self.ft,
                                                   retry_backoff_ms=1.0,
                                                   retry_jitter=0.5))
            f = self.ft
        if f.breaker_failures and not c.device_resident:
            notes.append(
                "ft.breaker_failures without cache.device_resident: the "
                "circuit breaker guards the device-resident stage-2 fast "
                "path — with no device tier every pack already takes the "
                "re-stacking route; resolved to breaker_failures=0")
            object.__setattr__(self, "ft",
                               dataclasses.replace(self.ft,
                                                   breaker_failures=0))
            f = self.ft
        brk_knobs = [n for n, v in
                     (("breaker_cooldown_ms",
                       None if f.breaker_cooldown_ms == 100.0 else
                       f.breaker_cooldown_ms),
                      ("breaker_probes",
                       None if f.breaker_probes == 1 else f.breaker_probes))
                     if v is not None]
        if brk_knobs and not f.breaker_failures:
            notes.append(
                f"ft.{'/'.join(brk_knobs)} without ft.breaker_failures > 0: "
                f"they parameterize the circuit breaker only — resolved to "
                f"defaults")
            object.__setattr__(self, "ft",
                               dataclasses.replace(self.ft,
                                                   breaker_cooldown_ms=100.0,
                                                   breaker_probes=1))
        # silent normalization (the engine's long-standing contract): the
        # smallest bucket can never exceed the row budget
        if b.min_bucket > b.max_batch:
            object.__setattr__(self, "batch",
                               dataclasses.replace(self.batch,
                                                   min_bucket=b.max_batch))
        object.__setattr__(self, "_notes", tuple(notes))
        for msg in notes:
            warnings.warn(msg, PlanResolutionWarning, stacklevel=3)

    @property
    def resolution_notes(self) -> tuple[str, ...]:
        """Auto-resolutions applied at construction (empty if none)."""
        return self._notes

    # -- derivation ---------------------------------------------------------
    def evolve(self, **updates: Any) -> "ServePlan":
        """Return a new plan with section fields replaced.

        Fields are addressed ``<section>__<field>``::

            plan.evolve(graph__mode="uoi", shard__shard_candidates=True)
        """
        per_section: dict[str, dict[str, Any]] = {n: {} for n in _SECTIONS}
        for key, value in updates.items():
            section, sep, field = key.partition("__")
            if not sep or section not in _SECTIONS or not field:
                raise TypeError(
                    f"evolve key {key!r} must be <section>__<field> with "
                    f"section in {sorted(_SECTIONS)}")
            per_section[section][field] = value
        kwargs = {}
        for name, fields in per_section.items():
            cur = getattr(self, name)
            # dataclasses.replace raises TypeError on unknown field names
            kwargs[name] = dataclasses.replace(cur, **fields) if fields \
                else cur
        return ServePlan(**kwargs)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {name: dataclasses.asdict(getattr(self, name))
                for name in _SECTIONS}

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ServePlan":
        unknown = set(d) - set(_SECTIONS)
        _require(not unknown,
                 f"unknown plan sections {sorted(unknown)}; known: "
                 f"{sorted(_SECTIONS)}")
        # sections pass through raw: __post_init__ owns validation, so a
        # malformed section (null, a string, ...) raises the documented
        # PlanError instead of a bare TypeError from dict()
        return cls(**{name: d[name] for name in _SECTIONS if name in d})

    @classmethod
    def from_json(cls, s: str) -> "ServePlan":
        d = json.loads(s)
        _require(isinstance(d, dict), "plan JSON must be an object")
        return cls.from_dict(d)

    @classmethod
    def load(cls, path: str) -> "ServePlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -- legacy kwargs shim -------------------------------------------------
    @classmethod
    def from_legacy_kwargs(cls, **kwargs: Any) -> "ServePlan":
        """Build a plan from the pre-plan ``ServingEngine`` keyword knobs.

        Unknown knobs raise ``TypeError`` (matching the old signature's
        behavior); invalid combinations raise/warn per the resolution
        table — the previously-silent no-op combos now fail fast.
        """
        unknown = set(kwargs) - set(_LEGACY_KWARGS)
        if unknown:
            raise TypeError(
                f"unknown ServingEngine kwargs {sorted(unknown)}; legacy "
                f"knobs: {sorted(_LEGACY_KWARGS)}")
        per_section: dict[str, dict[str, Any]] = {}
        for kw, value in kwargs.items():
            section, field = _LEGACY_KWARGS[kw]
            per_section.setdefault(section, {})[field] = value
        return cls(**{name: _SECTIONS[name](**fields)
                      for name, fields in per_section.items()})

    # -- presets ------------------------------------------------------------
    @classmethod
    def preset(cls, name: str) -> "ServePlan":
        """Named serving shapes: 'paper', 'vanilla', 'uoi', 'tpu',
        'distributed' (see ``PRESETS``)."""
        if name not in PRESETS:
            raise PlanError(
                f"unknown preset {name!r}; known: {sorted(PRESETS)}")
        return PRESETS[name]

    def preset_name(self) -> str | None:
        """The preset this plan equals, if any (provenance labeling)."""
        for name, plan in PRESETS.items():
            if plan == self:
                return name
        return None


# Frozen instances are immutable, so sharing the preset objects is safe.
PRESETS: dict[str, ServePlan] = {
    # the paper's serving shape: MaRI rewrite + two-stage split + coalescing
    "paper": ServePlan(),
    # baseline paradigms of Fig. 1 (single-stage tiled / two-stage uoi)
    "vanilla": ServePlan(graph=GraphPlan(mode="vani")),
    "uoi": ServePlan(graph=GraphPlan(mode="uoi")),
    # everything the Pallas path offers: fused mari_dense with the
    # kernel-side rep-table gather + gather-at-load decomposed attention
    "tpu": ServePlan(graph=GraphPlan(mode="mari", reparam_attention=True),
                     kernel=KernelPlan(use_pallas=True, kernel_gather=True,
                                       gather_attention=True)),
    # candidate-axis sharding on the 'cand' mesh; hedging off because the
    # multi-process SPMD schedule cannot tolerate per-process duplicates
    "distributed": ServePlan(shard=ShardPlan(shard_candidates=True),
                             batch=BatchPlan(hedging=False)),
}
