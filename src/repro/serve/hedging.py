"""Straggler mitigation with REAL duplicate execution (tail-at-scale hedging).

Two layers:

* ``HedgePolicy`` — the *decision*: a rolling-quantile latency tracker whose
  ``hedge_deadline_ms`` says how long a chunk may run before a duplicate is
  worth launching (p99 of the recent window, floored at ``min_hedge_ms``).
* ``HedgedRunner`` — the *execution*: runs the chunk on a worker thread,
  waits out the policy deadline, and if the primary is still straggling
  launches a duplicate of the same computation; the **first completed
  result wins** and the loser is cancelled (best effort: a not-yet-started
  future is cancelled outright; an in-flight XLA dispatch cannot be
  interrupted, so it is abandoned — its result is discarded and never
  blocks the caller).

The seed's ``HedgePolicy`` lived in ``repro.ft.failures`` and the engine
merely *recorded* the decision. The runner makes it real: both executions
dispatch the same jitted stage-2 executable (JAX dispatch is thread-safe;
results are deterministic, so first-wins cannot change scores).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait


class HedgePolicy:
    """Rolling-quantile hedging decision (tail-at-scale).

    Thread-safe: ``observe`` runs on whichever thread finishes a chunk
    while ``hedge_deadline_ms`` reads the window from the dispatcher —
    the window is snapshotted under a lock, so the sort never sees a
    deque mutating beneath it.
    """

    def __init__(self, quantile: float = 0.99, window: int = 512,
                 min_hedge_ms: float = 5.0):
        self.q = quantile
        self.lat = deque(maxlen=window)
        self.min_hedge_ms = min_hedge_ms
        self._lock = threading.Lock()

    def observe(self, latency_ms: float) -> None:
        with self._lock:
            self.lat.append(latency_ms)

    def hedge_deadline_ms(self) -> float:
        with self._lock:
            xs = list(self.lat)
        if len(xs) < 16:
            return self.min_hedge_ms * 10
        xs.sort()
        idx = min(len(xs) - 1, int(self.q * len(xs)))
        return max(xs[idx], self.min_hedge_ms)

    def should_hedge(self, elapsed_ms: float) -> bool:
        return elapsed_ms >= self.hedge_deadline_ms()


@dataclasses.dataclass
class HedgeOutcome:
    hedged: bool                  # a duplicate was actually launched
    winner: str                   # "primary" | "hedge"
    latency_ms: float             # first-result latency seen by the caller
    deadline_ms: float            # policy deadline that gated the duplicate


class HedgedRunner:
    """Run ``fn(*args)`` with policy-gated duplicate execution.

    ``fn`` must be deterministic and safe to invoke concurrently with
    itself (a jitted JAX call qualifies). The runner owns a small thread
    pool with headroom beyond the 2 slots a single call needs: an abandoned
    loser keeps its worker busy until its dispatch finishes, and with only
    2 workers a burst of consecutive stragglers would queue every new
    primary/duplicate behind zombies — silently disabling hedging exactly
    when it matters.

    The headroom is still finite, so the runner tracks outstanding pool
    work explicitly: once every worker is held by a zombie, a new primary
    would be *queued behind abandoned stragglers* — strictly worse than
    not hedging. Instead the call runs inline on the caller thread
    (``pool_exhausted`` counts these), and a duplicate that cannot get a
    worker simply isn't launched — the primary is awaited as if the
    deadline had not expired.
    """

    def __init__(self, fn, policy: HedgePolicy | None = None,
                 max_workers: int = 8):
        self.fn = fn
        self.policy = policy or HedgePolicy()
        self.max_workers = max_workers
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="hedge")
        self._olock = threading.Lock()
        self._outstanding = 0         # submitted, not yet finished (zombies
        #                               included: a worker is busy until its
        #                               abandoned dispatch actually returns)
        self.hedges_launched = 0
        self.hedge_wins = 0
        self.pool_exhausted = 0       # calls denied a worker (inline / no-dup)

    def _submit(self, *args) -> Future | None:
        """Submit to the pool iff a worker slot is actually free —
        returns None when zombies hold every slot (the caller falls back
        rather than queue behind abandoned work)."""
        with self._olock:
            if self._outstanding >= self.max_workers:
                return None
            self._outstanding += 1
        fut = self._pool.submit(self.fn, *args)

        def _done(_f, self=self):
            with self._olock:
                self._outstanding -= 1

        fut.add_done_callback(_done)
        return fut

    def run(self, *args) -> tuple[object, HedgeOutcome]:
        deadline_ms = self.policy.hedge_deadline_ms()
        t0 = time.perf_counter()
        primary = self._submit(*args)
        if primary is None:
            # zombie-pool starvation: every worker is busy with abandoned
            # stragglers. Run inline — the caller thread does the work
            # NOW instead of queueing behind zombies of indefinite life.
            self.pool_exhausted += 1
            result = self.fn(*args)
            latency_ms = (time.perf_counter() - t0) * 1e3
            self.policy.observe(latency_ms)
            return result, HedgeOutcome(hedged=False, winner="primary",
                                        latency_ms=latency_ms,
                                        deadline_ms=deadline_ms)
        done, _ = wait({primary}, timeout=deadline_ms / 1e3,
                       return_when=FIRST_COMPLETED)
        if done:
            result, hedged, winner = primary.result(), False, "primary"
        else:
            # primary is straggling: duplicate the chunk, first result wins
            backup = self._submit(*args)
            if backup is None:
                # no free worker for the duplicate — hedging is pointless
                # (the duplicate would queue behind the very stragglers
                # it is meant to beat); await the primary instead
                self.pool_exhausted += 1
                result = primary.result()
                latency_ms = (time.perf_counter() - t0) * 1e3
                self.policy.observe(latency_ms)
                return result, HedgeOutcome(hedged=False, winner="primary",
                                            latency_ms=latency_ms,
                                            deadline_ms=deadline_ms)
            self.hedges_launched += 1
            done, not_done = wait({primary, backup},
                                  return_when=FIRST_COMPLETED)
            # both may have completed between the deadline and the wait;
            # prefer the primary then (identical results either way)
            first = primary if primary in done else backup
            winner = "primary" if first is primary else "hedge"
            if winner == "hedge":
                self.hedge_wins += 1
            for f in not_done:
                f.cancel()        # not-started duplicates die here; an
            result = first.result()  # in-flight loser is abandoned, not awaited
            hedged = True
        latency_ms = (time.perf_counter() - t0) * 1e3
        self.policy.observe(latency_ms)
        return result, HedgeOutcome(hedged=hedged, winner=winner,
                                    latency_ms=latency_ms,
                                    deadline_ms=deadline_ms)

    def close(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)
