"""``RankingService`` — a multi-scenario serving router.

Industrial rankers serve heterogeneous scenario models side by side
(per-stage rankers, per-surface models, A/B variants); the repo's
``configs/`` registry already carries several ranking scenarios (din,
deepfm, fm, dlrm-mlperf, paper-ranking) that could previously only be
served one-at-a-time through ad-hoc flags. ``RankingService`` hosts them
behind ONE ``submit(scenario, request)`` API:

* **per-scenario engines** — each registered scenario gets its own
  ``ServingEngine`` compiled from a ``ServePlan`` (the service default or a
  per-scenario override) and its own ``CoalescingBatcher`` (cross-user
  coalescing stays within a scenario: different graphs cannot share a
  stage-2 executable);
* **registry-by-name** — ``service.register("din")`` builds the scenario
  from ``repro.configs`` (``smoke_build`` by default, the full-size
  ``BUILD`` with ``smoke=False``) and initializes params from a fixed
  seed, so a registered scenario is bit-reproducible; callers may instead
  pass an explicit ``graph``/``params`` pair (e.g. trained weights);
* **shared rep-cache budget** — every scenario engine plugs into ONE
  bounded ``UserRepCache``: ``shared_cache_users`` caps the LIVE user
  representations across all scenarios together (one LRU, evictions
  compete globally), with cache keys namespaced per scenario so equal user
  ids from different scenarios can never collide on wrong-shaped reps.

Scores are bit-identical to a standalone per-scenario engine: routing adds
no numerics — the same plan builds the same executable family, and the
shared cache only changes *when* stage 1 recomputes, never what stage 2
computes (proven by test).

Usage::

    svc = RankingService(ServePlan.preset("paper"))
    svc.register("din"); svc.register("deepfm")
    fut = svc.submit("din", req)          # Future[ServeResult]
    res = svc.score("deepfm", req2)       # synchronous
    svc.close()
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future
from typing import Iterable, Mapping, Sequence

import jax

from repro.graph.ir import Graph
from repro.serve.batcher import SLO_BEST_EFFORT, CoalescingBatcher
from repro.serve.cache import UserRepCache
from repro.serve.engine import ServeRequest, ServeResult, ServingEngine
from repro.serve.plan import ServePlan


@dataclasses.dataclass
class _Scenario:
    name: str
    plan: ServePlan
    source_graph: Graph          # pre-rewrite graph (feed specs live here)
    user_inputs: frozenset[str]  # input names with domain == "user"
    engine: ServingEngine
    batcher: CoalescingBatcher


class RankingService:
    """Host several scenario models behind one ``submit`` API.

    ``plan`` (a ``ServePlan`` or preset name) is the default serving shape
    for registered scenarios; ``shared_cache_users`` is the TOTAL live-user
    budget of the shared rep cache (defaults to the plan's
    ``max_cached_users``). ``smoke`` picks the registry build size used by
    name registration; ``seed`` the param-init key.
    """

    def __init__(self, plan: ServePlan | str | None = None, *,
                 smoke: bool = True, seed: int = 0,
                 shared_cache_users: int | None = None):
        if isinstance(plan, str):
            plan = ServePlan.preset(plan)
        self.plan = plan if plan is not None else ServePlan()
        self.smoke = smoke
        self.seed = seed
        budget = (shared_cache_users if shared_cache_users is not None
                  else self.plan.cache.max_cached_users)
        self.shared_cache = UserRepCache(max_users=budget)
        self._scenarios: dict[str, _Scenario] = {}
        self._closed = False

    # -- registration -------------------------------------------------------
    def register(self, scenario: str, *, graph: Graph | None = None,
                 params: dict | None = None,
                 plan: ServePlan | str | None = None,
                 smoke: bool | None = None,
                 seed: int | None = None) -> ServingEngine:
        """Register one scenario model and compile its engine.

        With no ``graph``, the scenario is built from the ``repro.configs``
        registry by name (``smoke_build``/``BUILD`` per ``smoke``) and
        params are initialized from ``seed`` — deterministic, so a
        standalone engine built the same way scores bit-identically.
        Returns the scenario's engine.
        """
        if self._closed:
            raise RuntimeError("service is closed")
        if scenario in self._scenarios:
            raise ValueError(f"scenario {scenario!r} is already registered")
        if (graph is None) != (params is None):
            raise ValueError("pass graph and params together (or neither, "
                             "to build from the configs registry)")
        if isinstance(plan, str):
            plan = ServePlan.preset(plan)
        plan = plan if plan is not None else self.plan
        if graph is None:
            from repro import configs as cfgreg
            from repro.graph.executor import init_graph_params
            mod = cfgreg.get_config(scenario)
            use_smoke = self.smoke if smoke is None else smoke
            build = mod.smoke_build() if use_smoke else mod.BUILD
            built = build()
            graph = built[0] if isinstance(built, tuple) else built
            params = init_graph_params(
                graph, jax.random.PRNGKey(self.seed if seed is None
                                          else seed))
        user_inputs = frozenset(n.name for n in graph.input_nodes()
                                if n.attrs.get("domain") == "user")
        engine = ServingEngine(graph, params, plan=plan,
                               cache=self.shared_cache,
                               cache_scope=scenario)
        # the whole batch section rides the plan spine: continuous loop,
        # in-flight budget, admission thresholds, and the ft section's
        # retry knobs included
        batcher = CoalescingBatcher.from_plan(engine, plan.batch, plan.ft)
        self._scenarios[scenario] = _Scenario(
            name=scenario, plan=plan, source_graph=graph,
            user_inputs=user_inputs, engine=engine, batcher=batcher)
        return engine

    # -- lookup -------------------------------------------------------------
    def _get(self, scenario: str) -> _Scenario:
        try:
            return self._scenarios[scenario]
        except KeyError:
            raise KeyError(
                f"scenario {scenario!r} is not registered; registered: "
                f"{sorted(self._scenarios)}") from None

    @property
    def scenarios(self) -> list[str]:
        return sorted(self._scenarios)

    def engine(self, scenario: str) -> ServingEngine:
        return self._get(scenario).engine

    def source_graph(self, scenario: str) -> Graph:
        """The scenario's pre-rewrite graph (input/feed specs)."""
        return self._get(scenario).source_graph

    def split_feeds(self, scenario: str, feeds: Mapping[str, jax.Array]
                    ) -> tuple[dict, dict]:
        """Partition a flat feed dict into (user_feeds, candidate_feeds)
        per the scenario graph's ``domain`` coloring — the ``ServeRequest``
        contract."""
        user_in = self._get(scenario).user_inputs
        return ({k: v for k, v in feeds.items() if k in user_in},
                {k: v for k, v in feeds.items() if k not in user_in})

    # -- scoring ------------------------------------------------------------
    def submit(self, scenario: str, req: ServeRequest, *,
               slo: str = SLO_BEST_EFFORT,
               deadline_ms: float | None = None) -> "Future[ServeResult]":
        """Route one request to its scenario's batcher (non-blocking)."""
        return self._get(scenario).batcher.submit(req, slo=slo,
                                                  deadline_ms=deadline_ms)

    def score(self, scenario: str, req: ServeRequest) -> ServeResult:
        return self.submit(scenario, req).result()

    def score_many(self, items: Sequence[tuple[str, ServeRequest]]
                   ) -> list[ServeResult]:
        """Score an interleaved multi-scenario stream: submit everything
        (scenario batchers coalesce their own co-arrivals concurrently),
        then collect results in submission order."""
        futs = [self.submit(scenario, req) for scenario, req in items]
        return [f.result() for f in futs]

    # -- memory hierarchy ----------------------------------------------------
    def warm(self, scenario: str, items, feature_version: int = 0) -> int:
        """Bulk-precompute stage-1 reps into a scenario's cold tier (see
        ``ServingEngine.warm``); requires ``plan.mem.cold_tier=True`` for
        that scenario. ``items``: ``(user_id, user_feeds)`` pairs."""
        return self._get(scenario).engine.warm(
            items, feature_version=feature_version)

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        """Per-scenario serving counters (including the stage-boundary
        profile and the device rep tier, when live) + the shared cache's
        state with byte accounting — ``shared_cache.boundary_bytes`` is
        the number to read when sizing ``CachePlan.device_slots``."""
        return {
            "scenarios": {
                s.name: {
                    "preset": s.plan.preset_name(),
                    "mode": s.engine.mode,
                    "two_stage": s.engine.two_stage,
                    "device_resident": s.engine.device_resident,
                    "requests": s.batcher.requests,
                    "batches": s.batcher.batches,
                    "coalesced_requests": s.batcher.coalesced_requests,
                    "queue_wait_ms": s.batcher.queue_wait_ms,
                    "shed_requests": s.batcher.shed_requests,
                    "shed_best_effort": s.batcher.shed_best_effort,
                    "shed_deadline": s.batcher.shed_deadline,
                    "degraded_requests": s.batcher.degraded_requests,
                    # self-healing counters: retries/respawns on the
                    # batcher, breaker + injector state on the engine —
                    # the chaos harness asserts recovery through these
                    "retries_attempted": s.batcher.retries_attempted,
                    "retries_exhausted": s.batcher.retries_exhausted,
                    "worker_crashes": s.batcher.worker_crashes,
                    "worker_respawns": s.batcher.worker_respawns,
                    "fallback_packs": getattr(s.engine, "fallback_packs", 0),
                    "corruptions_detected": getattr(
                        s.engine, "corruptions_detected", 0),
                    "breaker": (s.engine.breaker.stats()
                                if getattr(s.engine, "breaker", None)
                                is not None else None),
                    "faults": (s.engine.fault_injector.stats()
                               if getattr(s.engine, "fault_injector", None)
                               is not None else None),
                    "stage1_calls": s.engine.stage1_calls,
                    "stage2_calls": s.engine.stage2_calls,
                    "pipeline_forks": s.engine.pipeline_forks,
                    # log-bucketed distributions (repro.obs): the tail
                    # numbers an SLO is judged on, which the cumulative
                    # totals above cannot show
                    "latency": {
                        "request_ms": s.batcher.request_latency.snapshot(),
                        "queue_wait_ms": s.batcher.queue_wait.snapshot(),
                    },
                    # unified counter+histogram snapshot when the
                    # engine's registry is on (plan.obs.metrics)
                    "metrics": (s.engine.metrics.snapshot()
                                if s.engine.metrics is not None else None),
                    "profile": s.engine.profiler.snapshot(),
                    "device_store": (s.engine.device_store.stats()
                                     if s.engine.device_store is not None
                                     else None),
                    # memory hierarchy (plan.mem): cold arena occupancy,
                    # promotion-policy counters, warm-feed totals
                    "mem": s.engine.mem_stats(),
                } for s in self._scenarios.values()},
            # host-tier stats() carries users/max_users/hits/misses/
            # evictions plus bytes + per-boundary bytes
            "shared_cache": self.shared_cache.stats(),
        }

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        for s in self._scenarios.values():
            s.batcher.close()
            s.engine.close()
        self._closed = True

    def __enter__(self) -> "RankingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __contains__(self, scenario: str) -> bool:
        return scenario in self._scenarios

    def __iter__(self) -> Iterable[str]:
        return iter(self.scenarios)
