"""DIN target attention as one fused TPU Pallas kernel.

The whole local-activation unit — [k,q,k-q,k*q] features, 3-layer scoring
MLP, masked softmax over the history, weighted pool — runs per batch tile
entirely in VMEM. The user history (L×D, one-shot under UOI) and the tiny
MLP weights are broadcast to every grid step; the (B, L, 4D) feature tensor
never reaches HBM. This is the serving-side fusion the paper's engine would
apply on GPU, re-blocked for VMEM/MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, m_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
            b3_ref, o_ref):
    q = q_ref[...]                       # (bm, D)
    keys = k_ref[...]                    # (L, D)
    bm, D = q.shape
    L = keys.shape[0]
    k = jnp.broadcast_to(keys[None], (bm, L, D))
    qe = jnp.broadcast_to(q[:, None, :], (bm, L, D))
    feats = jnp.concatenate([k, qe, k - qe, k * qe], axis=-1)   # (bm, L, 4D)
    flat = feats.reshape(bm * L, 4 * D)
    h = jax.nn.relu(jnp.dot(flat, w1_ref[...],
                            preferred_element_type=jnp.float32) + b1_ref[...])
    h = jax.nn.relu(jnp.dot(h, w2_ref[...],
                            preferred_element_type=jnp.float32) + b2_ref[...])
    s = (jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32)
         + b3_ref[...]).reshape(bm, L)
    s = jnp.where(m_ref[...][None, :] != 0, s, NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p.astype(keys.dtype), keys,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def din_attention_kernel(query, keys, mask, w1, b1, w2, b2, w3, b3, *,
                         bm: int = 128, interpret: bool = False):
    B, D = query.shape
    L = keys.shape[0]
    h1, h2 = w1.shape[1], w2.shape[1]
    assert B % bm == 0
    mask_i = mask.astype(jnp.int32)
    full = lambda *shape: (shape, lambda i: tuple(0 for _ in shape))

    def spec(shape, imap):
        return pl.BlockSpec(shape, imap)

    return pl.pallas_call(
        _kernel,
        grid=(B // bm,),
        in_specs=[
            spec((bm, D), lambda i: (i, 0)),
            spec((L, D), lambda i: (0, 0)),
            spec((L,), lambda i: (0,)),
            spec((4 * D, h1), lambda i: (0, 0)),
            spec((h1,), lambda i: (0,)),
            spec((h1, h2), lambda i: (0, 0)),
            spec((h2,), lambda i: (0,)),
            spec((h2, 1), lambda i: (0, 0)),
            spec((1,), lambda i: (0,)),
        ],
        out_specs=spec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), query.dtype),
        interpret=interpret,
    )(query, keys, mask_i, w1, b1, w2, b2, w3, b3)
