"""Pure-jnp oracle: DIN local-activation target attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def din_attention_ref(query, keys, mask, w1, b1, w2, b2, w3, b3):
    """query (B, D); keys (L, D); mask (L,) bool. MLP: 4D->h1->h2->1 (relu).
    Returns (B, D) interest vector."""
    B, D = query.shape
    L = keys.shape[0]
    k = jnp.broadcast_to(keys[None], (B, L, D))
    q = jnp.broadcast_to(query[:, None, :], (B, L, D))
    feats = jnp.concatenate([k, q, k - q, k * q], axis=-1)      # (B, L, 4D)
    h = jax.nn.relu(feats @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    scores = (h @ w3 + b3)[..., 0]                               # (B, L)
    scores = jnp.where(mask[None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bl,ld->bd", w, keys)
