"""Public DIN-attention op with batch padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.din_attention.kernel import din_attention_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def din_attention(query, keys, mask, w1, b1, w2, b2, w3, b3, *,
                  interpret: bool = True):
    """query (B, D); keys (L, D); mask (L,). Returns (B, D)."""
    B = query.shape[0]
    bm = min(128, max(8, B))
    Bp = round_up(B, bm)
    qp = jnp.pad(query, ((0, Bp - B), (0, 0)))
    out = din_attention_kernel(qp, keys, mask, w1, b1, w2, b2, w3, b3,
                               bm=bm, interpret=interpret)
    return out[:B]
