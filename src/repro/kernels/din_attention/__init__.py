from repro.kernels.din_attention.ops import din_attention  # noqa: F401
