from repro.kernels.mari_matmul.ops import mari_matmul_fused  # noqa: F401
