from repro.kernels.mari_matmul.kernel import (  # noqa: F401
    mari_matmul_kernel,
    mari_matmul_kernel_gather,
)
from repro.kernels.mari_matmul.ops import (  # noqa: F401
    mari_matmul_fused,
    mari_matmul_fused_groups,
)
