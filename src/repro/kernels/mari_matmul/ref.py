"""Pure-jnp oracle for the fused MaRI matmul (Eq. 7, two-group form)."""
from __future__ import annotations

import jax.numpy as jnp


def mari_matmul_ref(x_user, x_rest, w_user, w_rest, b=None):
    """x_user (1, Du), x_rest (B, Dr), w_user (Du, d), w_rest (Dr, d)."""
    y = x_user.astype(jnp.float32) @ w_user.astype(jnp.float32) \
        + x_rest.astype(jnp.float32) @ w_rest.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x_rest.dtype)
