"""Pure-jnp oracles for the fused MaRI matmul (Eq. 7)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.layers import ACTIVATIONS


def mari_matmul_ref(x_user, x_rest, w_user, w_rest, b=None):
    """x_user (1, Du), x_rest (B, Dr), w_user (Du, d), w_rest (Dr, d)."""
    y = x_user.astype(jnp.float32) @ w_user.astype(jnp.float32) \
        + x_rest.astype(jnp.float32) @ w_rest.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x_rest.dtype)


def mari_matmul_groups_ref(parts, b=None, *, acc0=None, activation="identity"):
    """Oracle for ``mari_matmul_fused_groups``: act(Σ x_g W_g + acc0 + b)."""
    B = max(x.shape[0] for x, _ in parts)
    y = jnp.zeros((B, parts[0][1].shape[1]), jnp.float32)
    for x, w in parts:
        y = y + x.astype(jnp.float32) @ w.astype(jnp.float32)
    if acc0 is not None:
        y = y + acc0.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return ACTIVATIONS[activation](y).astype(parts[0][0].dtype)
