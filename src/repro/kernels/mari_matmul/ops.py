"""Public fused-MaRI matmul ops: pad to MXU-aligned tiles, compute the tiny
user-side products with jnp (2·Du·d FLOPs), and dispatch the Pallas kernel
for the batched side with the user row fused as accumulator init and the
bias + activation applied in the kernel epilogue.

``mari_matmul_fused``        — Eq. 7 two-group form (user, rest).
``mari_matmul_fused_groups`` — multi-group / fragmented form: any number of
    (x, w) products summed into one output. Batch-1 operands (user side,
    Σ 2·Du·d FLOPs) fold into the accumulator-init row; batch-B operands
    concatenate into a single MXU stream (Σ_g x_g @ w_g == concat(x_g) @
    stack(w_g), the block-matmul identity of Eq. 2), so a §2.4-fragmented
    layout costs one kernel launch, not one per fragment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.mari_matmul.kernel import (_EPILOGUES, mari_matmul_kernel,
                                              mari_matmul_kernel_gather)

_VMEM_BUDGET = 8 * 1024 * 1024  # bytes; conservative half of v5e VMEM


def _pick_blocks(B: int, Dr: int, d: int, itemsize: int) -> tuple[int, int, int]:
    bm = min(256, round_up(min(B, 256), 8))
    bn = min(256, round_up(min(d, 256), 128))
    bk = 512
    while (bm * bk + bk * bn) * itemsize + bm * bn * 4 > _VMEM_BUDGET and bk > 128:
        bk //= 2
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def mari_matmul_fused_groups(parts, b=None, *, acc0=None, user_index=None,
                             activation="identity", interpret=True):
    """act(Σ_g Tile-or-stream(x_g @ w_g) + acc0 + b) for (x, w) pairs.

    Each x is (1, D_g) (user side — folded into the broadcast row) or
    (B, D_g) (batched side — streamed through the MXU). ``acc0`` is an
    optional precomputed partial added to the accumulator init — a (1, d)
    row (one user per batch) or a row-wise (B, d) block (cross-user
    coalesced serving: row b carries user b's partial). With
    ``user_index`` (B,), ``acc0`` is instead the STACKED (U, d) per-user
    table and the kernel gathers row ``user_index[b]`` at accumulator-init
    load — the gathered (B, d) block never materializes (bit-identical:
    the row adds/epilogue commute with the exact row-copy gather).
    interpret=True on CPU (validation); False on TPU.
    """
    d = parts[0][1].shape[1]
    user = [(x, w) for x, w in parts if x.shape[0] == 1]
    rest = [(x, w) for x, w in parts if x.shape[0] != 1]

    # user row computed and kept in f32 — it seeds the f32 accumulator, so
    # rounding it to bf16 here would inject avoidable error (ulp(|u|)).
    u = jnp.zeros((1, d), jnp.float32)
    for x, w in user:
        u = u + x.astype(jnp.float32) @ w.astype(jnp.float32)
    if acc0 is not None:
        # (B, d) acc0 broadcasts u row-wise; a (U, d) table (user_index
        # set) broadcasts identically — per-slot rows, gathered below
        u = u + acc0.astype(jnp.float32)
    if b is not None:
        u = u + b.astype(jnp.float32)

    if not rest:  # no batched stream left: acc-init row/block IS the output
        out = _EPILOGUES[activation](u)
        if user_index is not None and acc0 is not None:
            # clip: a padded row's index must read a real slot, not wrap/NaN
            out = jnp.take(out, user_index, axis=0, mode="clip")
        return out.astype(parts[0][0].dtype)

    B = max(x.shape[0] for x, _ in rest)
    if len(rest) == 1 and rest[0][0].shape[0] == B:
        # single pre-concatenated stream (engine-side weight pre-concat):
        # no per-call operand copies at all
        x_rest, w_rest = rest[0]
    else:
        x_rest = jnp.concatenate(
            [jnp.broadcast_to(x, (B,) + x.shape[1:]) for x, _ in rest], axis=-1)
        w_rest = jnp.concatenate([w for _, w in rest], axis=0)

    Dr = x_rest.shape[1]
    bm, bn, bk = _pick_blocks(B, Dr, d, x_rest.dtype.itemsize)
    Bp, Drp, dp = round_up(B, bm), round_up(Dr, bk), round_up(d, bn)
    xp = jnp.pad(x_rest, ((0, Bp - B), (0, Drp - Dr)))
    wp = jnp.pad(w_rest, ((0, Drp - Dr), (0, dp - d)))
    if user_index is not None and acc0 is not None:
        # table layout (U, d): pad columns only; pad rows index slot 0 and
        # out-of-range indices clamp (same contract as kernels.gather_einsum)
        up = jnp.pad(u, ((0, 0), (0, dp - d)))
        idx = jnp.clip(user_index.astype(jnp.int32), 0, acc0.shape[0] - 1)
        idx = jnp.pad(idx, (0, Bp - B))
        out = mari_matmul_kernel_gather(xp, wp, up, idx, bm=bm, bn=bn,
                                        bk=bk, activation=activation,
                                        interpret=interpret)
        return out[:B, :d]
    # row-wise acc-init pads its batch dim alongside x; a single row does not
    up = jnp.pad(u, ((0, Bp - B if u.shape[0] == B else 0), (0, dp - d)))
    out = mari_matmul_kernel(xp, wp, up, bm=bm, bn=bn, bk=bk,
                             activation=activation, interpret=interpret)
    return out[:B, :d]


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def mari_matmul_fused(x_user, x_rest, w_user, w_rest, b=None, *,
                      activation="identity", interpret=True):
    """act(Tile(x_user @ w_user, B) + x_rest @ w_rest (+ b)) — Eq. 7.

    x_user (1, Du), x_rest (B, Dr), w_user (Du, d), w_rest (Dr, d).
    interpret=True on CPU (validation); False on real TPU.
    """
    return mari_matmul_fused_groups(
        [(x_user, w_user), (x_rest, w_rest)], b,
        activation=activation, interpret=interpret)
