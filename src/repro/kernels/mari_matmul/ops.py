"""Public fused-MaRI matmul op: pads to MXU-aligned tiles, computes the tiny
user-side product with jnp (2·Du·d FLOPs), and dispatches the Pallas kernel
for the batched side with the user row fused as accumulator init."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.mari_matmul.kernel import mari_matmul_kernel

_VMEM_BUDGET = 8 * 1024 * 1024  # bytes; conservative half of v5e VMEM


def _pick_blocks(B: int, Dr: int, d: int, itemsize: int) -> tuple[int, int, int]:
    bm = min(256, round_up(min(B, 256), 8))
    bn = min(256, round_up(min(d, 256), 128))
    bk = 512
    while (bm * bk + bk * bn) * itemsize + bm * bn * 4 > _VMEM_BUDGET and bk > 128:
        bk //= 2
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("interpret",))
def mari_matmul_fused(x_user, x_rest, w_user, w_rest, b=None, *,
                      interpret=True):
    """Eq. 7: Tile(x_user @ w_user, B) + x_rest @ w_rest (+ b).

    x_user (1, Du), x_rest (B, Dr), w_user (Du, d), w_rest (Dr, d).
    interpret=True on CPU (validation); False on real TPU.
    """
    B, Dr = x_rest.shape
    d = w_rest.shape[1]
    # user row computed and kept in f32 — it seeds the f32 accumulator, so
    # rounding it to bf16 here would inject avoidable error (ulp(|u|)).
    u = x_user.astype(jnp.float32) @ w_user.astype(jnp.float32)
    if b is not None:
        u = u + b.astype(jnp.float32)
    bm, bn, bk = _pick_blocks(B, Dr, d, x_rest.dtype.itemsize)
    Bp, Drp, dp = round_up(B, bm), round_up(Dr, bk), round_up(d, bn)
    xp = jnp.pad(x_rest, ((0, Bp - B), (0, Drp - Dr)))
    wp = jnp.pad(w_rest, ((0, Drp - Dr), (0, dp - d)))
    up = jnp.pad(u, ((0, 0), (0, dp - d)))
    out = mari_matmul_kernel(xp, wp, up, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return out[:B, :d]
