"""MaRI matmul as a TPU Pallas kernel.

TPU adaptation of Eq. 7 (DESIGN.md §3): the user-side product
``u = x_user @ w_user`` is a single 1×d row — negligible FLOPs — so the
kernel treats it as a *bias row*: the VMEM accumulator for each output tile
initializes from the broadcast ``u`` tile instead of zeros, and the MXU only
streams the item/cross operand ``x_rest @ w_rest``. ``Tile(u, B)`` never
exists in HBM, and the epilogue add is fused into the matmul.

The epilogue additionally applies the layer's activation in-register
(``activation``), so the (B, d) pre-activation never round-trips through
HBM between the matmul and the nonlinearity.

Grid: (B/bm, d/bn, Dr/bk), k innermost; accumulator in f32 VMEM scratch.
Block shapes are (8,128)-aligned for the MXU systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Epilogue activations computed on the f32 accumulator tile. Kept in sync
# with repro.nn.layers.ACTIVATIONS (not imported to keep the kernel module
# dependency-free).
_EPILOGUES = {
    "identity": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


def _kernel(x_ref, w_ref, u_ref, o_ref, acc_ref, *, activation):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        # Eq. 7's Tile(x_u W_u, B): broadcast the user row into the tile.
        acc_ref[...] = jnp.broadcast_to(
            u_ref[...].astype(jnp.float32), acc_ref.shape)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = _EPILOGUES[activation](acc_ref[...]).astype(o_ref.dtype)


def _kernel_gather(x_ref, w_ref, u_ref, idx_ref, o_ref, acc_ref, *,
                   activation):
    """Row-wise variant with the user-rep gather folded into the
    accumulator-init load: ``u_ref`` is the full (U, bn) column tile of the
    stacked rep table and ``idx_ref`` this row-tile's (bm, 1) user indices;
    row r initializes from table row ``idx[r]`` — the gathered (B, d)
    block never exists in HBM. U is small (the pow2-padded user-slot count
    of one coalesced batch), so the table tile stays VMEM-resident."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        idx = idx_ref[...][:, 0]
        acc_ref[...] = jnp.take(u_ref[...], idx, axis=0).astype(jnp.float32)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = _EPILOGUES[activation](acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "activation", "interpret"))
def mari_matmul_kernel(x_rest, w_rest, u_row, *, bm=128, bn=128, bk=512,
                       activation="identity", interpret=False):
    """act(x_rest (B, Dr) @ w_rest (Dr, d) + u_row).

    ``u_row`` is the accumulator init in one of two layouts:

    * (1, d) — one user per batch (classic Eq. 7): the row is broadcast
      into every output tile.
    * (B, d) — row-wise (cross-user coalesced serving): row b carries user
      b's precomputed partial, so each output tile initializes from its own
      row block. The broadcast in the init is then a no-op.

    Caller guarantees B % bm == 0, d % bn == 0, Dr % bk == 0 (ops.py pads).
    """
    B, Dr = x_rest.shape
    d = w_rest.shape[1]
    assert B % bm == 0 and d % bn == 0 and Dr % bk == 0, (B, Dr, d, bm, bn, bk)
    if u_row.shape[0] not in (1, B):
        raise ValueError(f"u_row rows must be 1 or B={B}, got {u_row.shape}")
    if activation not in _EPILOGUES:
        raise ValueError(f"unsupported epilogue activation {activation!r}")
    if u_row.shape[0] == 1:
        u_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    else:                                 # row-wise: follow the output tiling
        u_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=(B // bm, d // bn, Dr // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w tile
            u_spec,                                           # acc-init tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, d), x_rest.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_rest, w_rest, u_row)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "activation", "interpret"))
def mari_matmul_kernel_gather(x_rest, w_rest, u_table, user_index, *,
                              bm=128, bn=128, bk=512,
                              activation="identity", interpret=False):
    """act(x_rest (B, Dr) @ w_rest (Dr, d) + u_table[user_index]).

    ``u_table`` is the stacked (U, d) per-user accumulator-init table
    (cross-user coalesced serving) and ``user_index`` the (B,) row->user
    map; the gather happens at accumulator-init load inside the kernel,
    so the (B, d) gathered block is never materialized. Bit-identical to
    ``mari_matmul_kernel(x, w, u_table[user_index])`` — a gather is an
    exact row copy and commutes with the elementwise epilogue.

    Caller guarantees B % bm == 0, d % bn == 0, Dr % bk == 0 (ops.py pads).
    """
    B, Dr = x_rest.shape
    d = w_rest.shape[1]
    U = u_table.shape[0]
    assert B % bm == 0 and d % bn == 0 and Dr % bk == 0, (B, Dr, d, bm, bn, bk)
    if user_index.shape != (B,):
        raise ValueError(f"user_index must be ({B},), got {user_index.shape}")
    if activation not in _EPILOGUES:
        raise ValueError(f"unsupported epilogue activation {activation!r}")
    idx2d = user_index.astype(jnp.int32).reshape(B, 1)
    return pl.pallas_call(
        functools.partial(_kernel_gather, activation=activation),
        grid=(B // bm, d // bn, Dr // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # w tile
            pl.BlockSpec((U, bn), lambda i, j, k: (0, j)),    # rep-table tile
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),    # row indices
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, d), x_rest.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_rest, w_rest, u_table, idx2d)
