"""Pallas TPU kernels for the recsys serving hot spots.

Each subpackage ships: ``kernel.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper, padding/fallback logic) and
``ref.py`` (pure-jnp oracle used by the allclose test sweeps). Kernels are
validated on CPU with ``interpret=True``; TPU is the compile target.

See ``README.md`` in this package for the gather-at-load convention shared
by ``mari_matmul`` (kernel_gather accumulator init) and ``gather_einsum``
(attention-side contractions over stacked (U, ...) rep tables).
"""
from repro.kernels.mari_matmul.ops import (  # noqa: F401
    mari_matmul_fused,
    mari_matmul_fused_groups,
)
from repro.kernels.gather_einsum import (  # noqa: F401
    gather_einsum,
    gather_einsum_ref,
)
from repro.kernels.embedding_bag.ops import embedding_bag  # noqa: F401
from repro.kernels.dot_interaction.ops import dot_interaction  # noqa: F401
from repro.kernels.din_attention.ops import din_attention  # noqa: F401
