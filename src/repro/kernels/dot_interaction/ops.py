"""Public dot-interaction op with batch padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.dot_interaction.kernel import dot_interaction_kernel


@functools.partial(jax.jit, static_argnames=("keep_self", "interpret"))
def dot_interaction(x, *, keep_self: bool = False, interpret: bool = True):
    """x (B, F, D) -> (B, F*(F±1)/2) pairwise dots (DLRM interaction)."""
    B = x.shape[0]
    bm = min(128, max(8, B))
    Bp = round_up(B, bm)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0), (0, 0)))
    out = dot_interaction_kernel(xp, keep_self=keep_self, bm=bm,
                                 interpret=interpret)
    return out[:B]
