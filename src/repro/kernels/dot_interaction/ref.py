"""Pure-jnp oracle: DLRM pairwise-dot feature interaction."""
from __future__ import annotations

import jax.numpy as jnp


def dot_interaction_ref(x, keep_self: bool = False):
    """x (B, F, D) -> (B, P) upper-triangle pairwise dots."""
    z = jnp.einsum("bfd,bgd->bfg", x, x)
    f = x.shape[1]
    iu, ju = jnp.triu_indices(f, k=0 if keep_self else 1)
    return z[:, iu, ju]
