"""DLRM dot-interaction as a TPU Pallas kernel.

TPU adaptation (DESIGN.md §3): the triu *gather* that follows the F×F gram
matrix is hostile to the TPU vector unit (strided lane shuffles). We instead
select the upper triangle with a constant one-hot matrix multiply
(F² × P selection matrix) — on TPU a small MXU matmul beats any gather.
One batch tile per grid step; gram + selection fused in VMEM, so the (B,F,F)
gram tensor never reaches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _make_selector(f: int, keep_self: bool) -> np.ndarray:
    iu, ju = np.triu_indices(f, k=0 if keep_self else 1)
    p = len(iu)
    sel = np.zeros((f * f, p), np.float32)
    sel[iu * f + ju, np.arange(p)] = 1.0
    return sel


def _kernel(x_ref, sel_ref, o_ref):
    x = x_ref[...]                      # (bm, F, D)
    z = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)         # (bm, F, F)
    bm = z.shape[0]
    flat = z.reshape(bm, -1)                        # (bm, F*F)
    o_ref[...] = jnp.dot(flat, sel_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)      # (bm, P)


@functools.partial(jax.jit, static_argnames=("keep_self", "bm", "interpret"))
def dot_interaction_kernel(x, *, keep_self: bool = False, bm: int = 128,
                           interpret: bool = False):
    B, F, D = x.shape
    assert B % bm == 0, (B, bm)
    sel = jnp.asarray(_make_selector(F, keep_self))
    P = sel.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(B // bm,),
        in_specs=[pl.BlockSpec((bm, F, D), lambda i: (i, 0, 0)),
                  pl.BlockSpec((F * F, P), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, P), x.dtype),
        interpret=interpret,
    )(x, sel)
