from repro.kernels.dot_interaction.ops import dot_interaction  # noqa: F401
