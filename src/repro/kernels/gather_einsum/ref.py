"""Pure-jnp oracle for the gather-aware einsum: materialize the gathered
``(B, ...)`` operand with ``jnp.take`` and contract. This IS the memory
profile the kernel removes — it exists for the allclose sweeps and as the
executor's fallback when the Pallas path is off.

Out-of-range indices clamp (``mode="clip"``): bucketed serving batches pad
``user_index`` alongside the candidate rows, and a padding row that wrapped
(numpy) or poisoned the row with NaN (jax's default ``fill``) would be a
silent correctness hazard. Clamped padding rows read a real user's reps and
their scores are sliced off by the caller, exactly like every other padded
row.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gather_einsum.kernel import parse_spec


def gather_einsum_ref(spec, x, table, user_index):
    """``einsum(spec, x, table[user_index])`` via an explicit gather."""
    _, _, _, row_spec = parse_spec(spec)
    rows = jnp.take(table, user_index, axis=0, mode="clip")
    return jnp.einsum(row_spec, x, rows)
