"""Gather-aware einsum as a TPU Pallas kernel.

Cross-user coalesced serving hands stage 2 a stacked ``(U, ...)`` user-rep
table plus a per-row ``user_index``; the materializing path gathers the
table to ``(B, ...)`` before every contraction, which at coalesced batch
sizes re-creates exactly the HBM traffic MaRI's one-shot tensors were
built to avoid (for reparam DIN the gathered ``T`` block is ``(B, L, D, h)``).
This kernel family folds the gather into the contraction: each row tile
loads its rows from the VMEM-resident table at contraction time, so the
gathered ``(B, ...)`` operand never exists in HBM.

Supported specs are the decomposed-attention contractions — the first
operand is per-row (leading ``b``), the second is the stacked table
(leading ``u``), and the output is per-row:

* ``"bd,uldh->blh"`` — q against the one-shot tensor ``T``;
* ``"bl,uld->bd"``   — attention weights against the boundary keys;
* ``"blh,uh->bl"``   — per-row contraction against a per-user vector table.

Grid: 1-D over row tiles of ``bm`` rows. Per step the kernel holds the x
tile ``(bm, ...)``, the FULL table ``(U, ...)`` and the tile's indices
``(bm, 1)`` in VMEM; ``U`` is the pow2-padded user-slot count of one
coalesced batch (small by construction — ``max_users_per_batch``), so the
table tile is the whole memory footprint and it is shared across row tiles.
Row results depend only on ``x[b]`` and ``table[idx[b]]`` — not on ``U``,
``B``, or the tile packing — which is what makes a single request (U=1)
bit-identical to the coalesced path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def parse_spec(spec: str) -> tuple[str, str, str, str]:
    """Validate a gather-einsum spec; returns (x_sub, t_sub, out_sub,
    row_spec) where ``row_spec`` is the per-row einsum after the gather
    (``u`` replaced by ``b``)."""
    try:
        lhs, out = spec.split("->")
        x_sub, t_sub = lhs.split(",")
    except ValueError:
        raise ValueError(f"gather_einsum spec must be 'b...,u...->b...', "
                         f"got {spec!r}") from None
    if not (x_sub.startswith("b") and t_sub.startswith("u")
            and out.startswith("b")):
        raise ValueError(
            f"gather_einsum spec {spec!r}: first operand must lead with the "
            f"row dim 'b', the table with the user dim 'u', the output with "
            f"'b'")
    if "u" in x_sub or "u" in out or "b" in t_sub:
        raise ValueError(f"gather_einsum spec {spec!r}: 'u' lives only on "
                         f"the table operand, 'b' never does")
    for sub in (x_sub, t_sub, out):
        if len(set(sub)) != len(sub):
            raise ValueError(f"gather_einsum spec {spec!r}: repeated dim "
                             f"in {sub!r}")
    if not set(out[1:]) <= set(x_sub[1:]) | set(t_sub[1:]):
        raise ValueError(f"gather_einsum spec {spec!r}: output dim not "
                         f"present in any operand")
    return x_sub, t_sub, out, f"{x_sub},b{t_sub[1:]}->{out}"


def _kernel(x_ref, t_ref, idx_ref, o_ref, *, row_spec):
    # Gather-at-load: this tile's rows of the stacked table, straight from
    # the VMEM-resident (U, ...) block — (B, ...) never exists in HBM.
    idx = idx_ref[...][:, 0]
    rows = jnp.take(t_ref[...], idx, axis=0)
    o_ref[...] = jnp.einsum(
        row_spec, x_ref[...], rows,
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("spec", "bm", "interpret"))
def gather_einsum_kernel(spec, x, table, user_index, *, bm=256,
                         interpret=False):
    """``einsum(spec, x, table[user_index])`` with the gather fused into the
    row-tile load.

    ``x`` is ``(B, ...)``, ``table`` the stacked ``(U, ...)`` rep table,
    ``user_index`` the ``(B,)`` int32 row->user map (caller guarantees
    in-range values and ``B % bm == 0`` — ops.py clamps and pads).
    """
    x_sub, t_sub, out_sub, row_spec = parse_spec(spec)
    if x.ndim != len(x_sub) or table.ndim != len(t_sub):
        raise ValueError(f"gather_einsum {spec!r}: operand ranks "
                         f"{x.shape}/{table.shape} do not match the spec")
    B = x.shape[0]
    if user_index.shape != (B,):
        raise ValueError(f"user_index must be ({B},), got {user_index.shape}")
    assert B % bm == 0, (B, bm)
    sizes = {c: s for c, s in zip(x_sub, x.shape)}
    for c, s in zip(t_sub, table.shape):
        if sizes.setdefault(c, s) != s:
            raise ValueError(f"gather_einsum {spec!r}: dim {c!r} is "
                             f"{sizes[c]} on x but {s} on the table")
    out_shape = tuple(sizes[c] for c in out_sub)
    out_tail = out_shape[1:]
    idx2d = user_index.astype(jnp.int32).reshape(B, 1)

    x_tail = x.shape[1:]
    zeros = lambda n: (0,) * n
    return pl.pallas_call(
        functools.partial(_kernel, row_spec=row_spec),
        grid=(B // bm,),
        in_specs=[
            pl.BlockSpec((bm,) + x_tail,
                         lambda i: (i,) + zeros(len(x_tail))),   # x tile
            pl.BlockSpec(table.shape,
                         lambda i: zeros(table.ndim)),  # whole stacked table
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),             # row indices
        ],
        out_specs=pl.BlockSpec((bm,) + out_tail,
                               lambda i: (i,) + zeros(len(out_tail))),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        interpret=interpret,
    )(x, table, idx2d)
