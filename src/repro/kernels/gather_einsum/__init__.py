from repro.kernels.gather_einsum.ops import gather_einsum  # noqa: F401
from repro.kernels.gather_einsum.ref import gather_einsum_ref  # noqa: F401
