"""Public gather-aware einsum op: clamp the index, pad the row dim to the
tile size, dispatch the Pallas kernel, slice back.

``gather_einsum(spec, x, table, user_index)`` computes
``einsum(spec, x, table[user_index])`` for specs of the form
``"b...,u...->b..."`` WITHOUT materializing the gathered ``(B, ...)``
operand — the kernel indexes the stacked ``(U, ...)`` table at row-tile
load time. ``gather_einsum_ref`` (ref.py) is the jnp.take-based oracle and
the executor's non-Pallas fallback.

Index contract (shared with ``mari_matmul``'s kernel-gather path):

* ``user_index`` is ``(B,)`` integer, row ``b`` reads ``table[user_index[b]]``;
* out-of-range values CLAMP to ``[0, U-1]`` — matching the reference's
  ``mode="clip"`` — so a garbage index in a padded row can never wrap to an
  arbitrary user or poison the row with NaN;
* rows added here to pad ``B`` up to the tile size index slot 0; their
  outputs are sliced off before returning.

Only the row dim is padded: the table/feature dims ride through at their
natural sizes, which is exact for interpret mode (the validation target —
see ``kernels/README.md``); the Mosaic alignment sweep for compiled TPU is
tracked in ROADMAP "Next (kernels)".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.gather_einsum.kernel import gather_einsum_kernel

_BLOCK_B = 256


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def gather_einsum(spec, x, table, user_index, *, interpret=True):
    """``einsum(spec, x, table[user_index])``, gather fused into the kernel.

    interpret=True on CPU (validation); False on real TPU.
    """
    B = x.shape[0]
    bm = min(_BLOCK_B, round_up(B, 8))
    Bp = round_up(B, bm)
    idx = jnp.clip(user_index.astype(jnp.int32), 0, table.shape[0] - 1)
    if Bp != B:
        x = jnp.pad(x, ((0, Bp - B),) + ((0, 0),) * (x.ndim - 1))
        idx = jnp.pad(idx, (0, Bp - B))      # padding rows index slot 0
    out = gather_einsum_kernel(spec, x, table, idx, bm=bm,
                               interpret=interpret)
    return out[:B]
