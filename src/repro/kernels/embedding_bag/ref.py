"""Pure-jnp oracle: sum/mean-pooled multi-hot embedding lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table, ids, segment_ids, num_segments, combiner="sum"):
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, table.dtype), segment_ids,
                                  num_segments=num_segments)
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out
