"""EmbeddingBag as a TPU Pallas kernel — the recsys lookup hot path.

TPU adaptation (DESIGN.md §3): there is no hardware gather into VMEM; the
idiomatic pattern is *scalar-prefetched* BlockSpecs — the (sorted) id and
segment arrays are prefetched to SMEM, and each grid step's BlockSpec
index_map selects table row ``ids[i]`` and output row ``segments[i]``.
Because the grid is sequential on TPU, consecutive steps that hit the same
output row keep it resident in VMEM and accumulate — a row-streamed
segment-sum with no HBM round-trips for the accumulator.

Requires segment_ids sorted ascending (ops.py sorts); output rows whose
segment is empty are never visited and are zeroed by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, seg_ref, row_ref, o_ref):
    i = pl.program_id(0)
    prev = seg_ref[jnp.maximum(i - 1, 0)]
    first = jnp.where(i == 0, True, seg_ref[i] != prev)

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += row_ref[...]


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def embedding_bag_kernel(table, ids_sorted, seg_sorted, *, num_segments: int,
                         interpret: bool = False):
    """table (V, D); ids_sorted/seg_sorted (nnz,) with seg sorted ascending.
    Returns (num_segments, D) sum-pooled rows (empty segments undefined —
    wrapper masks them)."""
    nnz = ids_sorted.shape[0]
    D = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nnz,),
        in_specs=[pl.BlockSpec((1, D), lambda i, ids, seg: (ids[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, ids, seg: (seg[i], 0)),
    )
    return pl.pallas_call(
        _kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, D), table.dtype),
        interpret=interpret,
    )(ids_sorted, seg_sorted, table)
