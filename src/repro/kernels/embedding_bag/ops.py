"""Public EmbeddingBag op: sorts by segment, runs the Pallas kernel, zeroes
empty segments, applies the combiner."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_kernel


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "combiner", "interpret"))
def embedding_bag(table, ids, segment_ids, *, num_segments: int,
                  combiner: str = "sum", interpret: bool = True):
    """Pooled multi-hot lookup: out[s] = pool_{i: seg[i]==s} table[ids[i]]."""
    order = jnp.argsort(segment_ids)
    ids_s = ids[order]
    seg_s = segment_ids[order]
    out = embedding_bag_kernel(table, ids_s, seg_s,
                               num_segments=num_segments, interpret=interpret)
    counts = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), segment_ids,
                                 num_segments=num_segments)
    out = jnp.where((counts > 0)[:, None], out, 0)
    if combiner == "mean":
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out
