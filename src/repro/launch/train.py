"""Training launcher: ``python -m repro.launch.train --arch <id> --shape
<train shape> [--smoke] [--steps N]``.

On this CPU container only --smoke (reduced config, host mesh) executes;
full configs are exercised via the dry-run. The launcher wires the same
CellProgram machinery either way, so the smoke path IS the production path
at reduced scale.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.train.loop import LoopConfig, train_loop


def _smoke_lm(arch: str, steps: int, ckpt_dir: str):
    from repro import configs as cfgreg
    from repro.data.lm import token_batch
    from repro.models.transformer import init_lm_params, lm_loss
    from repro.train.optim import adamw, apply_updates

    cfg = cfgreg.get_config(arch).smoke_config()
    opt = adamw(1e-3, master_weights=True)
    params = init_lm_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    state = {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch["tokens"], batch["labels"])
        )(state["params"])
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates),
                 "opt": opt_state}, {"loss": loss})

    def batches():
        key = jax.random.PRNGKey(1)
        while True:
            key, k = jax.random.split(key)
            yield token_batch(k, 8, 32, cfg.vocab)

    mgr = CheckpointManager(ckpt_dir)
    return train_loop(step, state, batches(), mgr, LoopConfig(steps))


def _smoke_recsys(arch: str, steps: int, ckpt_dir: str):
    from repro import configs as cfgreg
    from repro.data.features import make_recsys_feeds, make_labels
    from repro.graph.executor import Executor, init_graph_params
    from repro.train.losses import bce_with_logits
    from repro.train.optim import adam, apply_updates

    mod = cfgreg.get_config(arch)
    graph, *_ = mod.smoke_build()()
    ex = Executor(graph, "vani")
    outputs = list(graph.outputs)
    opt = adam(1e-3)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step(state, batch):
        feeds, labels = batch
        def loss_fn(p):
            out = ex.run(p, feeds)
            return bce_with_logits(
                jnp.concatenate([out[o] for o in outputs], -1), labels)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates),
                 "opt": opt_state}, {"loss": loss})

    def batches():
        key = jax.random.PRNGKey(1)
        while True:
            key, k1, k2 = jax.random.split(key, 3)
            feeds = make_recsys_feeds(graph, 32, k1, tile_user=True)
            yield feeds, make_labels(32, k2, len(outputs))

    mgr = CheckpointManager(ckpt_dir)
    return train_loop(step, state, batches(), mgr, LoopConfig(steps))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    from repro import configs as cfgreg
    fam = cfgreg.get_config(args.arch).FAMILY
    if fam == "lm":
        _, hist = _smoke_lm(args.arch, args.steps, args.ckpt_dir)
    elif fam == "recsys":
        _, hist = _smoke_recsys(args.arch, args.steps, args.ckpt_dir)
    else:
        raise SystemExit("use examples/train_schnet for gnn smoke training")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
