"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state. Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips, axes (pod, data, model) — 'pod' joins
the DP axes (gradient sync crosses DCN).
"""
from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devs)} — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    arr = np.asarray(devs[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_host_mesh(shape: tuple[int, ...] = (1, 1),
                   axes: tuple[str, ...] = ("data", "model")):
    """Tiny mesh over whatever devices exist — smoke tests / CPU runs."""
    import jax

    n = math.prod(shape)
    arr = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def mesh_context(mesh):
    """Context manager activating ``mesh`` for a trace, across jax
    versions: ``jax.set_mesh`` where it exists (>= 0.5), else the Mesh
    object itself (its legacy context-manager protocol). ``None`` yields
    a null context."""
    import contextlib

    import jax

    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
