"""Per-cell programs: for every (architecture × input shape) build the jitted
step function, its ShapeDtypeStruct inputs, and the in/out shardings for a
given mesh. The dry-run lowers+compiles these; train.py/serve.py execute them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs as cfgreg
from repro.core.mari import mari_rewrite
from repro.data.features import feed_specs
from repro.dist.sharding import (
    dp_axes, gnn_state_pspecs, lm_batch_pspec, lm_cache_pspecs,
    lm_param_pspecs, lm_state_pspecs, named, recsys_feed_pspecs,
    recsys_param_pspecs, recsys_state_pspecs, zero1_pspecs)
from repro.graph.executor import Executor, init_graph_params
from repro.models import schnet as schnet_mod
from repro.models.transformer import (
    LMConfig, init_lm_params, kv_cache_specs, lm_decode_step, lm_forward,
    lm_loss)
from repro.train.losses import bce_with_logits, softmax_xent
from repro.train.optim import adam, adamw, apply_updates


@dataclasses.dataclass
class CellProgram:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: tuple                      # ShapeDtypeStruct pytrees
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple[int, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)
    policy_kv: dict = dataclasses.field(default_factory=dict)
    mesh: Any = None

    def jitted(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        from repro.dist import policy
        from repro.launch.mesh import mesh_context
        with policy.use(**self.policy_kv), mesh_context(self.mesh):
            return self.jitted().lower(*self.args)


def _opt_state_specs(opt, params_sds):
    return jax.eval_shape(opt.init, params_sds)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_policy(mesh, opts) -> dict:
    kv = {}
    dp = dp_axes(mesh)
    if "moe_local" in opts:
        kv["moe_shard_axes"] = dp
    if "seq_par" in opts:
        from jax.sharding import NamedSharding
        kv["residual"] = NamedSharding(mesh, P(dp, "model", None))
    return kv


def _lm_train(cfg: LMConfig, mesh, seq: int, global_batch: int,
              opts=()) -> CellProgram:
    opt = adamw(3e-4, master_weights=True)

    def train_step(state, batch):
        def loss_fn(p):
            return lm_loss(p, cfg, batch["tokens"], batch["labels"])
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        return {"params": params, "opt": opt_state}, {"loss": loss}

    params_sds = jax.eval_shape(
        lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
    opt_sds = _opt_state_specs(opt, params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}
    batch_sds = {"tokens": jax.ShapeDtypeStruct((global_batch, seq), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)}

    pp = lm_param_pspecs(cfg)
    zp = zero1_pspecs(pp, params_sds)
    state_ps = {"params": pp,
                "opt": {"mu": zp, "nu": zp, "master": zp, "step": P()}}
    bp = lm_batch_pspec(mesh)
    in_sh = (named(mesh, state_ps), named(mesh, {"tokens": bp, "labels": bp}))
    out_sh = (named(mesh, state_ps), named(mesh, {"loss": P()}))
    return CellProgram("", "", "train", train_step, (state_sds, batch_sds),
                       in_sh, out_sh, donate_argnums=(0,),
                       policy_kv=_lm_policy(mesh, opts))


def _lm_prefill(cfg: LMConfig, mesh, seq: int, batch: int,
                opts=()) -> CellProgram:
    def prefill_step(params, tokens):
        x, kv = lm_forward(params, cfg, tokens, return_kv=True)
        logits = x[:, -1, :] @ params["lm_head"].astype(x.dtype)
        return logits, kv

    params_sds = jax.eval_shape(lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
    tok_sds = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    pp = lm_param_pspecs(cfg)
    dp = dp_axes(mesh)
    cache_ps = lm_cache_pspecs(mesh, batch)["k"]
    in_sh = (named(mesh, pp), named(mesh, P(dp, None)))
    out_sh = (named(mesh, P(dp, "model")),
              named(mesh, {"k": cache_ps, "v": cache_ps}))
    return CellProgram("", "", "prefill", prefill_step, (params_sds, tok_sds),
                       in_sh, out_sh, policy_kv=_lm_policy(mesh, opts))


def _lm_decode(cfg: LMConfig, mesh, seq: int, batch: int) -> CellProgram:
    def decode(params, cache, tokens, pos):
        return lm_decode_step(params, cfg, cache, tokens, pos)

    params_sds = jax.eval_shape(lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
    cache_sds = kv_cache_specs(cfg, batch, seq)
    tok_sds = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pp = lm_param_pspecs(cfg)
    dp = dp_axes(mesh)
    cache_ps = named(mesh, lm_cache_pspecs(mesh, batch))
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    tok_ps = named(mesh, P(dp if batch % ndp == 0 and batch >= ndp else None, None))
    in_sh = (named(mesh, pp), cache_ps, tok_ps, named(mesh, P()))
    out_sh = (named(mesh, P(None, None, "model")), cache_ps)
    return CellProgram("", "", "decode", decode,
                       (params_sds, cache_sds, tok_sds, pos_sds),
                       in_sh, out_sh, donate_argnums=(1,))


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_train(mod, mesh, batch: int, opts=()) -> CellProgram:
    graph, _spec = mod.BUILD()
    table_axes = ("model", "data") if "table_md" in opts else ("model",)
    ex = Executor(graph, "vani")
    outputs = list(graph.outputs)
    opt = adam(1e-3)

    grad_bf16 = "grad_bf16" in opts

    def train_step(state, feeds, labels):
        def loss_fn(p):
            out = ex.run(p, feeds)
            logits = jnp.concatenate([out[o] for o in outputs], axis=-1)
            return bce_with_logits(logits, labels)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if grad_bf16:
            # §Perf: halve the embedding-grad resharding traffic; adam
            # moments still accumulate in f32 inside the optimizer.
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16), grads)
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        return ({"params": apply_updates(state["params"], updates),
                 "opt": opt_state}, {"loss": loss})

    params_sds = jax.eval_shape(
        lambda: init_graph_params(graph, jax.random.PRNGKey(0)))
    if "emb_bf16" in opts:
        # §Perf: bf16 embedding tables (f32 adam moments retained) — halves
        # lookup-activation resharding traffic and table HBM footprint.
        emb_nodes = {n.name for n in graph.param_nodes()
                     if n.op == "embedding"}
        params_sds = {
            k: ({kk: jax.ShapeDtypeStruct(vv.shape, jnp.bfloat16)
                 for kk, vv in v.items()} if k in emb_nodes else v)
            for k, v in params_sds.items()}
    opt_sds = _opt_state_specs(opt, params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}
    feeds_sds = feed_specs(graph, batch, train=True)
    labels_sds = jax.ShapeDtypeStruct((batch, len(outputs)), jnp.float32)

    sp = recsys_state_pspecs(graph, table_axes=table_axes)
    state_ps = {"params": sp["params"], "opt": sp["opt"]}
    feeds_ps = recsys_feed_pspecs(graph, mesh, train=True)
    in_sh = (named(mesh, state_ps), named(mesh, feeds_ps),
             named(mesh, P(dp_axes(mesh), None)))
    out_sh = (named(mesh, state_ps), named(mesh, {"loss": P()}))
    return CellProgram("", "", "train", train_step,
                       (state_sds, feeds_sds, labels_sds), in_sh, out_sh,
                       donate_argnums=(0,))


def _recsys_serve(mod, mesh, batch: int, use_mari: bool = True,
                  mode: str = "uoi", opts=()) -> CellProgram:
    graph, _spec = mod.BUILD()
    meta = {}
    # paper-baseline variants for the roofline comparison (Fig. 1 b/c):
    if "serve_uoi" in opts:
        use_mari, mode = False, "uoi"
    if "serve_vani" in opts:
        use_mari, mode = False, "vani"
    if use_mari:
        conv = mari_rewrite(graph,
                            reparam_attention="attn_reparam" in opts)
        graph = conv.graph
        meta["mari_rewrites"] = [r.dense for r in conv.rewrites]
        meta["attn_rewrites"] = [a.node for a in conv.attn_rewrites]
        mode = "uoi"
    ex = Executor(graph, mode)
    outputs = list(graph.outputs)

    def serve_step(params, feeds):
        out = ex.run(params, feeds)
        return jnp.concatenate([out[o] for o in outputs], axis=-1)

    dtype = jnp.bfloat16 if "serve_bf16" in opts else jnp.float32
    params_sds = jax.eval_shape(
        lambda: init_graph_params(graph, jax.random.PRNGKey(0), dtype))
    if "serve_full_dp" in opts:
        # §Perf: serving has no TP need — fold 'model' into the candidate
        # DP axes (16-32x more parallelism); pad B to a shardable multiple.
        batch = ((batch + 511) // 512) * 512
        cand_axes = dp_axes(mesh) + ("model",)
        meta["padded_batch"] = batch
    else:
        cand_axes = dp_axes(mesh)
    feeds_sds = feed_specs(graph, batch, train=False)
    if "serve_bf16" in opts:
        feeds_sds = {k: (jax.ShapeDtypeStruct(v.shape, jnp.bfloat16)
                         if v.dtype == jnp.float32 else v)
                     for k, v in feeds_sds.items()}
    pp = recsys_param_pspecs(graph)
    feeds_ps = {}
    for n in graph.input_nodes():
        rank = 1 + len(n.attrs["shape"])
        lead = None if n.attrs.get("domain") == "user" else cand_axes
        feeds_ps[n.name] = P(lead, *([None] * (rank - 1)))
    in_sh = (named(mesh, pp), named(mesh, feeds_ps))
    out_sh = named(mesh, P(cand_axes, None))
    return CellProgram("", "", "serve", serve_step, (params_sds, feeds_sds),
                       in_sh, out_sh, meta=meta)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _pad_up(n: int, m: int = 1024) -> int:
    return ((n + m - 1) // m) * m


def _gnn_train(cfg, mesh, shape_spec: dict) -> CellProgram:
    mode = shape_spec["mode"]
    dp = dp_axes(mesh)
    opt = adam(1e-3)

    if mode in ("full", "sampled"):
        n_classes = shape_spec["n_classes"]
        d_feat = shape_spec["d_feat"]
        scfg = dataclasses.replace(cfg, d_feat=d_feat, n_out=n_classes)
        if mode == "full":
            n_nodes, n_edges = shape_spec["n_nodes"], shape_spec["n_edges"]
        else:
            bn, fan = shape_spec["batch_nodes"], shape_spec["fanout"]
            n, tot = bn, bn
            e = 0
            for f in fan:
                n *= f
                tot += n
                e += n
            n_nodes, n_edges = tot, e
        # edge arrays pad to a DP-shardable length; padding carries mask=0
        n_edges = _pad_up(n_edges)

        def train_step(state, batch):
            def loss_fn(p):
                out = schnet_mod.schnet_forward(
                    p, scfg, batch["features"], batch["positions"],
                    batch["senders"], batch["receivers"],
                    edge_mask=batch["edge_mask"])
                if mode == "sampled":
                    out = out[: shape_spec["batch_nodes"]]
                    labels = batch["labels"][: shape_spec["batch_nodes"]]
                else:
                    labels = batch["labels"]
                return softmax_xent(out, labels)
            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            updates, opt_state = opt.update(grads, state["opt"], state["params"])
            return ({"params": apply_updates(state["params"], updates),
                     "opt": opt_state}, {"loss": loss})

        batch_sds = {
            "features": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
            "positions": jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32),
            "senders": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "receivers": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((n_edges,), jnp.bool_),
            "labels": jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
        }
        batch_ps = {"features": P(None, None), "positions": P(None, None),
                    "senders": P(dp), "receivers": P(dp), "edge_mask": P(dp),
                    "labels": P(None)}
    else:  # molecule: batched energy regression
        scfg = dataclasses.replace(cfg, d_feat=0, n_out=1)
        ng = shape_spec["batch"]
        n_nodes = ng * shape_spec["n_nodes"]
        n_edges = _pad_up(ng * shape_spec["n_edges"])

        def train_step(state, batch):
            def loss_fn(p):
                out = schnet_mod.schnet_forward(
                    p, scfg, batch["atom_types"], batch["positions"],
                    batch["senders"], batch["receivers"],
                    edge_mask=batch["edge_mask"])
                en = schnet_mod.schnet_graph_readout(out, batch["graph_ids"], ng)
                return jnp.mean(jnp.square(en[:, 0] - batch["energies"]))
            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            updates, opt_state = opt.update(grads, state["opt"], state["params"])
            return ({"params": apply_updates(state["params"], updates),
                     "opt": opt_state}, {"loss": loss})

        batch_sds = {
            "atom_types": jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
            "positions": jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32),
            "senders": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "receivers": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((n_edges,), jnp.bool_),
            "graph_ids": jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
            "energies": jax.ShapeDtypeStruct((ng,), jnp.float32),
        }
        batch_ps = {"atom_types": P(None), "positions": P(None, None),
                    "senders": P(dp), "receivers": P(dp), "edge_mask": P(dp),
                    "graph_ids": P(None), "energies": P(None)}

    params_sds = jax.eval_shape(
        lambda: schnet_mod.init_schnet_params(scfg, jax.random.PRNGKey(0)))
    opt_sds = _opt_state_specs(opt, params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}
    sp = gnn_state_pspecs(params_sds)
    state_ps = {"params": sp["params"], "opt": sp["opt"]}
    in_sh = (named(mesh, state_ps), named(mesh, batch_ps))
    out_sh = (named(mesh, state_ps), named(mesh, {"loss": P()}))
    return CellProgram("", "", "train", train_step, (state_sds, batch_sds),
                       in_sh, out_sh, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape: str, mesh, opts=(), **kw) -> CellProgram:
    """opts: named §Perf optimizations — 'moe_local', 'seq_par',
    'attn_reparam', 'serve_full_dp', 'serve_bf16'."""
    opts = frozenset(opts)
    mod = cfgreg.get_config(arch)
    spec = mod.SHAPES[shape]
    if spec.get("skip"):
        raise ValueError(f"cell ({arch}, {shape}) is skipped: {spec['skip']}")
    fam = mod.FAMILY
    if fam == "lm":
        cfg = mod.CONFIG
        if spec["kind"] == "train":
            prog = _lm_train(cfg, mesh, spec["seq"], spec["global_batch"],
                             opts)
        elif spec["kind"] == "prefill":
            prog = _lm_prefill(cfg, mesh, spec["seq"], spec["global_batch"],
                               opts)
        else:
            prog = _lm_decode(cfg, mesh, spec["seq"], spec["global_batch"])
    elif fam == "recsys":
        if spec["kind"] == "train":
            prog = _recsys_train(mod, mesh, spec["batch"], opts=opts)
        else:
            prog = _recsys_serve(mod, mesh, spec["batch"], opts=opts, **kw)
    elif fam == "gnn":
        prog = _gnn_train(mod.CONFIG, mesh, spec)
    else:
        raise ValueError(fam)
    prog.arch, prog.shape = arch, shape
    prog.mesh = mesh
    return prog
