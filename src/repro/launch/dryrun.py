"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh; record
memory_analysis, cost_analysis and the collective schedule for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # subprocess per cell, JSON out
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import re
import subprocess
import sys
import time

RESULTS_PATH = "experiments/dryrun_results.json"

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9          # per-link; single-link conservative assumption

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:[a-z0-9]+)\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_SCALAR_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")


def parse_collectives(hlo: str) -> dict:
    """Per-device collective traffic with WHILE-LOOP TRIP COUNTS applied.

    cost_analysis counts while bodies once; scan-over-layers would otherwise
    undercount in-loop collectives by n_layers. We attribute every collective
    def to its computation (headers sit at column 0, instructions are
    indented), rebuild the while call graph (condition/body edges), read each
    loop's trip count from the scalar integer literal in its condition, and
    multiply body traffic through nested loops. Ring all-reduce moves ~2x
    the buffer, others ~1x.
    """
    coll_bytes: dict[str, dict] = {}
    consts: dict[str, int] = {}
    whiles: list[tuple[str, str, str]] = []   # (parent, cond, body)
    entry = None
    cur = "?"
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _HDR_RE.match(line.lstrip("%"))
            mm = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if mm:
                cur = mm.group(2)
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        m = _COLLECTIVE_RE.search(line)
        if m and "-done(" not in line:
            d = coll_bytes.setdefault(cur, {
                "all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
                "all-to-all": 0, "collective-permute": 0, "count": 0})
            d[m.group(3)] += _shape_bytes(m.group(1) or m.group(2))
            d["count"] += 1
        m = _WHILE_RE.search(line)
        if m:
            whiles.append((cur, m.group(1), m.group(2)))
        m = _SCALAR_CONST_RE.search(line)
        if m:
            consts[cur] = max(consts.get(cur, 1), int(m.group(1)))

    # multipliers via while edges (iterate to fixpoint over nesting depth)
    mult: dict[str, int] = {entry or "?": 1}
    for _ in range(8):
        changed = False
        for parent, cond, body in whiles:
            if parent in mult:
                trip = consts.get(cond, 1)
                new = mult[parent] * max(trip, 1)
                if mult.get(body, 0) < new:
                    mult[body] = new
                    changed = True
        if not changed:
            break

    total = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
             "all-to-all": 0, "collective-permute": 0, "count": 0}
    for comp, d in coll_bytes.items():
        f = mult.get(comp, 1)
        for k in total:
            total[k] += d[k] * (f if k != "count" else 1)
    total["traffic_bytes"] = (2 * total["all-reduce"] + total["all-gather"]
                              + total["reduce-scatter"] + total["all-to-all"]
                              + total["collective-permute"])
    total["max_loop_trip"] = max(mult.values(), default=1)
    return total


def run_cell(arch: str, shape: str, mesh_kind: str, opts=()) -> dict:
    import jax

    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    prog = build_cell(arch, shape, mesh, opts=opts)
    with mesh:
        lowered = prog.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned a list
        cost = cost[0]
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    # cost_analysis counts while bodies ONCE; our scan-over-layers families
    # need the trip-count factor applied (collectives get exact per-loop
    # multipliers in parse_collectives; flops/bytes get the layer factor —
    # in-loop work dominates, error is O(1/L); the flash-attention inner
    # loops make the flops a LOWER bound for the attention component, see
    # EXPERIMENTS.md §Roofline methodology).
    from repro import configs as cfgreg
    mod = cfgreg.get_config(arch)
    if mod.FAMILY == "lm":
        scan_factor = mod.CONFIG.n_layers
    elif mod.FAMILY == "gnn":
        scan_factor = mod.CONFIG.n_interactions
    else:
        scan_factor = 1

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    flops = flops_raw * scan_factor
    bytes_acc = bytes_raw * scan_factor
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "opts": sorted(opts), "kind": prog.kind, "meta": prog.meta,
        "devices": int(mesh.devices.size),
        "scan_factor": scan_factor,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc,
                 "flops_raw": flops_raw, "bytes_raw": bytes_raw},
        "collectives": coll,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll["traffic_bytes"] / ICI_BW,
        },
    }
    terms = rec["roofline"]
    rec["roofline"]["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    return rec


def _cells(args):
    from repro import configs as cfgreg

    for cell in cfgreg.all_cells(include_paper=args.include_paper):
        if args.arch and cell.arch != args.arch:
            continue
        if args.shape and cell.shape != args.shape:
            continue
        yield cell


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in an isolated subprocess")
    ap.add_argument("--include-paper", action="store_true",
                    help="also run the paper's own ranking model")
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--opts", default="",
                    help="comma-separated §Perf optimization names")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        results = []
        if os.path.exists(args.out):
            results = json.load(open(args.out))
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results
                if "error" not in r}
        for cell in _cells(args):
            for mk in meshes:
                if (cell.arch, cell.shape, mk) in done:
                    continue
                if cell.skip_reason:
                    results.append({"arch": cell.arch, "shape": cell.shape,
                                    "mesh": mk, "skipped": cell.skip_reason})
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", cell.arch, "--shape", cell.shape,
                       "--mesh", mk]
                print(f"[dryrun] {cell.arch} × {cell.shape} × {mk} ...",
                      flush=True)
                try:
                    p = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    line = p.stdout.strip().splitlines()[-1] if p.stdout.strip() else ""
                    rec = json.loads(line) if line.startswith("{") else {
                        "error": (p.stderr or p.stdout)[-2000:]}
                except subprocess.TimeoutExpired:
                    rec = {"error": f"timeout after {args.timeout}s"}
                rec.update({"arch": cell.arch, "shape": cell.shape, "mesh": mk})
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (cell.arch, cell.shape, mk)]
                results.append(rec)
                json.dump(results, open(args.out, "w"), indent=1)
                status = ("OK" if "error" not in rec
                          else "FAIL: " + rec["error"].splitlines()[-1][:120])
                print(f"[dryrun]   -> {status}", flush=True)
        nerr = sum(1 for r in results if "error" in r)
        print(f"[dryrun] done: {len(results)} records, {nerr} failures")
        return 1 if nerr else 0

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    for mk in meshes:
        rec = run_cell(args.arch, args.shape, mk, opts=opts)
        print(json.dumps(rec))
    return 0


if __name__ == "__main__":
    sys.exit(main())
