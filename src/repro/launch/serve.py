"""Serving launcher: scores a stream of synthetic requests through the
ServingEngine under vani/uoi/mari and reports latency stats.

``python -m repro.launch.serve --arch din --mode mari --requests 20``
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.serve.engine import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="din")
    ap.add_argument("--mode", choices=["vani", "uoi", "mari"], default="mari")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--candidates", type=int, default=2048)
    ap.add_argument("--max-batch", type=int, default=1024)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--reparam-attention", action="store_true",
                    help="mari: also re-parameterize eligible "
                         "target_attention units (beyond-paper rewrite)")
    ap.add_argument("--gather-attention", action="store_true",
                    help="consume decomposed-attention boundary tensors as "
                         "stacked (U, ...) tables indexed inside the "
                         "contractions (gather-at-load; pairs with "
                         "--reparam-attention)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route mari_dense + gather_einsum through the "
                         "Pallas kernels (interpret mode off-TPU)")
    args = ap.parse_args()

    from repro import configs as cfgreg
    mod = cfgreg.get_config(args.arch)
    build = mod.smoke_build() if args.smoke else mod.BUILD
    graph, *_ = build()
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    engine = ServingEngine(graph, params, mode=args.mode,
                           max_batch=args.max_batch,
                           reparam_attention=args.reparam_attention,
                           gather_attention=args.gather_attention,
                           use_pallas=args.use_pallas)
    if engine.conversion:
        print("[serve] MaRI rewrote:",
              [r.dense for r in engine.conversion.rewrites])

    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    lats = []
    key = jax.random.PRNGKey(7)
    for r in range(args.requests):
        key, k = jax.random.split(key)
        feeds = make_recsys_feeds(graph, args.candidates, k)
        req = ServeRequest(
            user_id=r % 8,
            user_feeds={k2: v for k2, v in feeds.items() if k2 in user_in},
            candidate_feeds={k2: v for k2, v in feeds.items()
                             if k2 not in user_in})
        res = engine.score(req)
        lats.append(res.latency_ms)
    lats = np.asarray(lats[2:])  # drop compile warmup
    print(f"[serve] mode={args.mode} n={len(lats)} "
          f"avg={lats.mean():.2f}ms p50={np.percentile(lats, 50):.2f}ms "
          f"p99={np.percentile(lats, 99):.2f}ms")


if __name__ == "__main__":
    main()
