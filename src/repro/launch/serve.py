"""Serving launcher: scores a stream of synthetic requests through the
serving runtime and reports latency stats. Configuration is a
``ServePlan`` (``repro.serve.plan``) — from a JSON file, a named preset,
or the flag overrides — instead of hand-threaded engine kwargs.

Single-scenario (one ``ServingEngine``)::

  python -m repro.launch.serve --arch din --mode mari --requests 20
  python -m repro.launch.serve --plan plan.json --requests 3
  python -m repro.launch.serve --preset tpu --dump-plan plan.json

Multi-scenario (a ``RankingService`` routing an interleaved stream)::

  python -m repro.launch.serve --scenario din,deepfm,fm --requests 12

``--smoke`` is on by default; ``--no-smoke`` builds the full-size
registry models.

``--trace out.json`` turns on ``ObsPlan.trace`` for the run and writes a
Chrome trace-event file (load it at https://ui.perfetto.dev) covering the
whole request lifecycle — stage-1 spans, cache hit/miss instants, pack/
dispatch/collect, and one synthetic track per outstanding group.

``--cold-tier`` arms the ``MemPlan`` host-RAM cold tier, bulk-warms the
even user ids of the synthetic stream into the cold arena, and reports
cold hits / async promotions after the stream — so a traced run emits
the ``warm`` / ``cold_hit`` / ``promote`` instants.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.serve import RankingService, ServePlan, ServeRequest, ServingEngine
from repro.serve.plan import MODES, PRESETS


def build_plan(args) -> ServePlan:
    """Resolve the serving plan: file < preset < explicit flag overrides."""
    if args.plan and args.preset:
        raise SystemExit("pass --plan or --preset, not both")
    if args.plan:
        plan = ServePlan.load(args.plan)
    elif args.preset:
        plan = ServePlan.preset(args.preset)
    else:
        plan = ServePlan()
    over = {}
    if args.mode is not None:
        over["graph__mode"] = args.mode
    if args.max_batch is not None:
        over["batch__max_batch"] = args.max_batch
    if args.reparam_attention is not None:
        over["graph__reparam_attention"] = args.reparam_attention
    if args.gather_attention is not None:
        over["kernel__gather_attention"] = args.gather_attention
    if args.use_pallas is not None:
        over["kernel__use_pallas"] = args.use_pallas
    if args.continuous is not None:
        over["batch__continuous"] = args.continuous
    if args.cold_tier is not None:
        over["mem__cold_tier"] = args.cold_tier
    if args.trace:
        over["obs__trace"] = True
    return plan.evolve(**over) if over else plan


def _warm_half(warm, graph, split, candidates: int, n_uids: int = 8):
    """Bulk-warm the EVEN user ids of the launcher's ``r % n_uids`` stream
    into the cold arena. Odd ids stay unwarmed, so one interleaved stream
    deterministically exercises every tier: even ids cold-hit (and, after
    enough touches, promote); odd ids pay stage 1 once and then hot-hit."""
    key = jax.random.PRNGKey(11)
    items = []
    for uid in range(0, n_uids, 2):
        key, k = jax.random.split(key)
        uf, _ = split(make_recsys_feeds(graph, candidates, k))
        items.append((uid, uf))
    return warm(items)


def _summary(tag: str, lats: list[float]) -> None:
    if not lats:        # e.g. more scenarios than requests in round-robin
        print(f"[serve] {tag} n=0 (no requests routed)")
        return
    lats = np.asarray(lats)
    print(f"[serve] {tag} n={len(lats)} "
          f"avg={lats.mean():.2f}ms p50={np.percentile(lats, 50):.2f}ms "
          f"p99={np.percentile(lats, 99):.2f}ms")


def serve_single(args, plan: ServePlan) -> None:
    from repro import configs as cfgreg
    mod = cfgreg.get_config(args.arch)
    build = mod.smoke_build() if args.smoke else mod.BUILD
    graph, *_ = build()
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    engine = ServingEngine(graph, params, plan=plan)
    if engine.conversion:
        print("[serve] MaRI rewrote:",
              [r.dense for r in engine.conversion.rewrites])

    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}

    def split(feeds):
        return ({k: v for k, v in feeds.items() if k in user_in},
                {k: v for k, v in feeds.items() if k not in user_in})

    if engine.cold_tier:
        warmed = _warm_half(engine.warm, graph, split, args.candidates)
        print(f"[serve] warmed {warmed} users into the cold tier")
    lats = []
    key = jax.random.PRNGKey(7)
    for r in range(args.requests):
        key, k = jax.random.split(key)
        feeds = make_recsys_feeds(graph, args.candidates, k)
        req = ServeRequest(
            user_id=r % 8,
            user_feeds={k2: v for k2, v in feeds.items() if k2 in user_in},
            candidate_feeds={k2: v for k2, v in feeds.items()
                             if k2 not in user_in})
        res = engine.score(req)
        lats.append(res.latency_ms)
    if engine.cold_tier:
        engine.flush_promotions()
        ms = engine.mem_stats()
        print(f"[serve] mem cold_users={ms['cold']['users']} "
              f"cold_hits={ms['cold_hits']} "
              f"promotions={ms['promote']['promotions']}")
    if args.trace and engine.tracer is not None:
        from repro.obs import write_trace
        write_trace(args.trace, {args.arch: engine.tracer})
        print(f"[serve] wrote trace -> {args.trace} "
              f"({len(engine.tracer)} events, "
              f"{engine.tracer.dropped} dropped)")
    engine.close()
    _summary(f"arch={args.arch} mode={engine.mode}",
             lats[min(2, len(lats) - 1):])   # drop compile warmup


def serve_multi(args, plan: ServePlan, scenarios: list[str]) -> None:
    """Route an interleaved request stream across several scenario models
    hosted by one ``RankingService`` (shared rep-cache budget, per-scenario
    engines + batchers)."""
    with RankingService(plan, smoke=args.smoke) as svc:
        for sc in scenarios:
            svc.register(sc)
        print(f"[serve] scenarios={','.join(svc.scenarios)} "
              f"(interleaved round-robin)")
        for sc in scenarios:
            if svc.engine(sc).cold_tier:
                warmed = _warm_half(
                    lambda items, sc=sc: svc.warm(sc, items),
                    svc.source_graph(sc),
                    lambda feeds, sc=sc: svc.split_feeds(sc, feeds),
                    args.candidates)
                print(f"[serve] scenario={sc} warmed {warmed} users into "
                      f"the cold tier")
        key = jax.random.PRNGKey(7)
        items = []
        for r in range(args.requests):
            sc = scenarios[r % len(scenarios)]
            key, k = jax.random.split(key)
            feeds = make_recsys_feeds(svc.source_graph(sc),
                                      args.candidates, k)
            uf, cf = svc.split_feeds(sc, feeds)
            items.append((sc, ServeRequest(user_id=r % 8, user_feeds=uf,
                                           candidate_feeds=cf)))
        svc.score_many(items)                # compile warmup pass, untimed
        results = svc.score_many(items)
        per = {sc: [] for sc in scenarios}
        for (sc, _), res in zip(items, results):
            per[sc].append(res.latency_ms)
        for sc in scenarios:
            _summary(f"scenario={sc}", per[sc])
        cache = svc.stats()["shared_cache"]
        print(f"[serve] shared_cache users={cache['users']} "
              f"hits={cache['hits']} misses={cache['misses']} "
              f"evictions={cache['evictions']}")
        for sc in scenarios:
            eng = svc.engine(sc)
            if eng.cold_tier:
                eng.flush_promotions()
                ms = eng.mem_stats()
                print(f"[serve] scenario={sc} mem "
                      f"cold_users={ms['cold']['users']} "
                      f"cold_hits={ms['cold_hits']} "
                      f"promotions={ms['promote']['promotions']}")
        if args.trace:
            tracers = {sc: svc.engine(sc).tracer for sc in svc.scenarios
                       if svc.engine(sc).tracer is not None}
            if tracers:
                from repro.obs import write_trace
                write_trace(args.trace, tracers)
                n = sum(len(t) for t in tracers.values())
                print(f"[serve] wrote trace -> {args.trace} "
                      f"({n} events across {len(tracers)} scenarios)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="din",
                    help="single-scenario architecture (configs registry)")
    ap.add_argument("--scenario", default=None,
                    help="comma-separated scenario list — serves them all "
                         "through one RankingService (overrides --arch)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="load the ServePlan from a JSON file")
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None,
                    help="start from a named ServePlan preset")
    ap.add_argument("--dump-plan", default=None, metavar="PATH",
                    help="write the resolved plan JSON and continue")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--candidates", type=int, default=2048)
    # BooleanOptionalAction gives --smoke/--no-smoke; the old
    # action="store_true", default=True made the flag impossible to turn
    # off, so full-size builds were unreachable from the CLI
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="registry smoke builds (--no-smoke = full size)")
    # plan overrides: default None means "whatever the plan says"
    ap.add_argument("--mode", choices=list(MODES), default=None)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--reparam-attention",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="mari: also re-parameterize eligible "
                         "target_attention units (beyond-paper rewrite)")
    ap.add_argument("--gather-attention",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="consume decomposed-attention boundary tensors as "
                         "stacked (U, ...) tables indexed inside the "
                         "contractions (gather-at-load; pairs with "
                         "--reparam-attention)")
    ap.add_argument("--use-pallas",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="route mari_dense + gather_einsum through the "
                         "Pallas kernels (interpret mode off-TPU)")
    ap.add_argument("--continuous",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="continuous (two-phase overlapped) dispatch loop "
                         "in the scenario batchers")
    ap.add_argument("--cold-tier",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="host-RAM cold rep tier (MemPlan): bulk-warm the "
                         "even user ids of the stream, serve cold hits "
                         "from the arena, promote hot users async")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable ObsPlan tracing and write a Perfetto-"
                         "loadable Chrome trace-event JSON here")
    args = ap.parse_args()

    plan = build_plan(args)
    if args.dump_plan:
        plan.save(args.dump_plan)
        print(f"[serve] wrote plan -> {args.dump_plan}")
    if args.requests < 1:
        return
    if args.scenario:
        # dedupe while preserving order: registering a scenario twice is a
        # service-level error, not something a CLI typo should crash on
        scenarios = list(dict.fromkeys(
            s for s in args.scenario.split(",") if s))
        if not scenarios:
            raise SystemExit("--scenario needs at least one scenario name")
        serve_multi(args, plan, scenarios)
    else:
        serve_single(args, plan)


if __name__ == "__main__":
    main()
