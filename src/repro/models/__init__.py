from repro.models.recsys import (  # noqa: F401
    RecSysSpec,
    build_dlrm,
    build_fm,
    build_deepfm,
    build_din,
)
from repro.models.ranking import build_paper_ranking_model, PaperRankingConfig  # noqa: F401
from repro.models.transformer import LMConfig, init_lm_params, lm_forward  # noqa: F401
from repro.models.schnet import SchNetConfig, init_schnet_params, schnet_forward  # noqa: F401
