"""The four assigned recsys architectures as colored feature-fusion graphs.

Each builder returns ``(Graph, RecSysSpec)``. Feature fields are split into
user-side and item-side groups (Criteo fields carry no public user/item
labels, so the split is a documented synthetic assignment — DESIGN.md §4);
the split is what makes UOI/MaRI applicable, exactly as in the paper's
production models.

All graphs output a single ``logit`` node (CTR-style binary task).
"""
from __future__ import annotations

import dataclasses

from repro.graph.ir import Graph, GraphBuilder

# MLPerf DLRM (Criteo 1TB) sparse table row counts [arXiv:1906.00091; MLPerf].
DLRM_TABLE_ROWS = [
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
]


SHARD_PAD = 256       # tables >= SHARD_THRESHOLD rows pad to this multiple so
SHARD_THRESHOLD = 65536  # they shard evenly over ('model','data') (ZeRO)


def pad_vocab(v: int) -> int:
    if v < SHARD_THRESHOLD:
        return v
    return ((v + SHARD_PAD - 1) // SHARD_PAD) * SHARD_PAD


@dataclasses.dataclass(frozen=True)
class RecSysSpec:
    name: str
    user_fields: tuple[str, ...]
    item_fields: tuple[str, ...]
    cross_fields: tuple[str, ...]
    embed_dim: int
    vocab_sizes: dict[str, int]
    seq_len: int = 0                      # DIN behaviour sequence
    n_dense: int = 0                      # DLRM dense features
    expected_eligible: tuple[str, ...] = ()   # matmuls GCA must find

    @property
    def all_fields(self) -> tuple[str, ...]:
        return self.user_fields + self.item_fields + self.cross_fields


def _field_split(n: int, prefix: str, n_user: int) -> tuple[list[str], list[str]]:
    names = [f"{prefix}_{i}" for i in range(n)]
    return names[:n_user], names[n_user:]


# ---------------------------------------------------------------------------
# DLRM (MLPerf config): 13 dense + 26 sparse, dot interaction, top MLP
# ---------------------------------------------------------------------------

def build_dlrm(
    embed_dim: int = 128,
    bot_mlp: tuple[int, ...] = (512, 256, 128),
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1),
    n_dense: int = 13,
    table_rows: list[int] | None = None,
    scale_tables: float = 1.0,
) -> tuple[Graph, RecSysSpec]:
    rows = table_rows or DLRM_TABLE_ROWS
    rows = [pad_vocab(max(4, int(r * scale_tables))) for r in rows]
    n_sparse = len(rows)
    n_user_sparse = n_sparse // 2  # synthetic split: first half user-side
    user_sp, item_sp = _field_split(n_sparse, "sparse", n_user_sparse)

    b = GraphBuilder()
    # dense features = request/user context -> bottom MLP (user-side, one-shot)
    dense_in = b.input("user_dense", (n_dense,), "user")
    h = dense_in
    for li, width in enumerate(bot_mlp):
        h = b.dense(f"bot_mlp_{li}", h, width, activation="relu")
    bot_out = h  # (embed_dim,)

    emb_nodes = []
    vocab = {}
    for fi, f in enumerate(user_sp + item_sp):
        dom = "user" if f in user_sp else "item"
        ids = b.input(f"{f}_ids", (), dom, dtype="int32")
        emb = b.embedding(f"{f}_emb", ids, vocab=rows[fi], dim=embed_dim)
        vocab[f] = rows[fi]
        emb_nodes.append(emb)

    stacked = b.stack_features("feat_stack", [bot_out] + emb_nodes)
    inter = b.dot_interaction("dot_inter", stacked)
    fusion = b.concat("top_in", [bot_out, inter])  # mixed: user bottom + blue inter
    h = fusion
    for li, width in enumerate(top_mlp):
        last = li == len(top_mlp) - 1
        h = b.dense(f"top_mlp_{li}", h, width,
                    activation="identity" if last else "relu")
    b.output(h)
    spec = RecSysSpec(
        name="dlrm-mlperf", user_fields=tuple(user_sp), item_fields=tuple(item_sp),
        cross_fields=(), embed_dim=embed_dim, vocab_sizes=vocab, n_dense=n_dense,
        expected_eligible=("top_mlp_0",))
    return b.graph, spec


# ---------------------------------------------------------------------------
# FM (Rendle '10): linear + pairwise via sum-square trick, decomposed so the
# user-side partial sums run one-shot (UOI philosophy on a non-matmul op).
# ---------------------------------------------------------------------------

def build_fm(
    n_sparse: int = 39,
    embed_dim: int = 10,
    vocab_size: int = 100_000,
    n_user: int = 20,
) -> tuple[Graph, RecSysSpec]:
    user_f, item_f = _field_split(n_sparse, "field", n_user)
    vocab_size = pad_vocab(vocab_size)
    b = GraphBuilder()
    vocab = {}

    def field_embs(fields, dom):
        vs, lins = [], []
        for f in fields:
            ids = b.input(f"{f}_ids", (), dom, dtype="int32")
            vs.append(b.embedding(f"{f}_v", ids, vocab=vocab_size, dim=embed_dim))
            lins.append(b.embedding(f"{f}_w", ids, vocab=vocab_size, dim=1))
            vocab[f] = vocab_size
        return vs, lins

    uv, ul = field_embs(user_f, "user")
    iv, il = field_embs(item_f, "item")

    # linear term: user part pooled once (batch 1), item part at B.
    u_lin = b.reduce("u_lin_sum", b.stack_features("u_lin_stack", ul), "sum", -2)
    i_lin = b.reduce("i_lin_sum", b.stack_features("i_lin_stack", il), "sum", -2)
    lin = b.add("linear_term", u_lin, i_lin)

    # 2-way term, decomposed: S = S_u + S_i ; SS = SS_u + SS_i
    u_stack = b.stack_features("u_v_stack", uv)     # (1, Fu, D)
    i_stack = b.stack_features("i_v_stack", iv)     # (B, Fi, D)
    s_u = b.reduce("s_u", u_stack, "sum", -2)
    s_i = b.reduce("s_i", i_stack, "sum", -2)
    s = b.add("s_total", s_u, s_i)                   # (B, D)
    sq_u = b.reduce("sq_u", b.mul("u_sq", u_stack, u_stack), "sum", -2)
    sq_i = b.reduce("sq_i", b.mul("i_sq", i_stack, i_stack), "sum", -2)
    sq = b.add("sq_total", sq_u, sq_i)
    s2 = b.mul("s_sq", s, s)
    pair = b.scale("half", b.reduce("pair_sum", b.sub("diff", s2, sq), "sum", -1), 0.5)
    pair = b.reshape("pair_col", pair, (1,))
    logit = b.add("logit", lin, pair)
    b.output(logit)
    spec = RecSysSpec(
        name="fm", user_fields=tuple(user_f), item_fields=tuple(item_f),
        cross_fields=(), embed_dim=embed_dim, vocab_sizes=vocab,
        expected_eligible=())  # FM has no eligible matmul — §Arch-applicability
    return b.graph, spec


# ---------------------------------------------------------------------------
# DIN: target attention over user behaviour sequence + fusion MLP
# ---------------------------------------------------------------------------

def build_din(
    embed_dim: int = 18,
    seq_len: int = 100,
    attn_mlp: tuple[int, ...] = (80, 40),
    mlp: tuple[int, ...] = (200, 80),
    item_vocab: int = 200_000,
    user_profile_dim: int = 36,
    context_dim: int = 12,
) -> tuple[Graph, RecSysSpec]:
    item_vocab = pad_vocab(item_vocab)
    b = GraphBuilder()
    # user side: profile vector + behaviour sequence ids (computed one-shot)
    profile = b.input("user_profile", (user_profile_dim,), "user")
    seq_ids = b.input("user_seq_ids", (seq_len,), "user", dtype="int32")
    seq_emb = b.embedding("user_seq_emb", seq_ids, vocab=item_vocab, dim=embed_dim)

    # item side: candidate id + context
    item_ids = b.input("item_ids", (), "item", dtype="int32")
    item_emb = b.embedding("item_emb", item_ids, vocab=item_vocab, dim=embed_dim)
    context = b.input("cross_context", (context_dim,), "cross")

    interest = b.target_attention("din_attn", item_emb, seq_emb,
                                  mlp_hidden=attn_mlp)  # (B, D)
    fusion = b.concat("fusion", [profile, interest, item_emb, context])
    h = fusion
    for li, width in enumerate(mlp):
        h = b.dense(f"mlp_{li}", h, width, activation="relu")
    logit = b.dense("logit", h, 1)
    b.output(logit)
    spec = RecSysSpec(
        name="din", user_fields=("user_profile", "user_seq_ids"),
        item_fields=("item_ids",), cross_fields=("cross_context",),
        embed_dim=embed_dim, vocab_sizes={"item": item_vocab}, seq_len=seq_len,
        expected_eligible=("mlp_0",))
    return b.graph, spec


# ---------------------------------------------------------------------------
# DeepFM: FM component + deep MLP over concatenated field embeddings
# ---------------------------------------------------------------------------

def build_deepfm(
    n_sparse: int = 39,
    embed_dim: int = 10,
    mlp: tuple[int, ...] = (400, 400, 400),
    vocab_size: int = 100_000,
    n_user: int = 20,
) -> tuple[Graph, RecSysSpec]:
    user_f, item_f = _field_split(n_sparse, "field", n_user)
    vocab_size = pad_vocab(vocab_size)
    b = GraphBuilder()
    vocab = {}
    u_emb, i_emb, u_lin, i_lin = [], [], [], []
    for f in user_f + item_f:
        dom = "user" if f in user_f else "item"
        ids = b.input(f"{f}_ids", (), dom, dtype="int32")
        (u_emb if dom == "user" else i_emb).append(
            b.embedding(f"{f}_v", ids, vocab=vocab_size, dim=embed_dim))
        (u_lin if dom == "user" else i_lin).append(
            b.embedding(f"{f}_w", ids, vocab=vocab_size, dim=1))
        vocab[f] = vocab_size

    # FM component (decomposed like build_fm)
    lin = b.add("linear_term",
                b.reduce("u_lin_sum", b.stack_features("u_lin_stack", u_lin), "sum", -2),
                b.reduce("i_lin_sum", b.stack_features("i_lin_stack", i_lin), "sum", -2))
    u_stack = b.stack_features("u_v_stack", u_emb)
    i_stack = b.stack_features("i_v_stack", i_emb)
    s = b.add("s_total", b.reduce("s_u", u_stack, "sum", -2),
              b.reduce("s_i", i_stack, "sum", -2))
    sq = b.add("sq_total",
               b.reduce("sq_u", b.mul("u_sq", u_stack, u_stack), "sum", -2),
               b.reduce("sq_i", b.mul("i_sq", i_stack, i_stack), "sum", -2))
    pair = b.scale("half", b.reduce("pair_sum",
                                    b.sub("diff", b.mul("s_sq", s, s), sq),
                                    "sum", -1), 0.5)
    fm_logit = b.add("fm_logit", lin, b.reshape("pair_col", pair, (1,)))

    # deep component: concat of ALL field embeddings — mixed concat, fc1 eligible
    deep_in = b.concat("deep_in", u_emb + i_emb)
    h = deep_in
    for li, width in enumerate(mlp):
        h = b.dense(f"deep_mlp_{li}", h, width, activation="relu")
    deep_logit = b.dense("deep_logit", h, 1)
    logit = b.add("logit", fm_logit, deep_logit)
    b.output(logit)
    spec = RecSysSpec(
        name="deepfm", user_fields=tuple(user_f), item_fields=tuple(item_f),
        cross_fields=(), embed_dim=embed_dim, vocab_sizes=vocab,
        expected_eligible=("deep_mlp_0",))
    return b.graph, spec
