"""The paper's reference ranking model (Fig. 1): user tower + candidate
cross-attention over the behaviour sequence + MMoE + per-task towers.

Contains all three MaRI sites named in §2.5:
  (1) first FC of every MMoE expert,
  (2) first FC of each task tower,
  (3) the query projection of the cross-attention
and therefore serves as the GCA acceptance test and the Table-1 benchmark
model (coarse-ranking variant uses smaller dims).
"""
from __future__ import annotations

import dataclasses

from repro.graph.ir import Graph, GraphBuilder


@dataclasses.dataclass(frozen=True)
class PaperRankingConfig:
    d_user_profile: int = 4000     # matches Table-2 "D_user = 4000" regime
    d_item: int = 500
    d_cross: int = 500
    seq_len: int = 128             # user behaviour sequence length
    d_seq: int = 64                # per-event embedding dim
    d_attn: int = 64               # cross-attention width
    n_experts: int = 4
    d_expert: tuple[int, ...] = (512, 256)
    n_tasks: int = 2               # e.g. ctr + long-view
    d_tower: tuple[int, ...] = (128, 64)
    d_user_tower: int = 256
    # hidden widths of the user tower before the final d_user_tower layer.
    # None keeps the classic two-layer tower (one d_user_tower hidden); a
    # tuple like (4096, 4096, 4096) builds a deep/wide tower — the
    # industrial regime where stage-1 reuse is worth caching, used by the
    # serving benchmarks to measure cache-hit speedup at realistic
    # stage-1/stage-2 cost ratios.
    user_tower_widths: tuple[int, ...] | None = None

    def scaled(self, f: float) -> "PaperRankingConfig":
        s = lambda x: max(8, int(x * f))
        return dataclasses.replace(
            self, d_user_profile=s(self.d_user_profile), d_item=s(self.d_item),
            d_cross=s(self.d_cross), seq_len=max(4, int(self.seq_len * f)),
            d_seq=s(self.d_seq), d_attn=s(self.d_attn),
            d_expert=tuple(s(x) for x in self.d_expert),
            d_tower=tuple(s(x) for x in self.d_tower),
            d_user_tower=s(self.d_user_tower),
            user_tower_widths=(None if self.user_tower_widths is None
                               else tuple(s(x)
                                          for x in self.user_tower_widths)))


def build_paper_ranking_model(cfg: PaperRankingConfig = PaperRankingConfig()
                              ) -> tuple[Graph, PaperRankingConfig]:
    b = GraphBuilder()
    # ---- inputs ----
    profile = b.input("user_profile", (cfg.d_user_profile,), "user")
    seq = b.input("user_seq", (cfg.seq_len, cfg.d_seq), "user")
    item = b.input("item_feats", (cfg.d_item,), "item")
    cross = b.input("cross_feats", (cfg.d_cross,), "cross")

    # ---- user tower (entirely one-shot under UOI) ----
    # default: the classic fc1(d_user_tower) -> fc2(d_user_tower) pair;
    # user_tower_widths replaces the hidden chain (layer names stay
    # user_tower_fc1..fcN with the final layer projecting to d_user_tower)
    widths = (cfg.user_tower_widths if cfg.user_tower_widths is not None
              else (cfg.d_user_tower,))
    h = profile
    for li, width in enumerate(widths):
        h = b.dense(f"user_tower_fc{li + 1}", h, width, activation="relu")
    u_emb = b.dense(f"user_tower_fc{len(widths) + 1}", h, cfg.d_user_tower,
                    activation="relu")

    # ---- cross attention: candidates attend to user sequence (Eq. 1) ----
    # K/V projections act on the raw (1, L, d) sequence — one-shot.
    k = b.dense("attn_k_proj", seq, cfg.d_attn, use_bias=False)
    v = b.dense("attn_v_proj", seq, cfg.d_attn, use_bias=False)
    # Query takes item feats concat a user context vector -> MaRI site (3).
    u_ctx = b.dense("user_ctx_proj", profile, cfg.d_attn, activation="relu")
    q_in = b.concat("q_concat", [item, u_ctx])
    q = b.dense("attn_q_proj", q_in, cfg.d_attn, use_bias=False)
    e_iu = b.cross_attention("cross_attn", q, k, v)  # (B, d_attn)

    # ---- feature fusion ----
    fusion = b.concat("fusion", [u_emb, e_iu, item, cross])

    # ---- MMoE: experts + per-task gates (MaRI site (1) = expert fc1; GCA
    # additionally discovers the gate projections) ----
    expert_outs = []
    for ei in range(cfg.n_experts):
        h = fusion
        for li, width in enumerate(cfg.d_expert):
            h = b.dense(f"expert{ei}_fc{li}", h, width, activation="relu")
        expert_outs.append(h)
    experts = b.stack_features("expert_stack", expert_outs)  # (B, E, d)

    task_logits = []
    for ti in range(cfg.n_tasks):
        gate_logit = b.dense(f"gate{ti}_proj", fusion, cfg.n_experts)
        gate = b.softmax(f"gate{ti}_softmax", gate_logit)
        mix = b.weighted_sum(f"task{ti}_mix", gate, experts)  # (B, d)
        # tower input re-concats a user-side projection -> MaRI site (2)
        tower_in = b.concat(f"task{ti}_in", [mix, u_emb])
        h = tower_in
        for li, width in enumerate(cfg.d_tower):
            h = b.dense(f"task{ti}_fc{li}", h, width, activation="relu")
        task_logits.append(b.dense(f"task{ti}_logit", h, 1))
    b.output(*task_logits)
    return b.graph, cfg


# Matmuls the paper names as MaRI-optimizable in this architecture.
def expected_eligible(cfg: PaperRankingConfig) -> set[str]:
    out = {"attn_q_proj"}
    out |= {f"expert{e}_fc0" for e in range(cfg.n_experts)}
    out |= {f"gate{t}_proj" for t in range(cfg.n_tasks)}
    out |= {f"task{t}_fc0" for t in range(cfg.n_tasks)}
    return out
