"""Decoder-only LM family covering the five assigned architectures.

Design points for 1000+-chip runnability:
* ``lax.scan`` over stacked layer params — HLO size and compile time are
  O(1 layer) even for deepseek-67b's 95 layers.
* Flash-style block attention (online softmax, double ``lax.scan`` over Q/KV
  chunks) — a 32k-token prefill never materializes an S×S score matrix.
* Sliding-window attention (Mixtral) with a ring-buffer KV cache for the
  524k-token long-context decode cell.
* Sort-based capacity-dropped MoE dispatch — no (T, E, C) one-hot tensor.
* Optional per-layer remat; activations compute in cfg.dtype (bf16 target).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.common import Array, KeySeq, normal_init
from repro.nn.layers import rms_norm

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qk_norm: bool = False
    window: int | None = None          # sliding-window attention
    moe_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    rope_theta: float = 1_000_000.0
    dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512              # vocab-projection chunking in the loss

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 256 so embed/lm_head shard evenly over
        ('model','data') (16×16 ZeRO). Padded logits are masked in the loss."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def scaled_down(self, **over) -> "LMConfig":
        """Reduced config for CPU smoke tests."""
        small = dict(
            n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=max(1, self.n_kv_heads * 4 // self.n_heads),
            d_ff=128, vocab=256, head_dim=16,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            window=64 if self.window else None,
            q_chunk=8, kv_chunk=8, loss_chunk=16, dtype="float32", remat=False)
        small.update(over)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_lm_params(cfg: LMConfig, key, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = KeySeq(key)
    L, D, hd = cfg.n_layers, cfg.d_model, cfg.hd
    hq, hkv, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff

    def w(shape):
        return normal_init(next(ks), shape, 0.02, dtype)

    attn = {"wq": w((L, D, hq * hd)), "wk": w((L, D, hkv * hd)),
            "wv": w((L, D, hkv * hd)), "wo": w((L, hq * hd, D))}
    if cfg.qk_norm:
        attn["q_norm"] = jnp.ones((L, hd), dtype)
        attn["k_norm"] = jnp.ones((L, hd), dtype)

    if cfg.is_moe:
        E = cfg.moe_experts
        ffn = {"router": w((L, D, E)), "wg": w((L, E, D, F)),
               "wu": w((L, E, D, F)), "wd": w((L, E, F, D))}
    else:
        ffn = {"wg": w((L, D, F)), "wu": w((L, D, F)), "wd": w((L, F, D))}

    return {
        "embed": w((cfg.vocab_padded, D)),
        "layers": {"attn": attn, "ffn": ffn,
                   "ln1": jnp.ones((L, D), dtype), "ln2": jnp.ones((L, D), dtype)},
        "final_norm": jnp.ones((D,), dtype),
        "lm_head": w((D, cfg.vocab_padded)),
    }


def lm_param_specs(cfg: LMConfig, dtype=None):
    """ShapeDtypeStruct tree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_lm_params(cfg, jax.random.PRNGKey(0), dtype))


# ---------------------------------------------------------------------------
# RoPE (computed from positions on the fly — no 500k-row table)
# ---------------------------------------------------------------------------

def _rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    c, s = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style block attention
# ---------------------------------------------------------------------------

def flash_attention(
    q: Array,            # (B, Sq, Hq, hd)
    k: Array,            # (B, Sk, Hkv, hd)
    v: Array,            # (B, Sk, Hkv, hd)
    q_pos: Array,        # (B, Sq)
    kv_pos: Array,       # (B, Sk)
    *,
    causal: bool = True,
    window: int | None = None,
    kv_valid: Array | None = None,   # (B, Sk) bool
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> Array:
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    nq, nk = sq // qc, sk // kc

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # reshape to grouped heads: (B, S, Hkv, g, hd) treated as (B, S, Hkv*g, hd)
    qr = q.reshape(b, nq, qc, hkv, g, hd)
    kr = k.reshape(b, nk, kc, hkv, hd)
    vr = v.reshape(b, nk, kc, hkv, hd)
    qp = q_pos.reshape(b, nq, qc)
    kp = kv_pos.reshape(b, nk, kc)
    kval = (kv_valid.reshape(b, nk, kc) if kv_valid is not None
            else jnp.ones((b, nk, kc), bool))

    def q_block(carry, qi):
        qb = qr[:, qi]            # (B, qc, Hkv, g, hd)
        qpb = qp[:, qi]           # (B, qc)

        def kv_block(state, ki):
            m, l, acc = state
            kb, vb = kr[:, ki], vr[:, ki]          # (B, kc, Hkv, hd)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
            dist = qpb[:, :, None] - kp[:, ki][:, None, :]    # (B, qc, kc)
            msk = kval[:, ki][:, None, :]
            if causal:
                msk = msk & (dist >= 0)
            if window is not None:
                msk = msk & (dist < window)
            s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), q.dtype)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, hq, hd)
        return carry, out

    _, outs = jax.lax.scan(q_block, (), jnp.arange(nq))   # (nq, B, qc, Hq, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# MoE FFN — sort-based dispatch with capacity dropping (no one-hot tensor)
# ---------------------------------------------------------------------------

def moe_ffn(x: Array, ffn: dict, cfg: LMConfig, tp_axis: str | None = None
            ) -> Array:
    """x: (T, D) -> (T, D).

    tp_axis: inside a fully-manual shard_map, expert weights arrive F-sharded
    (wg/wu on their last dim, wd on its contraction dim); the output is a
    partial sum that must be psum'd over ``tp_axis`` after the combine."""
    T, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    C = max(1, int(T * k * cfg.capacity_factor / E))

    logits = (x @ ffn["router"].astype(x.dtype)).astype(jnp.float32)   # (T, E)
    topv, topi = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(topv, axis=-1)                              # (T, k)

    fe = topi.reshape(-1)                                 # (T*k,) expert ids
    ft = jnp.repeat(jnp.arange(T), k)                     # (T*k,) token ids
    fg = gates.reshape(-1)
    order = jnp.argsort(fe)
    se, st, sg = fe[order], ft[order], fg[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C
    # dropped entries route to a dummy row E*C so they can never clobber a
    # kept token's slot.
    slot = jnp.where(keep, se * C + pos, E * C)

    xd = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(x[st])
    xd = xd[: E * C].reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xd, ffn["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xd, ffn["wu"].astype(x.dtype))
    yd = jnp.einsum("ecf,efd->ecd", h, ffn["wd"].astype(x.dtype)).reshape(E * C, D)

    contrib = yd[slot] * (sg * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[st].add(contrib)
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)   # TP reduction after the combine
    return y


def dense_ffn(x: Array, ffn: dict, cfg: LMConfig) -> Array:
    h = jax.nn.silu(x @ ffn["wg"].astype(x.dtype)) * (x @ ffn["wu"].astype(x.dtype))
    return h @ ffn["wd"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Transformer block + full forward
# ---------------------------------------------------------------------------

def _attn_block(x, lp, cfg: LMConfig, positions, kv_state=None,
                return_kv: bool = False):
    """x: (B, S, D). kv_state: None (full-seq) or dict with cache (decode)."""
    b, s, D = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = lp["attn"]
    xn = rms_norm(x, lp["ln1"].astype(x.dtype))
    q = (xn @ attn["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k = (xn @ attn["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (xn @ attn["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, attn["q_norm"].astype(x.dtype))
        k = rms_norm(k, attn["k_norm"].astype(x.dtype))
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)

    if kv_state is None:
        out = flash_attention(q, k, v, positions, positions, causal=True,
                              window=cfg.window, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)
        new_kv = (k, v) if return_kv else None
    else:
        kc, vc, slot, kv_pos, kv_valid = (
            kv_state["k"], kv_state["v"], kv_state["slot"],
            kv_state["pos"], kv_state["valid"])
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        out = flash_attention(q, kc.astype(x.dtype), vc.astype(x.dtype),
                              positions, kv_pos, causal=True, window=cfg.window,
                              kv_valid=kv_valid, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)
        new_kv = (kc, vc)
    out = out.reshape(b, s, hq * hd) @ attn["wo"].astype(x.dtype)
    return x + out, new_kv


def _ffn_block(x, lp, cfg: LMConfig):
    from repro.dist import policy
    b, s, D = x.shape
    xn = rms_norm(x, lp["ln2"].astype(x.dtype))
    if cfg.is_moe:
        xs = xn.reshape(b * s, D)
        axes = policy.get("moe_shard_axes")
        if axes:
            # §Perf 'moe_local': fully-manual shard_map — routing (sort,
            # capacity, scatter) is local to each DP shard; expert weights
            # arrive F-sharded over 'model' and the combine psums over TP.
            from jax.sharding import PartitionSpec as P
            spec_x = P(axes, None)
            wspecs = {"router": P(None, None),
                      "wg": P(None, None, "model"),
                      "wu": P(None, None, "model"),
                      "wd": P(None, "model", None)}
            y = jax.shard_map(
                lambda xx, ff: moe_ffn(xx, ff, cfg, tp_axis="model"),
                in_specs=(spec_x, wspecs), out_specs=spec_x)(xs, lp["ffn"])
        else:
            y = moe_ffn(xs, lp["ffn"], cfg)
        y = y.reshape(b, s, D)
    else:
        y = dense_ffn(xn, lp["ffn"], cfg)
    return x + y


def lm_forward(params: dict, cfg: LMConfig, tokens: Array,
               positions: Array | None = None, return_kv: bool = False):
    """Full-sequence forward. tokens: (B, S) -> final hidden (B, S, D).
    With ``return_kv`` also returns the per-layer K/V (prefill cache fill)."""
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)

    def layer(x, lp):
        from repro.dist import policy
        x, kv = _attn_block(x, lp, cfg, positions, return_kv=return_kv)
        x = _ffn_block(x, lp, cfg)
        # §Perf 'seq_par': sequence-parallel residual layout — the scan
        # carry (and remat save) shrinks by the TP degree.
        x = policy.constrain(x, "residual")
        return x, kv

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    x, kvs = jax.lax.scan(layer, x, params["layers"])
    x = rms_norm(x, params["final_norm"].astype(dt))
    if return_kv:
        return x, {"k": kvs[0], "v": kvs[1]}
    return x


def lm_logits(params: dict, cfg: LMConfig, tokens: Array) -> Array:
    x = lm_forward(params, cfg, tokens)
    return x @ params["lm_head"].astype(x.dtype)


def lm_loss(params: dict, cfg: LMConfig, tokens: Array, labels: Array) -> Array:
    """Chunked-vocab cross entropy — never materializes (B, S, V) at once."""
    x = lm_forward(params, cfg, tokens)          # (B, S, D)
    b, s, D = x.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    head = params["lm_head"]

    def chunk_loss(i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = (xs @ head.astype(xs.dtype)).astype(jnp.float32)
        if cfg.vocab_padded != cfg.vocab:  # mask the padding columns
            pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
            logits = jnp.where(pad_mask, -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    total = jax.lax.map(chunk_loss, jnp.arange(s // c)).sum()
    return total / (b * s)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Cache capacity = window (ring buffer) for SWA archs, else max_len."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    W = min(cfg.window, max_len) if cfg.window else max_len
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    W = min(cfg.window, max_len) if cfg.window else max_len
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def lm_decode_step(params: dict, cfg: LMConfig, cache: dict,
                   tokens: Array, pos: Array) -> tuple[Array, dict]:
    """One decode step. tokens: (B, 1); pos: scalar int32 — number of tokens
    already in the cache (uniform across batch, standard batched serving).
    Returns (logits (B, 1, V), new cache)."""
    dt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    W = cache["k"].shape[2]
    slot = (pos % W).astype(jnp.int32)
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)

    # slot j currently holds absolute position: pos - ((slot - j) mod W),
    # once we've written the new token at `slot`.
    j = jnp.arange(W, dtype=jnp.int32)
    kv_pos = pos - ((slot - j) % W)
    valid = kv_pos >= 0
    kv_pos_b = jnp.broadcast_to(kv_pos[None], (b, W))
    valid_b = jnp.broadcast_to(valid[None], (b, W))

    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)   # (B, 1, D)

    def layer(x, per):
        lp, kc, vc = per
        kv_state = {"k": kc, "v": vc, "slot": slot, "pos": kv_pos_b,
                    "valid": valid_b}
        x, (knew, vnew) = _attn_block(x, lp, cfg, positions, kv_state)
        x = _ffn_block(x, lp, cfg)
        return x, (knew, vnew)

    x, (knew, vnew) = jax.lax.scan(layer, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"].astype(dt))
    logits = x @ params["lm_head"].astype(dt)
    return logits, {"k": knew, "v": vnew}
