"""SchNet [arXiv:1706.08566] — continuous-filter convolution GNN.

Message passing is implemented the JAX-native way (no CSR sparse in JAX):
edge-index gathers + ``jax.ops.segment_sum`` scatters — this IS the SpMM
layer of the system. Interaction blocks are stacked and scanned.

For the non-geometric assigned graphs (cora-like / ogbn-products) the data
pipeline synthesizes 3D coordinates; SchNet then acts as a continuous-filter
GNN over that embedding (DESIGN.md §4). Node features enter through a linear
projection instead of the atom-type embedding when ``d_feat > 0``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common import Array, KeySeq, glorot, normal_init

LOG2 = 0.6931471805599453


def ssp(x: Array) -> Array:
    """Shifted softplus, SchNet's activation."""
    return jax.nn.softplus(x) - LOG2


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_feat: int = 0          # 0 => atom-type embedding input
    n_atom_types: int = 100
    n_out: int = 1           # classes (node tasks) or 1 (energy)

    def scaled_down(self, **over) -> "SchNetConfig":
        small = dict(n_interactions=2, d_hidden=16, n_rbf=8)
        small.update(over)
        return dataclasses.replace(self, **small)


def init_schnet_params(cfg: SchNetConfig, key, dtype=jnp.float32) -> dict:
    ks = KeySeq(key)
    H, R, T = cfg.d_hidden, cfg.n_rbf, cfg.n_interactions

    def w(shape):
        return glorot(next(ks), shape, dtype)

    if cfg.d_feat > 0:
        inp = {"w": w((cfg.d_feat, H)), "b": jnp.zeros((H,), dtype)}
    else:
        inp = {"table": normal_init(next(ks), (cfg.n_atom_types, H), 0.1, dtype)}

    def stacked(shape):
        return jnp.stack([w(shape) for _ in range(T)])

    inter = {
        "filt_w1": stacked((R, H)), "filt_b1": jnp.zeros((T, H), dtype),
        "filt_w2": stacked((H, H)), "filt_b2": jnp.zeros((T, H), dtype),
        "in2f": stacked((H, H)),
        "f2out_w1": stacked((H, H)), "f2out_b1": jnp.zeros((T, H), dtype),
        "f2out_w2": stacked((H, H)), "f2out_b2": jnp.zeros((T, H), dtype),
    }
    readout = {"w1": w((H, H)), "b1": jnp.zeros((H,), dtype),
               "w2": w((H, cfg.n_out)), "b2": jnp.zeros((cfg.n_out,), dtype)}
    return {"input": inp, "interactions": inter, "readout": readout}


def rbf_expand(d: Array, n_rbf: int, cutoff: float) -> Array:
    """Gaussian radial basis over [0, cutoff]. d: (E,) -> (E, n_rbf)."""
    mu = jnp.linspace(0.0, cutoff, n_rbf, dtype=d.dtype)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * jnp.square(d[:, None] - mu[None, :]))


def schnet_forward(
    params: dict,
    cfg: SchNetConfig,
    node_input: Array,        # (N, d_feat) float or (N,) int atom types
    positions: Array,         # (N, 3)
    senders: Array,           # (E,)
    receivers: Array,         # (E,)
    edge_mask: Array | None = None,   # (E,) bool — padded sampled subgraphs
) -> Array:
    """Returns per-node outputs (N, n_out)."""
    n_nodes = positions.shape[0]
    if cfg.d_feat > 0:
        h = node_input @ params["input"]["w"] + params["input"]["b"]
    else:
        h = jnp.take(params["input"]["table"], node_input, axis=0)

    diff = positions[senders] - positions[receivers]          # (E, 3)
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)    # (E,)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)             # (E, R)
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    if edge_mask is not None:
        env = env * edge_mask.astype(env.dtype)

    def interaction(h, ip):
        filt = ssp(rbf @ ip["filt_w1"] + ip["filt_b1"])
        filt = (filt @ ip["filt_w2"] + ip["filt_b2"]) * env[:, None]   # (E, H)
        src = h[senders] @ ip["in2f"]                                  # (E, H)
        msg = src * filt
        agg = jax.ops.segment_sum(msg, receivers, num_segments=n_nodes)
        upd = ssp(agg @ ip["f2out_w1"] + ip["f2out_b1"])
        upd = upd @ ip["f2out_w2"] + ip["f2out_b2"]
        return h + upd, None

    h, _ = jax.lax.scan(interaction, h, params["interactions"])
    r = params["readout"]
    out = ssp(h @ r["w1"] + r["b1"]) @ r["w2"] + r["b2"]
    return out


def schnet_graph_readout(node_out: Array, graph_ids: Array, n_graphs: int) -> Array:
    """Molecule-level energy: sum node outputs per graph."""
    return jax.ops.segment_sum(node_out, graph_ids, num_segments=n_graphs)
