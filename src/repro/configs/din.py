"""DIN [arXiv:1706.06978; paper]: embed 18, behaviour seq 100,
attention MLP 80-40, fusion MLP 200-80."""
import functools

from repro.configs._recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import build_din

FAMILY = "recsys"
BUILD = functools.partial(build_din, embed_dim=18, seq_len=100,
                          attn_mlp=(80, 40), mlp=(200, 80),
                          item_vocab=10_000_000)
SHAPES = dict(RECSYS_SHAPES)


def smoke_build():
    return functools.partial(build_din, embed_dim=8, seq_len=12,
                             attn_mlp=(16, 8), mlp=(24, 12), item_vocab=128)
