"""Shared LM-family shape set (seq_len x global_batch per assignment)."""


def lm_shapes(sub_quadratic: bool) -> dict:
    shapes = {
        "train_4k": {"kind": "train", "seq": 4096, "global_batch": 256},
        "prefill_32k": {"kind": "prefill", "seq": 32768, "global_batch": 32},
        "decode_32k": {"kind": "decode", "seq": 32768, "global_batch": 128},
        "long_500k": {"kind": "decode", "seq": 524288, "global_batch": 1},
    }
    if not sub_quadratic:
        shapes["long_500k"]["skip"] = (
            "pure full-attention arch: 524k decode requires sub-quadratic "
            "attention (assignment rule; see DESIGN.md §4)")
    return shapes
