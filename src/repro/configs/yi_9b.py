"""Yi 9B [arXiv:2403.04652; hf]: llama-arch GQA, 48L d4096 32H(kv4)
ff11008 v64000."""
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

FAMILY = "lm"
CONFIG = LMConfig(
    name="yi-9b", n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128, rope_theta=5e6)
SHAPES = lm_shapes(sub_quadratic=False)


def smoke_config():
    return CONFIG.scaled_down()
