"""Qwen3 14B [hf:Qwen]: 40L d5120 40H(kv8) ff17408 v151936, qk_norm."""
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

FAMILY = "lm"
CONFIG = LMConfig(
    name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6)
SHAPES = lm_shapes(sub_quadratic=False)


def smoke_config():
    return CONFIG.scaled_down(qk_norm=True)
