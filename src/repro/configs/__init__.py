"""Architecture registry: 10 assigned archs (+ the paper's own ranking model),
each paired with its input-shape set. ``get_config(arch)`` returns the config
module; ``all_cells()`` enumerates the dry-run matrix.
"""
from __future__ import annotations

import dataclasses
import importlib

_ARCH_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-67b": "deepseek_67b",
    "qwen3-14b": "qwen3_14b",
    "yi-9b": "yi_9b",
    "schnet": "schnet",
    "dlrm-mlperf": "dlrm_mlperf",
    "fm": "fm",
    "din": "din",
    "deepfm": "deepfm",
    "paper-ranking": "paper_ranking",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "paper-ranking"]


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str                  # 'train' | 'prefill' | 'decode' | 'serve'
    skip_reason: str | None = None


def get_config(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def all_cells(include_paper: bool = False) -> list[Cell]:
    cells = []
    archs = list(ASSIGNED_ARCHS) + (["paper-ranking"] if include_paper else [])
    for arch in archs:
        mod = get_config(arch)
        for shape, spec in mod.SHAPES.items():
            cells.append(Cell(arch=arch, shape=shape, kind=spec["kind"],
                              skip_reason=spec.get("skip")))
    return cells
