"""Factorization Machine [ICDM'10 Rendle; paper]: 39 sparse fields, k=10,
pairwise term via the O(nk) sum-square trick."""
import functools

from repro.configs._recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import build_fm

FAMILY = "recsys"
BUILD = functools.partial(build_fm, n_sparse=39, embed_dim=10,
                          vocab_size=1_000_000, n_user=20)
SHAPES = dict(RECSYS_SHAPES)


def smoke_build():
    return functools.partial(build_fm, n_sparse=8, embed_dim=4,
                             vocab_size=64, n_user=4)
