"""SchNet [arXiv:1706.08566; paper]: n_interactions=3 d_hidden=64 rbf=300
cutoff=10. Four graph regimes (cora-like / reddit-sampled / ogbn-products /
batched molecules)."""
from repro.models.schnet import SchNetConfig

FAMILY = "gnn"
CONFIG = SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                      n_rbf=300, cutoff=10.0)

SHAPES = {
    "full_graph_sm": {
        "kind": "train", "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
        "n_classes": 7, "mode": "full"},
    "minibatch_lg": {
        "kind": "train", "n_nodes": 232965, "n_edges": 114615892,
        "d_feat": 602, "n_classes": 41, "mode": "sampled",
        "batch_nodes": 1024, "fanout": (15, 10)},
    "ogb_products": {
        "kind": "train", "n_nodes": 2449029, "n_edges": 61859140,
        "d_feat": 100, "n_classes": 47, "mode": "full"},
    "molecule": {
        "kind": "train", "n_nodes": 30, "n_edges": 64, "batch": 128,
        "mode": "molecule"},
}


def smoke_config():
    return CONFIG.scaled_down()
