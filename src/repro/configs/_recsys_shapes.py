"""Shared recsys shape set."""

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    # one user scored against 1M candidates: candidates ARE the batch dim,
    # the user side is computed once (the paper's B>>1 regime).
    "retrieval_cand": {"kind": "serve", "batch": 1_000_000},
}
