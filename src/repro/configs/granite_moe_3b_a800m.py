"""Granite MoE 3B-A800M [hf:ibm-granite]: 32L d1536 24H(kv8) ff512 v49155,
MoE 40 experts top-8 (fine-grained experts)."""
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

FAMILY = "lm"
CONFIG = LMConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155, head_dim=64,
    moe_experts=40, moe_top_k=8, rope_theta=1e4)
SHAPES = lm_shapes(sub_quadratic=False)


def smoke_config() -> LMConfig:
    return CONFIG.scaled_down()
