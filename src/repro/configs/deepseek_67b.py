"""DeepSeek 67B [arXiv:2401.02954; hf]: llama-arch, 95L d8192 64H(kv8)
ff22016 v102400."""
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

FAMILY = "lm"
CONFIG = LMConfig(
    name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, head_dim=128, rope_theta=1e4)
SHAPES = lm_shapes(sub_quadratic=False)


def smoke_config():
    return CONFIG.scaled_down()
