"""DeepFM [arXiv:1703.04247; paper]: 39 fields, k=10, deep MLP 400-400-400."""
import functools

from repro.configs._recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import build_deepfm

FAMILY = "recsys"
BUILD = functools.partial(build_deepfm, n_sparse=39, embed_dim=10,
                          mlp=(400, 400, 400), vocab_size=1_000_000, n_user=20)
SHAPES = dict(RECSYS_SHAPES)


def smoke_build():
    return functools.partial(build_deepfm, n_sparse=8, embed_dim=4,
                             mlp=(32, 32), vocab_size=64, n_user=4)
