"""The paper's own coarse-ranking reference model (Fig. 1): MMoE +
cross-attention + task towers, Table-2 dimension regime."""
import functools

from repro.configs._recsys_shapes import RECSYS_SHAPES
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model

FAMILY = "recsys"
CONFIG = PaperRankingConfig()
BUILD = functools.partial(build_paper_ranking_model, CONFIG)
SHAPES = dict(RECSYS_SHAPES)


def smoke_build():
    return functools.partial(build_paper_ranking_model, CONFIG.scaled(0.03))
