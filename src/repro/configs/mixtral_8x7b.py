"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L d4096 32H(kv8) ff14336 v32000,
MoE 8 experts top-2, sliding-window attention (window 4096)."""
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

FAMILY = "lm"
CONFIG = LMConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, window=4096,
    moe_experts=8, moe_top_k=2, rope_theta=1e6)
# SWA => sub-quadratic decode: long_500k runs with a ring-buffer KV cache.
SHAPES = lm_shapes(sub_quadratic=True)


def smoke_config() -> LMConfig:
    return CONFIG.scaled_down()
