"""DLRM MLPerf benchmark config (Criteo 1TB) [arXiv:1906.00091; paper]."""
import functools

from repro.configs._recsys_shapes import RECSYS_SHAPES
from repro.models.recsys import build_dlrm

FAMILY = "recsys"
BUILD = functools.partial(
    build_dlrm, embed_dim=128, bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1), n_dense=13)
SHAPES = dict(RECSYS_SHAPES)


def smoke_build():
    return functools.partial(build_dlrm, scale_tables=2e-6,
                             bot_mlp=(32, 16), top_mlp=(64, 32, 1),
                             embed_dim=16)
