"""Chrome trace-event JSON export — open the serving timeline in Perfetto.

Serializes one or more ``Tracer`` buffers to the Chrome trace-event
format (https://ui.perfetto.dev loads it directly, as does
``chrome://tracing``):

* one track (``tid``) per REAL thread that recorded events — the
  batcher worker, direct callers, the dist worker's main thread;
* one SYNTHETIC track per outstanding stage-2 group (``track="group:k"``
  events from the engine's two-phase API), so two overlapped groups
  render as two concurrent slices instead of an un-renderable nested
  mess on the worker's track — PR 7's continuous-batching overlap (and
  any future transfer race) becomes *visible*;
* ``pid`` per tracer (scenario, or dist shard index after
  ``merge_trace_files``), with ``process_name`` / ``thread_name``
  metadata events naming every timeline row.

Timestamps: tracers record ``perf_counter`` seconds plus a wall-clock
epoch; export emits wall-aligned microseconds relative to the earliest
event (``baseWallUs`` keeps the absolute base), so per-worker files
merged across processes land on one comparable timeline.
"""
from __future__ import annotations

import json
from typing import Any, Mapping, Sequence

from repro.obs.trace import Tracer

_CAT = "serve"
# synthetic tracks start far above the compacted real-thread tids so the
# two id spaces can never collide
_SYNTH_TID_BASE = 1000


def _json_safe(v: Any) -> Any:
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, Mapping):
        return {str(k): _json_safe(x) for k, x in v.items()}
    return str(v)


def chrome_events(tracer: Tracer, *, pid: int = 0,
                  process_name: str = "serve") -> tuple[list[dict], float]:
    """Convert one tracer's buffer to Chrome trace events.

    Returns ``(events, base_wall_us)`` — timestamps are µs relative to
    the tracer's earliest event; ``base_wall_us`` is that event's
    absolute wall-clock µs (merge realigns with it).
    """
    raw = tracer.events()
    thread_names = tracer.thread_names()
    base_perf = min((ts for _, _, ts, _, _, _, _ in raw),
                    default=tracer.epoch_perf)
    base_wall_us = (tracer.epoch_wall
                    + (base_perf - tracer.epoch_perf)) * 1e6

    # compact real thread ids (sorted for determinism) + synthetic tracks
    real_tids = sorted({tid for _, _, _, _, tid, track, _ in raw
                        if track is None} | set(thread_names))
    tid_of = {t: i + 1 for i, t in enumerate(real_tids)}
    tracks = sorted({track for _, _, _, _, _, track, _ in raw
                     if track is not None})
    track_tid = {t: _SYNTH_TID_BASE + i for i, t in enumerate(tracks)}

    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "cat": "__metadata", "args": {"name": process_name},
    }]
    for t in real_tids:
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid,
            "tid": tid_of[t], "cat": "__metadata",
            "args": {"name": thread_names.get(t, f"thread-{t}")},
        })
    for t in tracks:
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid,
            "tid": track_tid[t], "cat": "__metadata",
            "args": {"name": t},
        })

    for ph, name, ts, dur, tid, track, args in raw:
        ev: dict[str, Any] = {
            "name": name, "cat": _CAT, "ph": ph,
            "ts": (ts - base_perf) * 1e6, "pid": pid,
            "tid": track_tid[track] if track is not None else tid_of[tid],
        }
        if ph == "X":
            ev["dur"] = dur * 1e6
        if ph == "i":
            ev["s"] = "t"                 # thread-scoped instant
        if args:
            ev["args"] = _json_safe(args)
        events.append(ev)
    return events, base_wall_us


def trace_payload(tracers: Tracer | Mapping[str, Tracer],
                  ) -> dict:
    """Build the Perfetto-loadable payload for one or more tracers
    (``{name: tracer}`` gets one pid per name; a bare tracer gets
    pid 0)."""
    if isinstance(tracers, Tracer):
        tracers = {"serve": tracers}
    per = [chrome_events(t, pid=i, process_name=name)
           for i, (name, t) in enumerate(tracers.items())]
    if not per:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "baseWallUs": 0.0}
    base = min(b for _, b in per)
    events: list[dict] = []
    for evs, b in per:
        shift = b - base
        for ev in evs:
            if ev["ph"] != "M":
                ev = dict(ev, ts=ev["ts"] + shift)
            events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "baseWallUs": base}


def write_trace(path: str,
                tracers: Tracer | Mapping[str, Tracer]) -> dict:
    """Serialize ``tracers`` to ``path``; returns the payload."""
    payload = trace_payload(tracers)
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def merge_trace_files(paths: Sequence[str], out_path: str,
                      names: Sequence[str] | None = None) -> dict:
    """Merge per-worker trace files into one timeline: file i's events
    are reassigned ``pid=i`` (the dist runner passes shard order, so
    pid == shard index) and shifted onto the earliest file's wall-clock
    base, so cross-process overlap reads directly off the merged view."""
    payloads = []
    for p in paths:
        with open(p) as f:
            payloads.append(json.load(f))
    bases = [p.get("baseWallUs", 0.0) for p in payloads]
    base = min(bases, default=0.0)
    events: list[dict] = []
    for i, (payload, b) in enumerate(zip(payloads, bases)):
        shift = b - base
        name = names[i] if names is not None else f"shard-{i}"
        for ev in payload.get("traceEvents", []):
            ev = dict(ev, pid=i)
            if ev.get("ph") == "M":
                if ev.get("name") == "process_name":
                    ev["args"] = {"name": name}
            else:
                ev["ts"] = ev.get("ts", 0.0) + shift
            events.append(ev)
    merged = {"traceEvents": events, "displayTimeUnit": "ms",
              "baseWallUs": base}
    with open(out_path, "w") as f:
        json.dump(merged, f)
    return merged
