"""Log-bucketed histogram registry — tail percentiles without samples.

The serving counters grown across PRs 2–7 (batcher shed/degrade, cache
hits/misses, ``pipeline_forks``, the cumulative ``queue_wait_ms`` float)
are totals: they cannot show that p99 queue wait is 40× p50 under a
Zipf burst, which is the number an SLO lives or dies on. Retaining raw
samples is off the table at "millions of users" scale, so ``Histogram``
keeps log-spaced bucket counts instead: values land in geometric buckets
``base**k`` with ``base = 2**(1/4)`` (≈ ±9% relative resolution), and
``percentile(q)`` interpolates inside the covering bucket — p50/p90/p99
in O(buckets), O(buckets) memory, any value range.

``MetricsRegistry`` unifies the scattered counters behind ONE
``snapshot()``:

* ``histogram(name)`` — get-or-create a named histogram (request
  latency, queue wait);
* ``gauge(name, fn)`` — register a zero-argument callable sampled at
  snapshot time (the existing counters plug in without double
  bookkeeping: ``registry.gauge("cache_hits", lambda: cache.hits)``);
* ``snapshot()`` — ``{name: histogram summary | gauge value}``, the one
  dict ``RankingService.stats()`` and the bench rows read.

Thread safety: ``record`` takes a per-histogram lock (an increment — a
leaf lock, never calling out), so the batcher worker and direct callers
can record concurrently; registry mutation takes the registry lock.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable

# quarter-octave buckets: boundaries 2**(k/4), ~19% wide (±9% error)
_LOG_BASE = 4.0
_PCTS = (50.0, 90.0, 99.0)


class Histogram:
    """Log-bucketed value distribution with percentile estimation."""

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._buckets: dict[int, int] = {}   # k -> count; value in
        #                                      (2**((k-1)/4), 2**(k/4)]
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @staticmethod
    def _index(value: float) -> int:
        # non-positive values share one underflow bucket: latencies and
        # waits are >= 0, and a 0 observation carries no log-scale info
        if value <= 0.0:
            return -(10**9)
        return math.ceil(math.log2(value) * _LOG_BASE)

    @staticmethod
    def _upper(k: int) -> float:
        return 0.0 if k == -(10**9) else 2.0 ** (k / _LOG_BASE)

    def record(self, value: float) -> None:
        k = self._index(value)
        with self._lock:
            self._buckets[k] = self._buckets.get(k, 0) + 1
            self.count += 1
            self.total += value
            if value < self.min:
                self.min = value
            if value > self.max:
                self.max = value

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (0 < q <= 100): the upper edge of the
        covering bucket, linearly interpolated inside it, clamped to the
        exact observed min/max so single-bucket distributions stay
        honest."""
        with self._lock:
            if self.count == 0:
                return 0.0
            target = q / 100.0 * self.count
            seen = 0
            for k in sorted(self._buckets):
                n = self._buckets[k]
                if seen + n >= target:
                    lo = max(self._upper(k - 1), self.min)
                    hi = min(self._upper(k), self.max)
                    if hi <= lo:
                        return min(max(self._upper(k), self.min), self.max)
                    frac = (target - seen) / n
                    return lo + (hi - lo) * frac
                seen += n
            return self.max

    def snapshot(self) -> dict[str, float]:
        pcts = {f"p{int(p)}": self.percentile(p) for p in _PCTS}
        with self._lock:
            return {
                "count": self.count,
                "total": self.total,
                "mean": (self.total / self.count) if self.count else 0.0,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                **pcts,
            }

    def reset(self) -> None:
        """Zero the distribution (one lock acquisition) — benches window
        a measurement by resetting after warmup."""
        with self._lock:
            self._buckets.clear()
            self.count = 0
            self.total = 0.0
            self.min = math.inf
            self.max = -math.inf


class MetricsRegistry:
    """Named histograms + lazily-sampled gauges behind one snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hists: dict[str, Histogram] = {}
        self._gauges: dict[str, Callable[[], Any]] = {}

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(name)
            return h

    def gauge(self, name: str, fn: Callable[[], Any]) -> None:
        """Register (or replace) a counter sampled at snapshot time."""
        with self._lock:
            self._gauges[name] = fn

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            hists = dict(self._hists)
            gauges = dict(self._gauges)
        out: dict[str, Any] = {n: h.snapshot() for n, h in hists.items()}
        for n, fn in gauges.items():
            try:
                out[n] = fn()
            except Exception:                    # a dead gauge must never
                out[n] = None                    # take stats() down
        return out
