"""Bounded ring-buffer tracer for the serving hot path.

``StageProfiler`` answers "where does the mean microsecond go";
it cannot answer "what did THIS request wait on" or "were those two
stage-2 groups actually overlapped". ``Tracer`` records the missing
per-event timeline: span events (begin/end or complete, with wall-clock
timestamps and durations) and instant events, each stamped with the
recording thread's id and free-form args carrying the propagated
request/group context (``req=<submit seq>``, ``group=<engine group id>``).

Design constraints, in order:

* **bounded** — events land in a ring buffer of ``capacity`` entries;
  under sustained load the newest events win and ``dropped`` counts the
  overwritten ones. Tracing never grows without bound and never blocks
  the hot path on I/O (export is a separate, offline step —
  ``repro.obs.export``).
* **thread-safe** — the batcher worker, direct ``score`` callers, and
  the exporting thread all touch one buffer; every mutation is taken
  under a single lock whose critical section is an append (the lock is
  a leaf: ``Tracer`` never calls out under it, so it can be used from
  inside other subsystems' locks without ordering hazards).
* **cheap** — one ``perf_counter`` + one locked append per event;
  callers keep the ``tracer is None`` fast path when tracing is off
  (``ObsPlan.trace`` defaults to False), and ``sample_every`` thins
  per-request events under load without losing group-level spans.

Timestamps are ``time.perf_counter()`` (monotonic, high-resolution)
plus a wall-clock epoch captured at construction, so exports from
different processes (the dist runner's per-worker traces) land on one
comparable wall-clock timeline.

Event tuples are ``(ph, name, ts, dur, tid, track, args)``:

* ``ph`` — Chrome trace-event phase: ``"X"`` complete span, ``"B"`` /
  ``"E"`` begin/end pair (used for the synthetic per-group tracks,
  whose end is only known at ``collect``), ``"i"`` instant;
* ``ts`` / ``dur`` — perf_counter seconds (export converts to µs);
* ``tid`` — ``threading.get_ident()`` of the recording thread;
* ``track`` — None for "the recording thread's track", or a synthetic
  track name (e.g. ``"group:0"``) the exporter maps to its own timeline
  row so overlapping groups are visibly concurrent in Perfetto.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Iterator

DEFAULT_CAPACITY = 65536


class Tracer:
    """Lock-protected bounded ring buffer of trace events."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 sample_every: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if sample_every < 1:
            raise ValueError(
                f"sample_every must be >= 1, got {sample_every}")
        self.capacity = capacity
        self.sample_every = sample_every
        # wall/perf epoch pair: export aligns per-process perf_counter
        # timelines onto one wall clock (merged dist traces line up)
        self.epoch_wall = time.time()
        self.epoch_perf = time.perf_counter()
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._thread_names: dict[int, str] = {}
        self.recorded = 0        # total events ever pushed

    # -- recording -----------------------------------------------------------
    def _push(self, ph: str, name: str, ts: float, dur: float,
              track: str | None, args: dict | None) -> None:
        tid = threading.get_ident()
        with self._lock:
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
            self._events.append((ph, name, ts, dur, tid, track, args))
            self.recorded += 1

    def instant(self, name: str, *, track: str | None = None,
                **args: Any) -> None:
        """Record a point-in-time event (cache hit, shed verdict, fork)."""
        self._push("i", name, time.perf_counter(), 0.0, track, args or None)

    def complete(self, name: str, t0: float, dur_s: float, *,
                 track: str | None = None, **args: Any) -> None:
        """Record a finished span with an explicit start + duration (both
        in perf_counter seconds) — for phases whose timing the caller
        already measured."""
        self._push("X", name, t0, dur_s, track, args or None)

    @contextmanager
    def span(self, name: str, *, track: str | None = None,
             **args: Any) -> Iterator[None]:
        """Time a block as one complete span."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._push("X", name, t0, time.perf_counter() - t0, track,
                       args or None)

    def begin(self, name: str, *, track: str | None = None,
              **args: Any) -> None:
        """Open a span whose end is recorded separately (``end``) — the
        per-group tracks use this because a group's end is only known at
        ``collect``, possibly out of order with other groups."""
        self._push("B", name, time.perf_counter(), 0.0, track, args or None)

    def end(self, name: str, *, track: str | None = None,
            **args: Any) -> None:
        self._push("E", name, time.perf_counter(), 0.0, track, args or None)

    def sampled(self, seq: int) -> bool:
        """True when per-request events for submit seq ``seq`` should be
        recorded (``sample_every`` thinning; group spans are never
        thinned)."""
        return seq % self.sample_every == 0

    # -- inspection ----------------------------------------------------------
    def events(self) -> list[tuple]:
        """Snapshot the buffer (oldest first)."""
        with self._lock:
            return list(self._events)

    def thread_names(self) -> dict[int, str]:
        with self._lock:
            return dict(self._thread_names)

    @property
    def dropped(self) -> int:
        """Events overwritten by the ring bound (newest always win)."""
        with self._lock:
            return self.recorded - len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.recorded = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
