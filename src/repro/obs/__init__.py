"""``repro.obs`` — low-overhead tracing + histogram metrics for serving.

The serving stack's third observability layer, beside the cumulative
``StageProfiler`` phases and the per-subsystem counters:

* ``trace``   — ``Tracer``: lock-protected bounded ring buffer of span /
  instant events with thread ids and propagated request/group context
  (submit → admission → claim → group → pack → dispatch → collect);
* ``export``  — Chrome trace-event JSON serialization (Perfetto-loadable;
  one track per real thread + a synthetic track per outstanding stage-2
  group) and the per-worker merge used by ``repro.dist.runner``;
* ``metrics`` — ``Histogram`` / ``MetricsRegistry``: log-bucketed
  p50/p90/p99 without sample retention, unifying the scattered serving
  counters behind one ``snapshot()``.

Configured by the ``ObsPlan`` section of ``repro.serve.plan.ServePlan``
(``obs__trace=True`` + ``launch/serve.py --trace out.json`` /
``benchmarks/load.py --trace``); off-by-default tracing keeps the hot
path at a ``tracer is None`` check.
"""
from repro.obs.export import (  # noqa: F401
    chrome_events,
    merge_trace_files,
    trace_payload,
    write_trace,
)
from repro.obs.metrics import Histogram, MetricsRegistry  # noqa: F401
from repro.obs.trace import DEFAULT_CAPACITY, Tracer  # noqa: F401
