"""Lightweight computation-graph IR for ranking models.

Industrial serving systems (the paper's setting) rewrite *graphs* — TF GraphDef
at Kuaishou — not Python closures. This IR is the JAX-native equivalent: a
small, explicit node graph that the GCA colors, the MaRI pass rewrites, and an
executor interprets under jit (so the rewritten graph still compiles to one
XLA computation).

Shapes stored on nodes are PER-EXAMPLE (no batch dim); the executor prepends
batch 1 (user-side) or B (item/cross-side) at run time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

# Ops through which MaRI eligibility propagates (Alg. 1 line 24:
# "paths with only non-computational nodes").
TRANSPARENT_OPS = frozenset({"identity", "cast", "stop_gradient", "reshape"})

# Ops the rewriter can actually move through (must be shape-preserving so the
# weight-row ↔ concat-segment correspondence survives).
REWRITE_SAFE_OPS = frozenset({"identity", "cast", "stop_gradient"})

PARAM_OPS = frozenset({"dense", "mari_dense", "embedding", "target_attention"})

DOMAINS = ("user", "item", "cross")


@dataclasses.dataclass(frozen=True)
class Node:
    name: str
    op: str
    inputs: tuple[str, ...]
    attrs: Mapping[str, Any]

    def attr(self, key: str, default=None):
        return self.attrs.get(key, default)


class Graph:
    """Append-only DAG; insertion order is a valid topological order."""

    def __init__(self):
        self.nodes: dict[str, Node] = {}
        self.outputs: list[str] = []

    # -- construction ------------------------------------------------------
    def add(self, node: Node) -> str:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name!r}")
        for i in node.inputs:
            if i not in self.nodes:
                raise ValueError(f"node {node.name!r}: unknown input {i!r}")
        self.nodes[node.name] = node
        return node.name

    def set_outputs(self, names: Sequence[str]) -> None:
        for n in names:
            if n not in self.nodes:
                raise ValueError(f"unknown output {n!r}")
        self.outputs = list(names)

    # -- queries -----------------------------------------------------------
    def topo_order(self) -> list[Node]:
        return list(self.nodes.values())

    def consumers(self, name: str) -> list[Node]:
        return [n for n in self.nodes.values() if name in n.inputs]

    def inputs_of_type(self, op: str) -> list[Node]:
        return [n for n in self.nodes.values() if n.op == op]

    def input_nodes(self) -> list[Node]:
        return self.inputs_of_type("input")

    def param_nodes(self) -> list[Node]:
        return [n for n in self.nodes.values() if n.op in PARAM_OPS]

    def copy(self) -> "Graph":
        g = Graph()
        g.nodes = dict(self.nodes)
        g.outputs = list(self.outputs)
        return g

    def dce(self) -> "Graph":
        """Dead-code elimination: keep only ancestors of the outputs."""
        live: set[str] = set()
        stack = list(self.outputs)
        while stack:
            n = stack.pop()
            if n in live:
                continue
            live.add(n)
            stack.extend(self.nodes[n].inputs)
        g = Graph()
        g.nodes = {k: v for k, v in self.nodes.items() if k in live}
        g.outputs = list(self.outputs)
        return g

    def __repr__(self):
        return f"Graph({len(self.nodes)} nodes, outputs={self.outputs})"


class GraphBuilder:
    """Fluent construction helper; methods return node names."""

    def __init__(self):
        self.graph = Graph()
        self._ctr = 0

    def _name(self, base: str) -> str:
        self._ctr += 1
        return f"{base}_{self._ctr}"

    def _add(self, name, op, inputs, **attrs) -> str:
        return self.graph.add(Node(name, op, tuple(inputs), attrs))

    # inputs -----------------------------------------------------------------
    def input(self, name: str, shape: tuple[int, ...], domain: str | None,
              dtype: str = "float32") -> str:
        if domain is not None and domain not in DOMAINS:
            raise ValueError(f"bad domain {domain!r}")
        return self._add(name, "input", [], shape=tuple(shape), domain=domain, dtype=dtype)

    # params -----------------------------------------------------------------
    def dense(self, name: str, x: str, units: int, *, use_bias: bool = True,
              activation: str = "identity") -> str:
        return self._add(name, "dense", [x], units=units, use_bias=use_bias,
                         activation=activation)

    def embedding(self, name: str, ids: str, vocab: int, dim: int,
                  pool: str | None = None) -> str:
        """ids per-example shape () -> (dim,); (H,) -> (H, dim) or pooled (dim,)."""
        return self._add(name, "embedding", [ids], vocab=vocab, dim=dim, pool=pool)

    # structure ----------------------------------------------------------------
    def concat(self, name: str, xs: Sequence[str], axis: int = -1) -> str:
        return self._add(name, "concat", xs, axis=axis)

    def add(self, name: str, a: str, b: str) -> str:
        return self._add(name, "add", [a, b])

    def mul(self, name: str, a: str, b: str) -> str:
        return self._add(name, "mul", [a, b])

    def sub(self, name: str, a: str, b: str) -> str:
        return self._add(name, "sub", [a, b])

    def scale(self, name: str, x: str, factor: float) -> str:
        return self._add(name, "scale", [x], factor=factor)

    def target_attention(self, name: str, query: str, keys: str,
                         mask: str | None = None,
                         mlp_hidden: tuple[int, ...] = (80, 40)) -> str:
        """DIN local-activation unit (composite op with internal attention
        MLP params). query (D,) item-side; keys (L, D) user-side."""
        ins = [query, keys] + ([mask] if mask else [])
        return self._add(name, "target_attention", ins,
                         mlp_hidden=tuple(mlp_hidden), has_mask=mask is not None)

    def act(self, name: str, x: str, fn: str) -> str:
        return self._add(name, "act", [x], fn=fn)

    def softmax(self, name: str, x: str, axis: int = -1) -> str:
        return self._add(name, "softmax", [x], axis=axis)

    def reshape(self, name: str, x: str, shape: tuple[int, ...]) -> str:
        return self._add(name, "reshape", [x], shape=tuple(shape))

    def cast(self, name: str, x: str, dtype: str) -> str:
        return self._add(name, "cast", [x], dtype=dtype)

    def identity(self, name: str, x: str) -> str:
        return self._add(name, "identity", [x])

    def stop_gradient(self, name: str, x: str) -> str:
        return self._add(name, "stop_gradient", [x])

    def reduce(self, name: str, x: str, fn: str = "sum", axis: int = -2) -> str:
        return self._add(name, "reduce", [x], fn=fn, axis=axis)

    def weighted_sum(self, name: str, weights: str, values: str) -> str:
        """weights (..., K), values (..., K, D) -> (..., D)."""
        return self._add(name, "weighted_sum", [weights, values])

    def cross_attention(self, name: str, q: str, k: str, v: str,
                        mask: str | None = None) -> str:
        ins = [q, k, v] + ([mask] if mask else [])
        return self._add(name, "cross_attention", ins, has_mask=mask is not None)

    def fm_interaction(self, name: str, x: str) -> str:
        """x (..., F, D) -> (...,) pairwise-interaction scalar (sum-square trick)."""
        return self._add(name, "fm_interaction", [x])

    def dot_interaction(self, name: str, x: str, keep_self: bool = False) -> str:
        """x (..., F, D) -> (..., F*(F-1)/2) pairwise dots (DLRM)."""
        return self._add(name, "dot_interaction", [x], keep_self=keep_self)

    def stack_features(self, name: str, xs: Sequence[str]) -> str:
        """Each x (..., D) -> (..., F, D)."""
        return self._add(name, "stack_features", xs)

    def output(self, *names: str) -> None:
        self.graph.set_outputs(list(names))


def infer_shapes(graph: Graph) -> dict[str, tuple[int, ...]]:
    """Per-example output shapes for every node (batch dim excluded)."""
    shapes: dict[str, tuple[int, ...]] = {}
    for n in graph.topo_order():
        ins = [shapes[i] for i in n.inputs]
        if n.op == "input":
            s = tuple(n.attrs["shape"])
        elif n.op == "dense" or n.op == "mari_dense":
            s = ins[0][:-1] + (n.attrs["units"],)
        elif n.op == "embedding":
            ids = ins[0]
            dim = n.attrs["dim"]
            if n.attrs.get("pool"):
                s = ids[:-1] + (dim,) if ids else (dim,)
            else:
                s = ids + (dim,)
        elif n.op == "concat":
            last = sum(x[-1] for x in ins)
            s = ins[0][:-1] + (last,)
        elif n.op in ("add", "mul", "sub"):
            s = ins[0] if len(ins[0]) >= len(ins[1]) else ins[1]
        elif n.op in ("act", "softmax", "identity", "stop_gradient", "cast", "scale"):
            s = ins[0]
        elif n.op == "target_attention":
            s = ins[0]  # (D,) pooled interest, same shape as query
        elif n.op == "mari_user_partial":
            s = (n.attrs["units"],)
        elif n.op == "attn_user_part":
            s = (ins[0][0], n.attrs["h1"])
        elif n.op == "attn_user_T":
            s = (ins[0][0], ins[0][1], n.attrs["h1"])
        elif n.op == "reshape":
            s = tuple(n.attrs["shape"])
        elif n.op == "reduce":
            ax = n.attrs["axis"]
            lst = list(ins[0])
            del lst[ax]
            s = tuple(lst)
        elif n.op == "weighted_sum":
            s = ins[1][:-2] + (ins[1][-1],)
        elif n.op == "cross_attention":
            s = ins[0]  # (I, d) or (d,)
        elif n.op == "fm_interaction":
            s = ins[0][:-2] + (1,)
        elif n.op == "dot_interaction":
            f = ins[0][-2]
            keep = n.attrs.get("keep_self", False)
            npair = f * (f + 1) // 2 if keep else f * (f - 1) // 2
            s = ins[0][:-2] + (npair,)
        elif n.op == "gather_last":
            s = ins[0][:-1] + (len(n.attrs["indices"]),)
        elif n.op == "stack_features":
            d = ins[0][-1]
            for x in ins:
                if x[-1] != d:
                    raise ValueError(f"stack_features {n.name}: mismatched dims {ins}")
            s = ins[0][:-1] + (len(ins), d)
        else:
            raise ValueError(f"shape inference: unknown op {n.op!r} ({n.name})")
        shapes[n.name] = s
    return shapes


def dense_in_dim(graph: Graph, node: Node,
                 shapes: dict[str, tuple[int, ...]] | None = None) -> int:
    shapes = shapes or infer_shapes(graph)
    return shapes[node.inputs[0]][-1]
