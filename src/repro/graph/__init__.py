from repro.graph.ir import Graph, GraphBuilder, Node, infer_shapes, TRANSPARENT_OPS  # noqa: F401
from repro.graph.executor import Executor, init_graph_params  # noqa: F401
