"""Graph executor: interprets a repro.graph IR under jit.

Batch semantics — the key to VanI / UOI / MaRI:

* Every feed carries a leading batch dim. Item/cross feeds arrive at B
  (candidate count); user feeds arrive at 1.
* ``vani`` mode tiles user feeds to B at entry — the whole graph runs at B
  (training-identical computation, fully redundant user side).
* ``uoi`` mode keeps user feeds at 1. Batch-1-ness propagates through the
  user-only subgraph automatically; the first op that mixes batch-1 with
  batch-B inputs (a concat, an add, an attention) broadcasts — that IS the
  deferred tile of Fig. 1(c).
* ``mari`` is not a mode here: the MaRI pass rewrites eligible ``dense``
  nodes into ``mari_dense`` nodes (repro.core.mari) and the rewritten graph
  runs in ``uoi`` mode — the tile is deferred *through* the matmul (Eq. 7).
* **row-wise user values** — user-side feeds (raw inputs, stage-2 boundary
  activations, rewritten-unit partials) may also arrive at batch B, where
  row b carries user b's value (a cross-user coalesced serving batch,
  gathered by ``reps[user_index]`` upstream). Every op dispatches on the
  leading dim: batch-1 operands take the broadcast (deferred-tile) forms,
  batch-B operands the row-wise forms; results are row-identical either
  way.
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.common import Array, KeySeq, glorot, normal_init
from repro.graph.ir import Graph, Node, infer_shapes
from repro.nn.layers import ACTIVATIONS
from repro.nn.attention import cross_attention

# Reserved feed key: per-candidate-row user index for kernel-side gather.
# When present, input nodes listed in ``Executor.lazy_gather_inputs``
# receive their STACKED (U, ...) rep table as the fed value and the gather
# moves into the consuming kernel: the Pallas mari_matmul indexes the
# (U, units) table at accumulator-init load, and the decomposed-attention
# contractions run through ``kernels.gather_einsum`` — the gathered
# (B, units) / (B, L, D, h) blocks never materialize. Out-of-range indices
# (padded batch rows) clamp everywhere (``mode="clip"``): they read a real
# user's reps instead of wrapping or going NaN, and their rows are sliced
# off by the serving engine like every other padded row.
USER_INDEX_FEED = "__user_index__"


def init_graph_params(graph: Graph, key, dtype=jnp.float32) -> dict:
    """Initialize params for every parameterized node."""
    ks = KeySeq(key)
    shapes = infer_shapes(graph)
    params: dict = {}
    for n in graph.topo_order():
        if n.op == "dense":
            din = shapes[n.inputs[0]][-1]
            p = {"w": glorot(next(ks), (din, n.attrs["units"]), dtype)}
            if n.attrs.get("use_bias", True):
                p["b"] = jnp.zeros((n.attrs["units"],), dtype)
            params[n.name] = p
        elif n.op == "embedding":
            scale = 1.0 / max(n.attrs["vocab"], 1) ** 0.5
            params[n.name] = {
                "table": normal_init(next(ks), (n.attrs["vocab"], n.attrs["dim"]),
                                     scale, dtype)}
        elif n.op == "target_attention":
            d = shapes[n.inputs[0]][-1]
            dims = (4 * d,) + tuple(n.attrs["mlp_hidden"]) + (1,)
            p = {}
            for li, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
                p[f"layer_{li}"] = {"w": glorot(next(ks), (di, do), dtype),
                                    "b": jnp.zeros((do,), dtype)}
            if n.attrs.get("decomposed"):
                # re-parameterized unit (core.mari.AttnRewrite): split blocks
                h1 = n.attrs["mlp_hidden"][0]
                p["layer_0"] = {
                    "w_kd": glorot(next(ks), (d, h1), dtype),
                    "w_qd": glorot(next(ks), (d, h1), dtype),
                    "w_p": glorot(next(ks), (d, h1), dtype),
                    "b": jnp.zeros((h1,), dtype)}
            params[n.name] = p
        elif n.op == "mari_dense":
            # Normally produced by repro.core.mari.convert_params; direct init
            # creates the already-split blocks.
            units = n.attrs["units"]
            p = {}
            for label, seg_idx in n.attrs["groups"]:
                d = sum(n.attrs["seg_widths"][i] for i in seg_idx)
                p[f"w_{label}"] = glorot(next(ks), (d, units), dtype)
            if n.attrs.get("use_bias", True):
                p["b"] = jnp.zeros((units,), dtype)
            params[n.name] = p
    return params


def _bcast_batch(xs: list[Array]) -> list[Array]:
    """Broadcast leading batch dims (1 -> B) across a list of arrays."""
    b = max(x.shape[0] for x in xs)
    out = []
    for x in xs:
        if x.shape[0] != b:
            x = jnp.broadcast_to(x, (b,) + x.shape[1:])
        out.append(x)
    return out


def _concat_xs(xs: list[Array]) -> Array:
    xs = _bcast_batch(xs) if len({x.shape[0] for x in xs}) > 1 else xs
    return jnp.concatenate(xs, axis=-1) if len(xs) > 1 else xs[0]


def _concat_ws(ws: list[Array]) -> Array:
    return jnp.concatenate(ws, axis=0) if len(ws) > 1 else ws[0]


def _mari_dense_operands(node: Node, params: dict, vals: dict):
    """Assemble (x, w) pairs + accumulator init + bias for a ``mari_dense``.

    Returns (parts, acc0, bias): ``parts`` is a list of (x, w) whose products
    sum to the pre-activation output (minus acc0/bias); ``acc0`` is a
    precomputed user partial — a (1, units) row, or a row-wise (B, units)
    block when stage 2 serves a cross-user coalesced batch — or None;
    ``bias`` is the bias vector or None.

    The batched (non-user) groups are fused into ONE (x, w) stream via the
    block-matmul identity Σ_g x_g W_g == concat(x_g) @ stack(W_g) — matching
    the Pallas kernel's single MXU stream. When the serving engine has
    pre-concatenated the grouped weights at build time (``w_cat`` in the
    node's params), the per-call weight concat disappears from the hot path;
    either way the streamed operands are identical, so scores are
    bit-identical with pre-concat on or off.
    """
    attrs = node.attrs
    p = params[node.name]
    cast = attrs.get("cast_dtype")

    def seg(name: str) -> Array:
        x = vals[name]
        return x.astype(cast) if cast else x

    parts: list[tuple[Array, Array]] = []
    acc0 = vals[node.inputs[0]] if attrs.get("precomputed_user") else None
    if attrs.get("fragment", False):
        if acc0 is not None:
            # Stage-2 residual of a split fragmented node: every remaining
            # segment is candidate-side — fuse them into one stream instead
            # of paying the Table-3 per-fragment launches while serving.
            x = _concat_xs([seg(nm) for nm in node.inputs[1:]])
            w = p.get("w_cat")
            if w is None:
                w = _concat_ws([p[f"w_seg{i}"]
                                for i in attrs["seg_param_idx"]])
            parts.append((x, w))
        else:
            # Table-3 regime: one small matmul per original concat segment
            # (batch-1-ness varies per segment, so no static fusion).
            for i, name in enumerate(node.inputs):
                parts.append((seg(name), p[f"w_seg{i}"]))
    else:
        # "groups" indices already point into node.inputs on both paths (the
        # split pass remaps them past the partial at position 0). The user
        # group (present only when un-peeled) stays its own one-shot part;
        # all other groups fuse into a single batched stream.
        rest_xs: list[Array] = []
        rest_ws: list[Array] = []
        for label, seg_idx in attrs["groups"]:
            if label == "user":
                parts.append((_concat_xs([seg(node.inputs[i])
                                          for i in seg_idx]), p["w_user"]))
            else:
                rest_xs.extend(seg(node.inputs[i]) for i in seg_idx)
                rest_ws.append(p[f"w_{label}"])
        if rest_xs:
            w = p.get("w_cat")
            if w is None:
                w = _concat_ws(rest_ws)
            parts.append((_concat_xs(rest_xs), w))
    bias = p["b"] if attrs.get("use_bias", True) else None
    return parts, acc0, bias


def _run_mari_dense(node: Node, params: dict, vals: dict, *,
                    use_pallas: bool = False, interpret: bool = True,
                    user_index: Array | None = None) -> Array:
    """Eq. 7: Tile(Σ_user x_u W_u, B) + Σ_rest x W  — tile realized as a
    broadcast add (never materialized).

    With ``use_pallas`` the batched side dispatches to the fused Pallas
    kernel (``kernels.mari_matmul``): user row as accumulator init, bias and
    activation applied in the kernel epilogue, so the (B, units)
    pre-activation never round-trips through HBM. With ``user_index`` the
    precomputed partial arrives as a stacked (U, units) table and the
    kernel gathers row ``user_index[b]`` at accumulator-init load time
    (bit-identical: gather commutes with the elementwise epilogue).
    """
    attrs = node.attrs
    parts, acc0, bias = _mari_dense_operands(node, params, vals)
    activation = attrs.get("activation", "identity")
    if use_pallas:
        from repro.kernels.mari_matmul import mari_matmul_fused_groups
        return mari_matmul_fused_groups(parts, bias, acc0=acc0,
                                        user_index=user_index,
                                        activation=activation,
                                        interpret=interpret)
    if user_index is not None and acc0 is not None:
        # jnp fallback: explicit gather; clip so a padded row's index can
        # never wrap to an arbitrary slot or NaN-poison the row
        acc0 = jnp.take(acc0, user_index, axis=0, mode="clip")
    acc = acc0
    for x, w in parts:
        y = x @ w
        acc = y if acc is None else acc + y  # (1,u) + (B,u) broadcasts
    if bias is not None:
        acc = acc + bias
    return ACTIVATIONS[activation](acc)


class Executor:
    """Interpret a graph. Construct once, then jit ``run``."""

    def __init__(self, graph: Graph, mode: str = "uoi", *,
                 use_pallas: bool = False, pallas_interpret: bool | None = None,
                 kernel_gather: bool = False, gather_attention: bool = False):
        if mode not in ("vani", "uoi"):
            raise ValueError(f"mode must be 'vani' or 'uoi', got {mode!r}")
        self.graph = graph
        self.mode = mode
        # Backend-gated Pallas dispatch for mari_dense: compiled on TPU,
        # interpret mode everywhere else (CPU validation).
        self.use_pallas = use_pallas
        if pallas_interpret is None:
            pallas_interpret = jax.default_backend() != "tpu"
        self.pallas_interpret = pallas_interpret
        self.gather_attention = gather_attention
        self._user_inputs = {
            n.name for n in graph.input_nodes() if n.attrs.get("domain") == "user"
        }
        # Gather-at-load: user-side inputs whose EVERY consumption is
        # gather-capable may be fed as stacked (U, ...) rep tables + a
        # USER_INDEX_FEED row index, and the consuming op indexes the table
        # inside its contraction instead of receiving a pre-gathered
        # row-wise value. Two consumer kinds qualify:
        #
        # * a Pallas ``mari_dense`` accumulator init (``kernel_gather``):
        #   the kernel gathers the (U, units) table at acc-init load;
        # * a decomposed+precomputed ``target_attention`` operand
        #   (``gather_attention``): keys / u_part / T (and the mask) are
        #   indexed by ``kernels.gather_einsum`` inside the attention
        #   contractions, so the (B, L, D, h)-class gathered blocks never
        #   materialize.
        #
        # Any other consumer needs the materialized row-wise value, so such
        # inputs stay on the explicit-gather path.
        self.lazy_gather_inputs: frozenset[str] = frozenset()
        allow_md = kernel_gather and use_pallas
        if allow_md or gather_attention:
            lazy = set()
            for n in graph.input_nodes():
                if n.attrs.get("domain") != "user":
                    continue
                cons = graph.consumers(n.name)
                if cons and all(
                        (allow_md and self._is_md_acc_init(c, n.name))
                        or (gather_attention
                            and self._is_attn_operand(c, n.name))
                        for c in cons):
                    lazy.add(n.name)
            self.lazy_gather_inputs = frozenset(lazy)

    @staticmethod
    def _is_md_acc_init(c: Node, name: str) -> bool:
        """``name`` feeds ``c`` only as a Pallas-eligible mari_dense
        accumulator init (the mixed-precision path keeps jnp)."""
        return (c.op == "mari_dense"
                and c.attrs.get("precomputed_user")
                and not c.attrs.get("cast_dtype")
                and c.inputs[0] == name
                and c.inputs.count(name) == 1)

    @staticmethod
    def _is_attn_operand(c: Node, name: str) -> bool:
        """``name`` feeds ``c`` only in gather-capable positions of a
        decomposed, precomputed target_attention: keys (1), u_part (-2),
        T (-1), and the mask (2) when present. The query (0) is
        candidate-side by construction and never qualifies."""
        if not (c.op == "target_attention" and c.attrs.get("decomposed")
                and c.attrs.get("precomputed")):
            return False
        k = len(c.inputs)
        allowed = {1, k - 2, k - 1}
        if c.attrs.get("has_mask"):
            allowed.add(2)
        return all(i in allowed
                   for i, s in enumerate(c.inputs) if s == name)

    def run(self, params: dict, feeds: Mapping[str, Array]) -> dict[str, Array]:
        vals: dict[str, Array] = {}
        if USER_INDEX_FEED in feeds:
            vals[USER_INDEX_FEED] = feeds[USER_INDEX_FEED]
        batch = max((v.shape[0] for k, v in feeds.items()
                     if k not in self._user_inputs and k != USER_INDEX_FEED),
                    default=1)
        for n in self.graph.topo_order():
            vals[n.name] = self._eval(n, params, vals, feeds, batch)
        return {o: vals[o] for o in self.graph.outputs}

    def __call__(self, params, feeds):
        return self.run(params, feeds)

    def _gather_einsum(self, spec, x, table, uidx) -> Array:
        """Contract ``x`` against the stacked ``(U, ...)`` table, indexed
        per row by ``uidx`` — Pallas kernel when enabled, jnp.take oracle
        otherwise (bit-identical semantics; only the memory profile
        differs)."""
        if self.use_pallas:
            from repro.kernels.gather_einsum import gather_einsum
            return gather_einsum(spec, x, table, uidx,
                                 interpret=self.pallas_interpret)
        from repro.kernels.gather_einsum import gather_einsum_ref
        return gather_einsum_ref(spec, x, table, uidx)

    # ------------------------------------------------------------------
    def _eval(self, n: Node, params, vals, feeds, batch: int) -> Array:
        op = n.op
        if op == "input":
            x = feeds[n.name]
            if (self.mode == "vani" and n.name in self._user_inputs
                    and x.shape[0] == 1 and batch > 1):
                x = jnp.broadcast_to(x, (batch,) + x.shape[1:])
            return x
        ins = [vals[i] for i in n.inputs]
        if op == "dense":
            p = params[n.name]
            y = ins[0] @ p["w"]
            if n.attrs.get("use_bias", True):
                y = y + p["b"]
            return ACTIVATIONS[n.attrs.get("activation", "identity")](y)
        if op == "mari_dense":
            # The Pallas path requires a clean f32 pipeline; mixed-precision
            # (cast_dtype) nodes keep the jnp path.
            use_pallas = self.use_pallas and not n.attrs.get("cast_dtype")
            uidx = (vals.get(USER_INDEX_FEED)
                    if n.inputs and n.inputs[0] in self.lazy_gather_inputs
                    else None)
            return _run_mari_dense(n, params, vals, use_pallas=use_pallas,
                                   interpret=self.pallas_interpret,
                                   user_index=uidx)
        if op == "mari_user_partial":
            # Stage-1 half of a split mari_dense: Σ_user x_u W_u (+ b), a
            # (1, units) row the batched stage consumes as accumulator init.
            p = params[n.attrs["param_of"]]
            cast = n.attrs.get("cast_dtype")
            if n.attrs.get("fragment"):
                acc = None
                for i, name in zip(n.attrs["seg_idx"], n.inputs):
                    x = vals[name]
                    if cast:
                        x = x.astype(cast)
                    y = x @ p[f"w_seg{i}"]
                    acc = y if acc is None else acc + y
            else:
                xs = [vals[i] for i in n.inputs]
                x = jnp.concatenate(xs, axis=-1) if len(xs) > 1 else xs[0]
                if cast:
                    x = x.astype(cast)
                acc = x @ p["w_user"]
            if n.attrs.get("use_bias", True) and "b" in p:
                acc = acc + p["b"]
            return acc
        if op == "attn_user_part":
            # One-shot k @ w_kd (+ b) of a decomposed target_attention.
            l0 = params[n.attrs["param_of"]]["layer_0"]
            return (ins[0][0] @ l0["w_kd"] + l0["b"])[None]
        if op == "attn_user_T":
            # One-shot T[l,d,h] = k[l,d] * w_p[d,h].
            l0 = params[n.attrs["param_of"]]["layer_0"]
            return (ins[0][0][:, :, None] * l0["w_p"][None])[None]
        if op == "embedding":
            rows = jnp.take(params[n.name]["table"], ins[0], axis=0)
            pool = n.attrs.get("pool")
            if pool == "sum":
                rows = rows.sum(axis=-2)
            elif pool == "mean":
                rows = rows.mean(axis=-2)
            return rows
        if op == "concat":
            xs = _bcast_batch(ins)
            return jnp.concatenate(xs, axis=n.attrs.get("axis", -1))
        if op == "add":
            return ins[0] + ins[1]
        if op == "mul":
            return ins[0] * ins[1]
        if op == "sub":
            return ins[0] - ins[1]
        if op == "scale":
            return ins[0] * n.attrs["factor"]
        if op == "target_attention":
            from repro.nn.attention import target_attention as _ta
            from repro.nn.layers import dense_apply
            p = params[n.name]
            nlayers = len(p)
            q, keys = ins[0], ins[1]
            if n.attrs.get("has_mask"):
                mask = ins[2]
            else:
                mask = jnp.ones(keys.shape[:-1], bool)

            if n.attrs.get("decomposed") and "w_kd" in p["layer_0"]:
                # Beyond-paper re-parameterized unit (core.mari.AttnRewrite).
                # The user-side tensors carry batch 1 (one user per batch —
                # the (B, L, 4D) feature tensor never materializes and the
                # broadcast einsums realize the deferred tile) OR batch B
                # (row-wise: a cross-user coalesced batch where row b holds
                # user b's gathered tensors) OR — gather-aware serving —
                # arrive as stacked (U, ...) rep tables alongside a
                # USER_INDEX_FEED, in which case the per-row gather folds
                # into the contractions (kernels.gather_einsum) and the
                # (B, L, D, h)-class gathered blocks never materialize.
                l0 = p["layer_0"]
                uidx = vals.get(USER_INDEX_FEED)

                def stacked(name: str) -> bool:
                    return uidx is not None and name in self.lazy_gather_inputs

                t_stacked = u_stacked = k_stacked = False
                if n.attrs.get("precomputed"):
                    # Two-stage serving: one-shot tensors arrive from stage 1
                    # (core.split) — bias is folded into u_part there.
                    u_part = ins[-2]                    # (1|B|U, L, h)
                    t = ins[-1]                         # (1|B|U, L, D, h)
                    u_stacked = stacked(n.inputs[-2])
                    t_stacked = stacked(n.inputs[-1])
                    k_stacked = stacked(n.inputs[1])
                else:
                    if keys.shape[0] == 1:
                        u_part = (keys[0] @ l0["w_kd"] + l0["b"])[None]
                        t = (keys[0][:, :, None] * l0["w_p"][None])[None]
                    else:                               # row-wise keys
                        u_part = keys @ l0["w_kd"] + l0["b"]
                        t = keys[..., None] * l0["w_p"][None, None]
                if n.attrs.get("has_mask") and stacked(n.inputs[2]):
                    mask = jnp.take(mask, uidx, axis=0, mode="clip")
                elif not n.attrs.get("has_mask") and k_stacked:
                    # the default all-ones mask above took its shape from
                    # the STACKED keys (U, L): re-shape to broadcast (1, L)
                    mask = jnp.ones((1,) + keys.shape[1:-1], bool)
                q_part = q @ l0["w_qd"]                 # (B, h)
                if t_stacked:
                    p_part = self._gather_einsum("bd,uldh->blh", q, t, uidx)
                elif t.shape[0] == 1 and q.shape[0] != 1:
                    p_part = jnp.einsum("bd,ldh->blh", q, t[0])
                else:
                    p_part = jnp.einsum("bd,bldh->blh", q, t)
                if u_stacked:
                    # (B, L, h) exists anyway as the relu output below, so
                    # an explicit (clamped) gather costs nothing extra
                    u_part = jnp.take(u_part, uidx, axis=0, mode="clip")
                h = jax.nn.relu(u_part + q_part[:, None, :] + p_part)
                for li in range(1, nlayers):
                    h = dense_apply(p[f"layer_{li}"], h)
                    if li < nlayers - 1:
                        h = jax.nn.relu(h)
                scores = h[..., 0]                      # (B, L)
                scores = jnp.where(mask, scores, -1e30)
                w = jax.nn.softmax(scores, axis=-1)
                if k_stacked:
                    return self._gather_einsum("bl,uld->bd", w, keys, uidx)
                if keys.shape[0] == 1 and w.shape[0] != 1:
                    return jnp.einsum("bl,ld->bd", w, keys[0])
                return jnp.einsum("bl,bld->bd", w, keys)

            def mlp_apply(x):
                for li in range(nlayers):
                    x = dense_apply(p[f"layer_{li}"], x)
                    if li < nlayers - 1:
                        x = jax.nn.relu(x)
                return x

            return _ta(q, keys, mask, mlp_apply)
        if op == "act":
            return ACTIVATIONS[n.attrs["fn"]](ins[0])
        if op == "softmax":
            return jax.nn.softmax(ins[0], axis=n.attrs.get("axis", -1))
        if op == "reshape":
            return ins[0].reshape((ins[0].shape[0],) + tuple(n.attrs["shape"]))
        if op == "cast":
            return ins[0].astype(n.attrs["dtype"])
        if op in ("identity", "stop_gradient"):
            return jax.lax.stop_gradient(ins[0]) if op == "stop_gradient" else ins[0]
        if op == "reduce":
            fn = {"sum": jnp.sum, "mean": jnp.mean, "max": jnp.max}[n.attrs["fn"]]
            return fn(ins[0], axis=n.attrs["axis"])
        if op == "weighted_sum":
            w, v = ins
            if w.shape[0] != v.shape[0]:
                w, v = _bcast_batch([w, v])
            return jnp.einsum("...k,...kd->...d", w, v)
        if op == "cross_attention":
            q, k, v = ins[0], ins[1], ins[2]
            mask = ins[3] if n.attrs.get("has_mask") else None
            squeeze = q.ndim == 2
            if squeeze:
                q = q[:, None, :]
            out = cross_attention(q, k, v, mask)
            return out[:, 0, :] if squeeze else out
        if op == "fm_interaction":
            x = ins[0]
            s = x.sum(axis=-2)
            sq = (x * x).sum(axis=-2)
            return (0.5 * (s * s - sq).sum(axis=-1))[..., None]
        if op == "dot_interaction":
            x = ins[0]
            f = x.shape[-2]
            z = jnp.einsum("...fd,...gd->...fg", x, x)
            iu, ju = jnp.triu_indices(f, k=0 if n.attrs.get("keep_self") else 1)
            return z[..., iu, ju]
        if op == "gather_last":
            idx = jnp.asarray(n.attrs["indices"], jnp.int32)
            return jnp.take(ins[0], idx, axis=-1)
        if op == "stack_features":
            xs = _bcast_batch(ins)
            return jnp.stack(xs, axis=-2)
        raise ValueError(f"executor: unknown op {op!r} ({n.name})")
