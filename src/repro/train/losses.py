"""Losses and ranking metrics."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import Array


def bce_with_logits(logits: Array, labels: Array, weights: Array | None = None) -> Array:
    """Numerically stable binary cross-entropy over logits."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    per = jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    if weights is not None:
        per = per * weights
        return per.sum() / jnp.maximum(weights.sum(), 1.0)
    return per.mean()


def softmax_xent(logits: Array, labels: Array) -> Array:
    """logits: (..., V); labels: (...) int ids. Mean NLL."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def auc(scores, labels) -> float:
    """Exact ROC-AUC via rank statistic (numpy, for eval-time use)."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel()
    pos = scores[labels == 1]
    neg = scores[labels == 0]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    # average ranks for ties
    s = np.concatenate([pos, neg])[order]
    ranks[order] = np.arange(1, len(s) + 1)
    _, inv, cnt = np.unique(s, return_inverse=True, return_counts=True)
    sums = np.zeros(len(cnt))
    np.add.at(sums, inv, ranks)
    ranks = (sums / cnt)[inv]
    r_pos = ranks[: len(pos)].sum()
    return float((r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg)))


def valid_task_aucs(scores, labels) -> dict[int, float]:
    """Per-task ROC-AUCs over the trailing task axis, skipping degenerate
    slices.

    ``scores``/``labels`` are ``(B, T)`` multi-task outputs. A task whose
    label slice is single-class has no defined ROC (``auc`` returns NaN);
    such tasks are OMITTED from the result instead of poisoning downstream
    comparisons — callers assert on the tasks that remain."""
    scores = np.asarray(scores)
    labels = np.asarray(labels)
    out: dict[int, float] = {}
    for t in range(scores.shape[-1]):
        a = auc(scores[..., t], labels[..., t])
        if not np.isnan(a):
            out[t] = a
    return out
