from repro.train.optim import sgd, adam, adamw, adafactor, Optimizer, clip_by_global_norm  # noqa: F401
from repro.train.losses import bce_with_logits, softmax_xent, auc  # noqa: F401
