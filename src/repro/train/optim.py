"""Optimizers, built in-repo (optax is not available in this environment).

API mirrors the (init, update) gradient-transformation style so optimizer
states are plain pytrees — shardable with pjit and checkpointable as-is.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.common import Array, PyTree


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    # update(grads, opt_state, params) -> (updates, new_opt_state);
    # apply with: params = tree_map(lambda p, u: p + u, params, updates)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params):
        del params
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), ()
        new_m = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree_util.tree_map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, master_weights: bool = False) -> Optimizer:
    """AdamW. With ``master_weights=True`` the state carries an f32 master
    copy of the params (mixed-precision training: params may live in bf16,
    updates are applied to the master and re-cast) — combined with ZeRO
    sharding of the state this is the standard large-scale setup.
    """
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        st = {"mu": z, "nu": jax.tree_util.tree_map(jnp.zeros_like, z),
              "step": jnp.zeros((), jnp.int32)}
        if master_weights:
            st["master"] = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params)
        return st

    def update(grads, state, params):
        step = state["step"] + 1
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p, w):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / b1t
            vhat = v / b2t
            delta = -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
            if master_weights:
                w_new = w + delta
                return (w_new.astype(p.dtype) - p, m, v, w_new)
            return (delta.astype(p.dtype), m, v, None)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        if master_weights:
            flat_w = treedef.flatten_up_to(state["master"])
        else:
            flat_w = [p.astype(jnp.float32) for p in flat_p]
        out = [upd(g, m, v, p, w)
               for g, m, v, p, w in zip(flat_g, flat_m, flat_v, flat_p, flat_w)]
        updates = treedef.unflatten([o[0] for o in out])
        new_state = {
            "mu": treedef.unflatten([o[1] for o in out]),
            "nu": treedef.unflatten([o[2] for o in out]),
            "step": step,
        }
        if master_weights:
            new_state["master"] = treedef.unflatten([o[3] for o in out])
        return updates, new_state

    return Optimizer(init, update)


def adafactor(lr: float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer — O(n+m) state for (n,m) matrices.

    The memory-lean choice for billion-row embedding tables at scale.
    """
    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"m": jax.tree_util.tree_map(st, params,
                                            is_leaf=lambda x: isinstance(x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, eps))[..., None]
                u = g * jax.lax.rsqrt(jnp.maximum(r * vc[..., None, :], eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), new_s

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state["m"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                {"m": treedef.unflatten([o[1] for o in out]), "step": step})

    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float
    warmup_steps: int
    total_steps: int
    min_ratio: float = 0.1

    def __call__(self, step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(self.warmup_steps, 1)
        prog = (step - self.warmup_steps) / jnp.maximum(
            self.total_steps - self.warmup_steps, 1)
        cos = self.min_ratio + (1 - self.min_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * jnp.clip(prog, 0.0, 1.0)))
        return self.peak_lr * jnp.where(step < self.warmup_steps, warm, cos)
