"""Training loop with checkpoint/restart and (simulated) failure handling.

The loop is framework-generic: it drives any ``step_fn(state, batch) ->
(state, metrics)`` with a data iterator, a CheckpointManager, and an optional
failure injector — the restart path is exactly what a preempted worker runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3


def train_loop(
    step_fn: Callable,
    init_state,
    batches: Iterator,
    ckpt: CheckpointManager,
    cfg: LoopConfig,
    *,
    fail_at: int | None = None,      # inject a crash (tests/drills)
    log: Callable[[str], None] = print,
):
    """Runs to cfg.total_steps, resuming from the newest checkpoint if one
    exists. Returns (state, history)."""
    state = init_state
    start = 0
    if ckpt.latest_step() is not None:
        state, meta = ckpt.restore(init_state)
        start = int(meta["step"]) + 1
        log(f"[loop] resumed from step {meta['step']}")

    history = []
    t0 = time.time()
    for step in range(start, cfg.total_steps):
        batch = next(batches)
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        state, metrics = step_fn(state, batch)
        if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            history.append({"step": step, **m})
            log(f"[loop] step {step}: " +
                " ".join(f"{k}={v:.5f}" for k, v in m.items()))
        if step % cfg.ckpt_every == 0 and step > 0:
            ckpt.save(step, state)
    ckpt.save(cfg.total_steps - 1, state)
    ckpt.wait()
    log(f"[loop] done {cfg.total_steps - start} steps "
        f"in {time.time() - t0:.1f}s")
    return state, history
