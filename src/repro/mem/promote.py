"""``PromotionWorker`` — async, frequency-gated cold -> hot promotion.

A cold hit serves the request from the arena read alone; whether the user
DESERVES a hot (and hence device-tier) slot is decided off the request
path by this worker. The policy is Zipf-friendly: promotion requires
``touches`` cold hits within a ``window_s`` sliding window, so a one-shot
tail user — the overwhelming majority of a Zipf stream — never enters the
hot LRU, never evicts a genuinely-hot user, and never costs a device-table
row write. A user crossing the threshold is promoted by re-reading its
arena row and ``put``-ting it into the hot cache: the NEXT request finds
it there (and the engine's existing write-barrier path makes it
device-resident), all without a single stage-1 recompute.

The worker never touches the device tier directly — ``DeviceRepStore``
writes are only sound under the engine's write barrier, so device
residency always follows the normal resolve path one request later.

Threading: one daemon thread drains a queue of touch events. ``touch`` is
non-blocking (queue put). The worker calls ``cold.peek`` (arena leaf
lock) and ``cache.put`` (cache lock; its removal listeners fire OUTSIDE
that lock and may demote back into the arena) — the lock order
worker -> cache -> (released) -> arena is acyclic. ``flush()`` blocks
until every touch enqueued so far has been processed — what makes
promotion deterministic in tests and benchmarks.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Hashable

Key = tuple[Hashable, Hashable]          # (user_id, feature_version)

_PRUNE_EVERY = 1024      # touches between sweeps of stale touch histories


class PromotionWorker:
    """Background promotion policy over a (cold store, hot cache) pair."""

    def __init__(self, cold, cache, *, touches: int = 2,
                 window_s: float = 60.0, tracer=None,
                 clock=time.monotonic):
        if touches < 1:
            raise ValueError(f"touches must be >= 1, got {touches}")
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.cold = cold
        self.cache = cache
        self.touches = touches
        self.window_s = window_s
        self._tracer = tracer
        self._clock = clock
        self._q: queue.Queue = queue.Queue()
        # key -> deque of touch timestamps inside the window
        self._history: dict[Key, deque] = {}
        self._since_prune = 0
        self.touches_seen = 0
        self.promotions = 0
        self._closed = False
        self._thread = threading.Thread(target=self._run,
                                        name="mem-promoter", daemon=True)
        self._thread.start()

    # -- request-path API ----------------------------------------------------
    def touch(self, key: Key) -> None:
        """Record one cold hit for ``key`` (non-blocking)."""
        if not self._closed:
            self._q.put(key)

    def flush(self, timeout: float | None = 10.0) -> None:
        """Block until every touch enqueued so far is processed."""
        if timeout is None:
            self._q.join()
            return
        done = threading.Event()
        # ride the queue: a sentinel task enqueued now is processed only
        # after everything ahead of it
        self._q.put(done)
        done.wait(timeout)

    def stop(self) -> None:
        self._closed = True
        self._q.put(None)
        self._thread.join(timeout=10.0)

    # -- worker loop ---------------------------------------------------------
    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                if isinstance(item, threading.Event):
                    item.set()
                    continue
                self._process(item)
            except Exception:
                # promotion is best-effort: a failed put (e.g. a closing
                # cache) must not kill the worker or the serving path
                pass
            finally:
                self._q.task_done()

    def _process(self, key: Key) -> None:
        self.touches_seen += 1
        now = self._clock()
        hist = self._history.setdefault(key, deque())
        hist.append(now)
        while hist and now - hist[0] > self.window_s:
            hist.popleft()
        self._since_prune += 1
        if self._since_prune >= _PRUNE_EVERY:
            self._since_prune = 0
            stale = [k for k, h in self._history.items()
                     if not h or now - h[-1] > self.window_s]
            for k in stale:
                self._history.pop(k, None)
        if len(hist) < self.touches:
            return
        self._history.pop(key, None)
        if key in self.cache:
            return                      # already promoted by another path
        reps = self.cold.peek(key)
        if reps is None:
            return                      # demoted/evicted/invalidated since
        self.cache.put(key, reps)
        self.promotions += 1
        if self._tracer is not None:
            self._tracer.instant("promote", user=key[0],
                                 touches=self.touches)

    def stats(self) -> dict:
        return {
            "touches_seen": self.touches_seen,
            "promotions": self.promotions,
            "pending": self._q.qsize(),
            "tracked_keys": len(self._history),
            "touches": self.touches,
            "window_s": self.window_s,
        }
