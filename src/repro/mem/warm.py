"""``RepWarmer`` — the bulk warming feed into the cold tier.

Warming precomputes stage-1 representations OFFLINE (from a training
refresh, a nightly job, a launch ramp) straight into the cold arena, so a
warmed user's first live request is already a cold hit: one arena read,
zero stage-1 compute on the request path.

Bit-identity contract: the warmer dispatches the engine's OWN jitted
stage-1 executable per user at the live path's exact ``(1, ...)`` feed
shapes — never a differently-batched variant — so a warmed rep is
bit-identical to what the request path would have computed, and serving
from it is bit-identical to recompute. Batching happens at the dispatch
level instead: launches within a ``batch``-sized chunk are enqueued
asynchronously and synced ONCE per chunk, so the device pipelines the
chunk while the host stores the previous one — the offline feed runs at
throughput without touching the numerics.

Duplicate-feed memoization: callers replaying one feed dict across many
user ids (synthetic universes, template users, the benchmarks' pool-reuse
pattern) pay stage 1 once per DISTINCT feeds object per ``warm`` call —
identical inputs compute identical rows, so the memo is value-exact.
"""
from __future__ import annotations

from typing import Any, Hashable, Iterable, Mapping, Sequence

Item = tuple[Hashable, Hashable, Mapping[str, Any]]
#      (user_id, feature_version, user_feeds)


class RepWarmer:
    """Batched offline stage-1 feed into a ``ColdRepStore``.

    ``stage1_fn(params, user_feeds) -> reps`` is the (jitted,
    non-blocking) user-tower executable; ``cold`` the destination arena;
    ``batch`` the chunk size between device syncs.
    """

    def __init__(self, stage1_fn, cold, *, batch: int = 256, tracer=None):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.stage1_fn = stage1_fn
        self.cold = cold
        self.batch = batch
        self._tracer = tracer
        self.warmed = 0              # users written into the cold tier
        self.stage1_launches = 0     # distinct stage-1 dispatches paid

    def warm(self, items: Iterable[Item], params) -> int:
        """Precompute reps for ``items`` into the cold tier; returns the
        number of users warmed. Items are ``(user_id, feature_version,
        user_feeds)`` with user feeds at leading dim 1."""
        import jax
        import numpy as np

        items = list(items)
        total = 0
        for lo in range(0, len(items), self.batch):
            chunk = items[lo:lo + self.batch]
            # launch the whole chunk without blocking; memoize by feeds
            # object identity (same object => same values => same reps)
            memo: dict[int, Any] = {}
            launched: list[tuple[Hashable, Hashable, int]] = []
            for uid, ver, feeds in chunk:
                fid = id(feeds)
                if fid not in memo:
                    memo[fid] = self.stage1_fn(params, feeds)
                    self.stage1_launches += 1
                launched.append((uid, ver, fid))
            # one sync per chunk: the device pipelines the chunk's
            # dispatches while the host was still enqueueing them —
            # then materialize each distinct result to numpy ONCE and
            # fan it out to every user id that shares it (the arena
            # copies rows into its slabs, so sharing the source is safe)
            jax.block_until_ready(list(memo.values()))
            memo_np = {fid: {k: np.asarray(v) for k, v in r.items()}
                       for fid, r in memo.items()}
            for uid, ver, fid in launched:
                self.cold.put((uid, ver), memo_np[fid])
            total += len(launched)
            self.warmed += len(launched)
            if self._tracer is not None:
                self._tracer.instant("warm", users=len(launched),
                                     total=self.warmed)
        return total
