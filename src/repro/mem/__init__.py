"""Hierarchical memory tier for the serving runtime (``MemPlan``).

MaRI's win is reusing user-side precompute; this package is what lets
"reusing" scale past one device's memory. It layers a third tier UNDER the
existing hot host LRU (``repro.serve.cache.UserRepCache``) and device slot
table (``DeviceRepStore``):

* ``cold``    — ``ColdRepStore``: a byte-budgeted, slab-allocated host-RAM
  numpy arena per stage-2 boundary, keyed ``(user_id, feature_version)``.
  Hot-LRU eviction DEMOTES reps here instead of discarding them; a later
  request pays one arena read instead of a stage-1 recompute.
* ``promote`` — ``PromotionWorker``: a background thread applying a
  Zipf-friendly frequency gate (k touches within a window) before copying
  a cold row back into the hot LRU — one-shot tail users never thrash the
  hot/device tiers, and promotion never blocks a request.
* ``warm``    — ``RepWarmer``: the bulk offline feed — batched stage-1
  dispatch straight into the cold arena, so a warmed user's first live
  request is already a hit.

Tier walk on a request: hot LRU -> device slots (resolve) on a hot hit;
on a hot miss, cold arena (serve from the read, touch the promoter,
stay OFF the device tier); only a full miss recomputes stage 1. Every
path is bit-identical — cold rows are raw copies of stage-1 outputs and
cold-served packs take the engine's re-stacking route.

Everything is driven by the plan spine: ``ServePlan.mem``
(``repro.serve.plan.MemPlan``) with ``cold_tier`` / ``cold_bytes`` /
``promote_touches`` / ``promote_window_s`` / ``warm_batch``; the engine
wires the tiers, the obs instants (``cold_hit`` / ``cold_miss`` /
``promote`` / ``demote`` / ``warm``) and the per-tier gauges.
``benchmarks/memtier.py`` measures the hit-rate/latency frontier up to
U=1M users.
"""
from repro.mem.cold import ColdRepStore  # noqa: F401
from repro.mem.promote import PromotionWorker  # noqa: F401
from repro.mem.warm import RepWarmer  # noqa: F401
