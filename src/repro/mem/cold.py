"""``ColdRepStore`` — the host-RAM cold tier of the rep hierarchy.

Stage-1 representations that fall out of the hot ``UserRepCache`` (or are
pre-warmed offline) land here instead of being discarded: a byte-budgeted,
slab-allocated numpy arena per stage-2 boundary tensor, keyed by
``(user_id, feature_version)``. A later request for a cold user pays ONE
arena read (a few row memcpys) instead of a stage-1 recompute — the whole
point of the MARM-style hierarchy: cheap host bytes convert into hit rate,
and hit rate into latency.

Why slabs, not one dict of per-user arrays: at the intended scale
(hundreds of thousands to millions of users) per-user numpy objects cost
an allocator round-trip + object overhead each, and a byte budget over
them is only enforceable by walking the dict. The arena instead allocates
``slab_rows``-row slabs per boundary lazily as occupancy grows, addresses
user rows as ``slot -> (slab, row)``, and recycles slots LRU when the
budget's row capacity is reached — steady-state churn allocates NOTHING
(rows are overwritten in place), and the slab count is bounded by
``ceil(capacity / slab_rows)`` forever (asserted by test).

Layout is discovered from the first ``put`` (same lazy contract as
``DeviceRepStore._alloc``): per-boundary dtype + per-row shape, from which
``bytes_per_user`` and the slot ``capacity = cold_bytes // bytes_per_user``
follow. Later rows must match the layout exactly — a drifting rep shape is
rejected, never silently resized.

Bit-exactness: rows are stored as raw numpy copies of the stage-1 outputs
and read back as copies — a demote -> promote round trip returns the
identical bytes, so serving from cold (or from a later re-promotion to
hot/device) is bit-identical to recompute by construction.

Thread safety: one leaf lock around every operation. Callers (the hot
cache's removal listeners, the promotion worker, request threads) may hold
no cache lock here — ``UserRepCache`` fires listeners outside its lock —
and this store calls nothing back, so the lock order is acyclic.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable, Mapping

import numpy as np

Key = tuple[Hashable, Hashable]          # (user_id, feature_version)

DEFAULT_SLAB_ROWS = 1024


class ColdRepStore:
    """Byte-budgeted slab arena of stage-1 reps, keyed like the hot LRU.

    ``cold_bytes`` bounds the arena payload: once the per-user row size is
    known (first ``put``), the budget fixes a slot ``capacity`` and
    inserting past it recycles the least-recently-touched user's slot
    (``evictions``). ``slab_rows`` sizes the lazy allocation granule.
    """

    def __init__(self, cold_bytes: int,
                 slab_rows: int = DEFAULT_SLAB_ROWS):
        if cold_bytes < 1:
            raise ValueError(f"cold_bytes must be >= 1, got {cold_bytes}")
        if slab_rows < 1:
            raise ValueError(f"slab_rows must be >= 1, got {slab_rows}")
        self.cold_bytes = int(cold_bytes)
        self._slab_rows = int(slab_rows)
        # per-boundary layout, discovered from the first put
        self._layout: dict[str, tuple[tuple[int, ...], np.dtype]] | None = None
        self.bytes_per_user: int | None = None
        self.capacity: int | None = None
        self._slabs: dict[str, list[np.ndarray]] = {}
        # user_id -> (feature_version, slot); insertion order == LRU order
        self._map: OrderedDict[Hashable, tuple[Hashable, int]] = OrderedDict()
        self._free: list[int] = []       # recycled slots (LIFO)
        self._next_slot = 0              # high-water mark of virgin slots
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0               # budget-bound slot recycles

    # -- layout -------------------------------------------------------------
    def _discover_layout(self, row: Mapping[str, np.ndarray]) -> None:
        layout = {}
        per_user = 0
        for k in sorted(row):
            v = row[k]
            layout[k] = (tuple(v.shape), v.dtype)
            per_user += int(v.nbytes)
        self._layout = layout
        self.bytes_per_user = max(per_user, 1)
        self.capacity = max(1, self.cold_bytes // self.bytes_per_user)
        self._slab_rows = min(self._slab_rows, self.capacity)

    def _row_of(self, reps: Mapping[str, Any]) -> dict[str, np.ndarray]:
        """Normalize one user's rep pytree to per-boundary numpy rows
        (leading dim 1 stripped), validating against the arena layout."""
        row = {}
        for k, v in reps.items():
            a = np.asarray(v)
            if a.ndim < 1 or a.shape[0] != 1:
                raise ValueError(
                    f"boundary {k!r}: cold-tier rows are per-user reps with "
                    f"leading dim 1, got shape {a.shape}")
            row[k] = a[0]
        if self._layout is not None:
            if set(row) != set(self._layout):
                raise ValueError(
                    f"rep boundaries {sorted(row)} do not match the arena "
                    f"layout {sorted(self._layout)}")
            for k, (shape, dtype) in self._layout.items():
                if tuple(row[k].shape) != shape or row[k].dtype != dtype:
                    raise ValueError(
                        f"boundary {k!r}: row {row[k].shape}/{row[k].dtype} "
                        f"does not match the arena layout {shape}/{dtype}")
        return row

    def _slab_of(self, boundary: str, slot: int) -> tuple[np.ndarray, int]:
        idx, off = divmod(slot, self._slab_rows)
        slabs = self._slabs.setdefault(boundary, [])
        shape, dtype = self._layout[boundary]
        while len(slabs) <= idx:
            slabs.append(np.empty((self._slab_rows,) + shape, dtype))
        return slabs[idx], off

    # -- mutation -----------------------------------------------------------
    def put(self, key: Key, reps: Mapping[str, Any]) -> None:
        """Store (demote/warm) one user's reps. An existing entry for the
        user is overwritten in place (any version); at capacity the
        least-recently-touched user's slot is recycled."""
        user_id, version = key
        row = self._row_of(reps)
        with self._lock:
            if self._layout is None:
                self._discover_layout(row)
                row = self._row_of(reps)   # validate against the new layout
            entry = self._map.get(user_id)
            if entry is not None:
                slot = entry[1]
            elif self._free:
                slot = self._free.pop()
            elif self._next_slot < self.capacity:
                slot = self._next_slot
                self._next_slot += 1
            else:
                # budget reached: recycle the LRU user's slot in place —
                # no new slab is ever allocated past capacity
                _, (_, slot) = self._map.popitem(last=False)
                self.evictions += 1
            for k, v in row.items():
                slab, off = self._slab_of(k, slot)
                slab[off] = v
            self._map[user_id] = (version, slot)
            self._map.move_to_end(user_id)
            self.puts += 1

    def get(self, key: Key) -> dict[str, np.ndarray] | None:
        """Read one user's reps back as fresh leading-dim-1 numpy copies
        (LRU-refreshing). None on miss or version mismatch — a stale
        version is dropped (its slot recycles) rather than served."""
        user_id, version = key
        with self._lock:
            entry = self._map.get(user_id)
            if entry is None:
                self.misses += 1
                return None
            if entry[0] != version:
                # stale feature version: never servable again
                self._map.pop(user_id)
                self._free.append(entry[1])
                self.misses += 1
                return None
            self._map.move_to_end(user_id)
            self.hits += 1
            return self._read_slot(entry[1])

    def _read_slot(self, slot: int) -> dict[str, np.ndarray]:
        out = {}
        for k in self._layout:
            slab, off = self._slab_of(k, slot)
            out[k] = slab[off][None].copy()    # fresh (1, ...) row copy
        return out

    def peek(self, key: Key) -> dict[str, np.ndarray] | None:
        """``get`` without touching hit/miss counters or dropping stale
        versions (the promotion worker's re-read must not double-count
        the request path's cold hit)."""
        user_id, version = key
        with self._lock:
            entry = self._map.get(user_id)
            if entry is None or entry[0] != version:
                return None
            self._map.move_to_end(user_id)
            return self._read_slot(entry[1])

    def drop(self, user_id: Hashable) -> int:
        """Remove any version of ``user_id`` (invalidation hook); the slot
        recycles. Returns entries removed (0 or 1)."""
        with self._lock:
            entry = self._map.pop(user_id, None)
            if entry is None:
                return 0
            self._free.append(entry[1])
            return 1

    def clear(self) -> None:
        with self._lock:
            self._map.clear()
            self._free = []
            self._next_slot = 0

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def __contains__(self, key: Key) -> bool:
        user_id, version = key
        with self._lock:
            entry = self._map.get(user_id)
            return entry is not None and entry[0] == version

    def keys(self) -> list[Key]:
        with self._lock:
            return [(uid, ver) for uid, (ver, _) in self._map.items()]

    @property
    def slab_count(self) -> int:
        """Allocated slabs per boundary (bounded by
        ``ceil(capacity / slab_rows)`` — the no-leak invariant)."""
        with self._lock:
            return max((len(s) for s in self._slabs.values()), default=0)

    def stats(self) -> dict:
        with self._lock:
            slab_bytes = sum(int(s.nbytes) for slabs in self._slabs.values()
                             for s in slabs)
            return {
                "users": len(self._map),
                "capacity": self.capacity,
                "cold_bytes": self.cold_bytes,
                "bytes_per_user": self.bytes_per_user,
                "bytes": (len(self._map) * self.bytes_per_user
                          if self.bytes_per_user else 0),
                "slab_bytes": slab_bytes,
                "slabs": max((len(s) for s in self._slabs.values()),
                             default=0),
                "slab_rows": self._slab_rows,
                "hits": self.hits,
                "misses": self.misses,
                "puts": self.puts,
                "evictions": self.evictions,
            }
