"""Two-stage serving pipeline: graph bipartition (core.split), user-rep
caching, bucketed batch compilation, and Pallas-backed mari_dense — the
inference workflow of Fig. 2 end-to-end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_mari, split_two_stage
from repro.core.mari import convert_params, mari_rewrite
from repro.data.features import make_recsys_feeds
from repro.graph.executor import Executor, init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.models.recsys import build_din
from repro.serve.engine import ServeRequest, ServingEngine


def _paper_setup(scale=0.05, batch=23):
    graph, cfg = build_paper_ranking_model(PaperRankingConfig().scaled(scale))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    feeds = make_recsys_feeds(graph, batch, jax.random.PRNGKey(1))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, feeds, user_in


def _request(feeds, user_in, user_id=0, version=0):
    return ServeRequest(
        user_id=user_id,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


class TestSplitStructure:
    def test_stage1_is_user_only(self):
        graph, params, _, _ = _paper_setup()
        mg, _, _ = apply_mari(graph, params)
        split = split_two_stage(mg)
        # every stage-1 node is user-side (or a partial of a rewritten unit)
        for n in split.stage1.nodes.values():
            assert (n.name in split.user_nodes
                    or n.op == "mari_user_partial"
                    or n.op.startswith("attn_user")), n.name
        # no user-domain *feature* input survives in stage 2: the user tower
        # was peeled off, only boundary activations/partials cross over
        s2_inputs = {n.name for n in split.stage2.input_nodes()}
        assert "user_profile" not in s2_inputs
        assert split.n_precompute_nodes > 0

    def test_mari_dense_partials_peeled(self):
        graph, params, _, _ = _paper_setup()
        mg, _, conv = apply_mari(graph, params)
        split = split_two_stage(mg)
        for r in conv.rewrites:
            assert f"{r.dense}::u" in split.stage1.nodes
            node2 = split.stage2.nodes[r.dense]
            assert node2.attrs["precomputed_user"]
            assert not any(lab == "user" for lab, _ in node2.attrs["groups"])

    def test_boundary_specs_match_stage1_outputs(self):
        """boundary_specs names every stage-2 user-side input and carries
        the per-example shape the coalescing runtime stacks rep tables by."""
        graph, params, feeds, _ = _paper_setup()
        mg, mp, _ = apply_mari(graph, params)
        split = split_two_stage(mg)
        assert set(split.boundary_specs) == set(split.stage1.outputs)
        s2_user = {n.name for n in split.stage2.input_nodes()
                   if n.attrs.get("domain") == "user"}
        assert s2_user <= set(split.boundary_specs)
        s1_in = {n.name for n in split.stage1.input_nodes()}
        reps = Executor(split.stage1, "uoi").run(
            mp, {k: v for k, v in feeds.items() if k in s1_in})
        for name, spec in split.boundary_specs.items():
            assert tuple(reps[name].shape[1:]) == tuple(spec), name

    def test_attention_one_shot_tensors_peeled(self):
        graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                             mlp=(24, 12), item_vocab=128)
        conv = mari_rewrite(graph, reparam_attention=True)
        split = split_two_stage(conv.graph)
        assert "din_attn::u_part" in split.stage1.nodes
        assert "din_attn::T" in split.stage1.nodes
        assert split.stage2.nodes["din_attn"].attrs["precomputed"]


class TestLossless:
    """stage-1 ∘ stage-2 == single-graph uoi == vani, to f32 tolerance."""

    @pytest.mark.parametrize("fragment", [False, True])
    def test_paper_model(self, fragment):
        graph, params, feeds, user_in = _paper_setup()
        ref = Executor(graph, "vani").run(params, feeds)
        mg, mp, _ = apply_mari(graph, params, fragment=fragment)
        uoi = Executor(mg, "uoi").run(mp, feeds)
        split = split_two_stage(mg)
        s1_in = {n.name for n in split.stage1.input_nodes()}
        reps = Executor(split.stage1, "uoi").run(
            mp, {k: v for k, v in feeds.items() if k in s1_in})
        cand = {k: v for k, v in feeds.items() if k not in user_in}
        out = Executor(split.stage2, "uoi").run(mp, {**reps, **cand})
        for o in graph.outputs:
            np.testing.assert_allclose(out[o], uoi[o], rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(out[o], ref[o], rtol=2e-4, atol=2e-4)

    def test_din_with_reparam_attention(self):
        graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                             mlp=(24, 12), item_vocab=128)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        feeds = make_recsys_feeds(graph, 11, jax.random.PRNGKey(1))
        ref = Executor(graph, "vani").run(params, feeds)["logit"]
        conv = mari_rewrite(graph, reparam_attention=True)
        mp = convert_params(conv, params)
        split = split_two_stage(conv.graph)
        s1_in = {n.name for n in split.stage1.input_nodes()}
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        reps = Executor(split.stage1, "uoi").run(
            mp, {k: v for k, v in feeds.items() if k in s1_in})
        cand = {k: v for k, v in feeds.items() if k not in user_in}
        out = Executor(split.stage2, "uoi").run(mp, {**reps, **cand})["logit"]
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestEngineCaching:
    def test_repeat_user_skips_stage1(self):
        graph, params, feeds, user_in = _paper_setup()
        eng = ServingEngine(graph, params, mode="mari", max_batch=16)
        assert eng.two_stage
        r1 = eng.score(_request(feeds, user_in, user_id=5))
        assert not r1.user_cache_hit and eng.stage1_calls == 1
        r2 = eng.score(_request(feeds, user_in, user_id=5))
        # no user-only node re-executed: the stage-1 counter did not move
        assert r2.user_cache_hit and eng.stage1_calls == 1
        np.testing.assert_allclose(r1.scores, r2.scores, atol=1e-6)

    def test_feature_version_invalidates(self):
        graph, params, feeds, user_in = _paper_setup()
        eng = ServingEngine(graph, params, mode="mari", max_batch=16)
        eng.score(_request(feeds, user_in, user_id=5, version=0))
        r = eng.score(_request(feeds, user_in, user_id=5, version=1))
        assert not r.user_cache_hit and eng.stage1_calls == 2

    def test_new_version_evicts_old(self):
        """One live cache entry per user: a version bump frees the old reps
        instead of accumulating them."""
        graph, params, feeds, user_in = _paper_setup()
        eng = ServingEngine(graph, params, mode="mari", max_batch=16)
        for v in range(4):
            eng.score(_request(feeds, user_in, user_id=5, version=v))
        assert len(eng.cache) == 1
        assert (5, 3) in eng.cache

    def test_invalidate_user_drops_all_versions(self):
        graph, params, feeds, user_in = _paper_setup()
        eng = ServingEngine(graph, params, mode="mari", max_batch=16)
        eng.score(_request(feeds, user_in, user_id=5, version=0))
        eng.score(_request(feeds, user_in, user_id=5, version=1))
        eng.invalidate_user(5)
        r = eng.score(_request(feeds, user_in, user_id=5, version=0))
        assert not r.user_cache_hit

    def test_modes_agree_two_stage(self):
        graph, params, feeds, user_in = _paper_setup()
        outs = {}
        for mode in ("vani", "uoi", "mari"):
            eng = ServingEngine(graph, params, mode=mode, max_batch=16)
            outs[mode] = eng.score(_request(feeds, user_in)).scores
        np.testing.assert_allclose(outs["uoi"], outs["vani"],
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs["mari"], outs["vani"],
                                   rtol=2e-4, atol=2e-4)


class TestUnservableSplit:
    """A domain-less input pulled into the user closure cannot be fed under
    the user/candidate request contract: auto two-stage falls back to
    single-stage, explicit two_stage=True raises."""

    def _graph(self):
        from repro.graph.ir import GraphBuilder
        b = GraphBuilder()
        u = b.input("u", (6,), "user")
        ctx = b.input("ctx", (4,), None)        # uncolored global context
        i = b.input("i", (5,), "item")
        uc = b.concat("uc", [u, ctx])           # yellow closure pulls in ctx
        c = b.concat("c", [uc, i])
        f = b.dense("f", c, 8, activation="relu")
        out = b.dense("out", f, 1)
        b.output(out)
        return b.graph

    def test_auto_falls_back_single_stage(self):
        g = self._graph()
        params = init_graph_params(g, jax.random.PRNGKey(0))
        eng = ServingEngine(g, params, mode="mari", max_batch=16)
        assert not eng.two_stage
        B = 7
        feeds = {
            "u": jax.random.normal(jax.random.PRNGKey(1), (1, 6)),
            "ctx": jax.random.normal(jax.random.PRNGKey(2), (1, 4)),
            "i": jax.random.normal(jax.random.PRNGKey(3), (B, 5)),
        }
        ref = Executor(g, "vani").run(params, feeds)["out"]
        req = ServeRequest(0, {"u": feeds["u"], "ctx": feeds["ctx"]},
                           {"i": feeds["i"]})
        np.testing.assert_allclose(eng.score(req).scores, np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_explicit_two_stage_raises(self):
        g = self._graph()
        params = init_graph_params(g, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="non-user feeds"):
            ServingEngine(g, params, mode="mari", max_batch=16,
                          two_stage=True)


class TestBucketedBatching:
    """Regression for the _split tail-padding bug: a lone chunk smaller than
    max_batch used to keep its ragged shape and recompile per pool size."""

    def test_single_compile_across_pool_sizes(self):
        graph, params, feeds, user_in = _paper_setup(scale=0.03)
        eng = ServingEngine(graph, params, mode="mari", max_batch=128)
        for n in (100, 1000, 3000):
            feeds_n = make_recsys_feeds(graph, n, jax.random.PRNGKey(n))
            r = eng.score(_request(feeds_n, user_in))
            assert r.scores.shape[0] == n
        assert eng.stage2_compilations == 1

    def test_pow2_bucket_bound(self):
        import math
        graph, params, feeds, user_in = _paper_setup(scale=0.03)
        eng = ServingEngine(graph, params, mode="mari", max_batch=4096)
        sizes = (100, 1000, 3000)
        for n in sizes:
            feeds_n = make_recsys_feeds(graph, n, jax.random.PRNGKey(n))
            eng.score(_request(feeds_n, user_in))
        bound = math.ceil(math.log2(max(sizes) / min(sizes))) + 1
        assert eng.stage2_compilations <= bound

    def test_scores_unaffected_by_padding(self):
        graph, params, feeds, user_in = _paper_setup(scale=0.03, batch=40)
        big = ServingEngine(graph, params, mode="mari", max_batch=4096)
        small = ServingEngine(graph, params, mode="mari", max_batch=16)
        r_big = big.score(_request(feeds, user_in))
        r_small = small.score(_request(feeds, user_in))
        assert r_small.n_batches == 3
        np.testing.assert_allclose(r_big.scores, r_small.scores,
                                   rtol=1e-5, atol=1e-5)


class TestEnginePallas:
    def test_pallas_engine_matches_jnp_engine(self):
        graph, params, feeds, user_in = _paper_setup()
        ref = ServingEngine(graph, params, mode="mari", max_batch=16)
        pal = ServingEngine(graph, params, mode="mari", max_batch=16,
                            use_pallas=True)
        r1 = ref.score(_request(feeds, user_in))
        r2 = pal.score(_request(feeds, user_in))
        np.testing.assert_allclose(r2.scores, r1.scores, rtol=1e-4, atol=1e-4)
