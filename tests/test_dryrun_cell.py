"""Dry-run regression test: one real (arch × shape × mesh) cell compiles on
the 256-device production mesh in a subprocess (the 512-host-device flag
must never leak into this test process)."""
import json
import os
import subprocess
import sys

import jax
import pytest


def test_single_cell_dryrun_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "fm", "--shape", "serve_p99", "--mesh", "single"],
        capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    rec = json.loads(p.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 256
    assert rec["kind"] == "serve"
    assert rec["roofline"]["bottleneck"] in (
        "compute_s", "memory_s", "collective_s")
    assert rec["cost"]["flops_per_device"] > 0
    # MaRI conversion must have fired inside the cell build
    assert rec["meta"] == {} or "mari_rewrites" in rec["meta"]


def test_flag_isolation():
    """This process must still see exactly ONE device (conftest guarantee)."""
    assert len(jax.devices()) == 1
