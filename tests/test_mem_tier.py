"""Hierarchical memory tier (``repro.mem`` + ``MemPlan``).

Covers the three-tier contract end to end: hot-LRU evictions demote into
the cold arena, a cold hit serves from one arena read (bit-identical to
recompute, no stage-1 call, no device slot), the async worker promotes
only frequency-qualified users back to hot, and the bulk warming feed
makes a warmed user's first live request a cold hit. Plus the arena's
budget/no-leak invariants, the promotion policy in isolation, the
``UserRepCache`` removal-record contract (fired outside the lock), and
the ``MemPlan`` validation rows.
"""
import threading

import jax
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.mem import ColdRepStore, PromotionWorker, RepWarmer
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve import MemPlan, ServePlan, ServeRequest, ServingEngine
from repro.serve.cache import UserRepCache
from repro.serve.plan import PlanError, PlanResolutionWarning


@pytest.fixture(scope="module")
def paper():
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n=8, seed=None, version=0):
    feeds = make_recsys_feeds(
        graph, n, jax.random.PRNGKey(uid if seed is None else seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


def _cold_plan(**overrides):
    base = dict(cache__max_cached_users=2, mem__cold_tier=True,
                mem__cold_bytes=1 << 22, mem__promote_touches=2,
                batch__hedging=False, batch__linger_ms=0.0)
    base.update(overrides)
    return ServePlan().evolve(**base)


def _reps(uid, d=8):
    return {"a": np.full((1, d), float(uid), np.float32),
            "b": np.full((1, 2, 3), uid, np.int32)}


# -- ColdRepStore ------------------------------------------------------------
class TestColdRepStore:
    def test_round_trip_bit_exact(self):
        cold = ColdRepStore(1 << 16)
        reps = {"a": np.arange(8, dtype=np.float32)[None] * 0.3,
                "b": np.ones((1, 2, 3), np.int32)}
        cold.put((1, 0), reps)
        got = cold.get((1, 0))
        for k in reps:
            assert got[k].shape == reps[k].shape
            assert got[k].dtype == reps[k].dtype
            assert np.array_equal(got[k], reps[k])
        # reads hand back COPIES: mutating one must not poison the arena
        got["a"][:] = -1
        assert np.array_equal(cold.get((1, 0))["a"], reps["a"])

    def test_stale_version_dropped_not_served(self):
        cold = ColdRepStore(1 << 16)
        cold.put((1, 0), _reps(1))
        assert cold.get((1, 7)) is None
        assert (1, 0) not in cold          # stale entry dropped outright
        assert cold.stats()["misses"] == 1

    def test_budget_overflow_evicts_lru_without_leaking_slabs(self):
        per_user = 8 * 4 + 2 * 3 * 4       # bytes of _reps rows
        cold = ColdRepStore(cold_bytes=10 * per_user, slab_rows=4)
        for u in range(50):
            cold.put((u, 0), _reps(u))
        st = cold.stats()
        assert st["capacity"] == 10
        assert st["users"] == 10
        assert st["evictions"] == 40
        # the no-leak invariant: slabs are bounded by ceil(capacity /
        # slab_rows) FOREVER — churn recycles rows in place
        assert st["slabs"] == 3
        assert st["slab_bytes"] <= 3 * 4 * per_user
        # survivors are the 10 most recent, values intact
        for u in range(40, 50):
            assert cold.get((u, 0))["a"][0, 0] == float(u)
        for u in range(40):
            assert cold.get((u, 0)) is None

    def test_lru_refresh_on_get(self):
        cold = ColdRepStore(cold_bytes=3 * (8 * 4 + 2 * 3 * 4))
        for u in range(3):
            cold.put((u, 0), _reps(u))
        cold.get((0, 0))                   # refresh user 0
        cold.put((3, 0), _reps(3))         # evicts user 1, not 0
        assert (0, 0) in cold and (1, 0) not in cold

    def test_layout_drift_rejected(self):
        cold = ColdRepStore(1 << 16)
        cold.put((1, 0), _reps(1))
        with pytest.raises(ValueError, match="layout"):
            cold.put((2, 0), {"a": np.zeros((1, 9), np.float32),
                              "b": np.zeros((1, 2, 3), np.int32)})
        with pytest.raises(ValueError, match="leading dim 1"):
            cold.put((2, 0), {"a": np.zeros((2, 8), np.float32),
                              "b": np.zeros((2, 2, 3), np.int32)})


# -- PromotionWorker ---------------------------------------------------------
class TestPromotionWorker:
    def test_k_touches_within_window_promotes(self):
        cold = ColdRepStore(1 << 16)
        cache = UserRepCache(max_users=8)
        t = [0.0]
        pw = PromotionWorker(cold, cache, touches=3, window_s=5.0,
                             clock=lambda: t[0])
        try:
            cold.put((1, 0), _reps(1))
            for i in range(2):
                pw.touch((1, 0))
            pw.flush()
            assert (1, 0) not in cache     # below threshold
            pw.touch((1, 0))
            pw.flush()
            assert (1, 0) in cache
            assert pw.promotions == 1
            # promoted copy is bit-identical to the arena row
            assert np.array_equal(cache.get((1, 0))["a"], _reps(1)["a"])
        finally:
            pw.stop()

    def test_window_expiry_resets_tail_users(self):
        cold = ColdRepStore(1 << 16)
        cache = UserRepCache(max_users=8)
        t = [0.0]
        pw = PromotionWorker(cold, cache, touches=2, window_s=5.0,
                             clock=lambda: t[0])
        try:
            cold.put((1, 0), _reps(1))
            pw.touch((1, 0))
            pw.flush()                     # process BEFORE moving the clock
            t[0] = 10.0                    # first touch now outside window
            pw.touch((1, 0))
            pw.flush()
            assert (1, 0) not in cache     # one-shot-per-window: no promote
            pw.touch((1, 0))
            pw.flush()
            assert (1, 0) in cache         # two touches at t=10: promoted
        finally:
            pw.stop()

    def test_vanished_cold_row_is_a_noop(self):
        cold = ColdRepStore(1 << 16)
        cache = UserRepCache(max_users=8)
        pw = PromotionWorker(cold, cache, touches=1, window_s=60.0)
        try:
            pw.touch((9, 0))               # never put into cold
            pw.flush()
            assert (9, 0) not in cache and pw.promotions == 0
        finally:
            pw.stop()


# -- UserRepCache removal records --------------------------------------------
class TestCacheRemovalRecords:
    def test_records_carry_reason_and_reps(self):
        cache = UserRepCache(max_users=2)
        seen = []
        cache.subscribe_removal(
            lambda uid, ver, reps, reason: seen.append((uid, ver, reason)))
        cache.put((1, 0), _reps(1))
        cache.put((2, 0), _reps(2))
        cache.put((3, 0), _reps(3))        # evicts user 1 (LRU)
        cache.put((2, 1), _reps(2))        # supersedes user 2's version 0
        cache.invalidate_user(3)
        cache.clear()
        assert seen == [(1, 0, "evict"), (2, 0, "supersede"),
                        (3, 0, "invalidate"), (2, 1, "clear")]

    def test_eviction_record_reps_are_the_cached_values(self):
        cache = UserRepCache(max_users=1)
        got = []
        cache.subscribe_removal(
            lambda uid, ver, reps, reason: got.append(reps))
        r1 = _reps(1)
        cache.put((1, 0), r1)
        cache.put((2, 0), _reps(2))
        assert len(got) == 1
        assert got[0] is r1                # the exact cached mapping

    def test_listeners_fire_outside_the_cache_lock(self):
        """The demote path (and any listener) may take other locks — so
        the cache lock must NOT be held while listeners run. Probe it:
        a non-blocking acquire inside the callback must succeed."""
        cache = UserRepCache(max_users=1)
        lock_free = []

        def probe(uid, ver, reps, reason):
            ok = cache._lock.acquire(blocking=False)
            if ok:
                cache._lock.release()
            lock_free.append(ok)

        cache.subscribe_removal(probe)
        cache.put((1, 0), _reps(1))
        cache.put((2, 0), _reps(2))        # evict -> probe fires
        cache.invalidate_user(2)           # invalidate -> probe fires
        assert lock_free == [True, True]

    def test_legacy_uid_only_subscribers_still_work(self):
        cache = UserRepCache(max_users=1)
        uids = []
        cache.subscribe(uids.append)
        cache.put((1, 0), _reps(1))
        cache.put((2, 0), _reps(2))        # evicts user 1
        assert uids == [1]


# -- engine integration ------------------------------------------------------
class TestEngineMemTier:
    @pytest.mark.parametrize("mode", ["vani", "uoi", "mari"])
    def test_demote_promote_round_trip_bit_identical(self, paper, mode):
        """Eviction churn pushes a user to cold; the cold-served scores,
        the post-promotion hot-served scores, and a cache-off engine's
        recompute must all be bit-identical."""
        graph, params, user_in = paper
        plan = _cold_plan(graph__mode=mode)
        eng = ServingEngine(graph, params, plan=plan)
        off = ServingEngine(graph, params, plan=ServePlan().evolve(
            graph__mode=mode, cache__cache_user_reps=False,
            batch__hedging=False, batch__linger_ms=0.0))
        try:
            if mode == "vani":
                # single-stage: no stage-1 outputs to tier — the cold
                # tier disarms (same forcing as cache_user_reps) and
                # serving works unchanged
                assert not eng.cold_tier
                r = eng.score(_request(graph, user_in, 0))
                assert not r.cold_hit
                return
            assert eng.cold_tier
            r0 = _request(graph, user_in, 0)
            base = eng.score(r0)
            # churn users 1..2 through the 2-slot hot LRU: user 0 demotes
            for u in (1, 2):
                eng.score(_request(graph, user_in, u))
            assert eng.demotions >= 1
            s1 = eng.stage1_calls
            cold = eng.score(r0)
            assert cold.cold_hit and not cold.user_cache_hit
            assert eng.stage1_calls == s1          # no recompute
            assert np.array_equal(cold.scores, base.scores)
            # second touch inside the window qualifies the promotion
            eng.score(r0)
            eng.flush_promotions()
            hot = eng.score(r0)
            assert hot.user_cache_hit and not hot.cold_hit
            assert np.array_equal(hot.scores, base.scores)
            # ... and everything equals the cache-off recompute
            assert np.array_equal(off.score(r0).scores, base.scores)
        finally:
            eng.close()
            off.close()

    def test_cold_hit_skips_device_tier(self, paper):
        """A cold-served (by policy, tail) user must not cost a device
        slot: its packs take the bit-identical re-stacking fallback."""
        graph, params, user_in = paper
        plan = _cold_plan(cache__device_resident=True,
                          cache__device_slots=4)
        eng = ServingEngine(graph, params, plan=plan)
        try:
            r0 = _request(graph, user_in, 0)
            base = eng.score(r0)
            for u in (1, 2):
                eng.score(_request(graph, user_in, u))
            writes = eng._device_store.stats()["writes"]
            cold = eng.score(r0)
            assert cold.cold_hit
            assert eng._device_store.stats()["writes"] == writes
            assert np.array_equal(cold.scores, base.scores)
        finally:
            eng.close()

    def test_warm_then_serve_first_request_hits(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_cold_plan())
        off = ServingEngine(graph, params, plan=ServePlan().evolve(
            cache__cache_user_reps=False, batch__hedging=False,
            batch__linger_ms=0.0))
        try:
            reqs = [_request(graph, user_in, u) for u in range(5, 9)]
            n = eng.warm([(r.user_id, r.user_feeds) for r in reqs])
            assert n == len(reqs)
            s1 = eng.stage1_calls
            for r in reqs:
                res = eng.score(r)
                assert res.cold_hit, "warmed user's FIRST request must hit"
                assert np.array_equal(res.scores, off.score(r).scores)
            assert eng.stage1_calls == s1
            assert eng.mem_stats()["warm"]["warmed"] == len(reqs)
        finally:
            eng.close()
            off.close()

    def test_invalidate_drops_warmed_only_user(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_cold_plan())
        try:
            r = _request(graph, user_in, 11)
            eng.warm([(r.user_id, r.user_feeds)])
            eng.invalidate_user(11)
            res = eng.score(r)
            assert not res.cold_hit and not res.user_cache_hit
        finally:
            eng.close()

    def test_version_bump_misses_cold(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_cold_plan())
        try:
            eng.score(_request(graph, user_in, 0))
            for u in (1, 2):
                eng.score(_request(graph, user_in, u))   # demote user 0
            res = eng.score(_request(graph, user_in, 0, version=1))
            assert not res.cold_hit        # stale version never served
        finally:
            eng.close()

    def test_mem_gauges_and_instants(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params,
                            plan=_cold_plan(obs__trace=True))
        try:
            r0 = _request(graph, user_in, 0)
            eng.score(r0)
            for u in (1, 2):
                eng.score(_request(graph, user_in, u))
            eng.score(r0)
            eng.score(r0)
            eng.flush_promotions()
            eng.score(r0)
            eng.warm([(5, _request(graph, user_in, 5).user_feeds)])
            names = {e[1] for e in eng.tracer.events()}
            assert {"cold_hit", "cold_miss", "promote", "demote",
                    "warm"} <= names
            snap = eng.metrics.snapshot()
            assert snap["cold_hits"] >= 2
            assert snap["demotions"] >= 1
            assert snap["promotions"] >= 1
            assert snap["warmed_users"] == 1
            assert snap["cold_users"] >= 1
        finally:
            eng.close()

    def test_warm_requires_cold_tier(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=ServePlan().evolve(
            batch__hedging=False))
        try:
            with pytest.raises(RuntimeError, match="cold_tier"):
                eng.warm([(0, _request(graph, user_in, 0).user_feeds)])
        finally:
            eng.close()


# -- MemPlan -----------------------------------------------------------------
class TestMemPlan:
    def test_defaults_off_and_round_trip(self):
        p = ServePlan()
        assert p.mem == MemPlan()
        assert not p.mem.cold_tier
        p2 = p.evolve(mem__cold_tier=True, mem__cold_bytes=1 << 20,
                      mem__promote_touches=3, mem__promote_window_s=5.0,
                      mem__warm_batch=64)
        assert ServePlan.from_json(p2.to_json()) == p2

    @pytest.mark.parametrize("field,value", [
        ("cold_bytes", 0), ("promote_touches", 0),
        ("promote_window_s", 0.0), ("warm_batch", 0)])
    def test_non_positive_knobs_reject(self, field, value):
        with pytest.raises(PlanError, match=field):
            ServePlan().evolve(**{f"mem__{field}": value})

    def test_type_contract(self):
        with pytest.raises(PlanError, match="cold_bytes"):
            ServePlan(mem={"cold_bytes": "256MiB"})

    def test_cold_tier_without_cache_resolves_off(self):
        with pytest.warns(PlanResolutionWarning, match="cold_tier"):
            p = ServePlan().evolve(cache__cache_user_reps=False,
                                   mem__cold_tier=True)
        assert not p.mem.cold_tier
        assert any("cold_tier" in n for n in p.resolution_notes)

    def test_mem_knobs_without_cold_tier_resolve_to_defaults(self):
        with pytest.warns(PlanResolutionWarning, match="warm_batch"):
            p = ServePlan().evolve(mem__warm_batch=64)
        assert p.mem == MemPlan()

    def test_resolution_idempotent_through_round_trip(self):
        import warnings as w
        with w.catch_warnings():
            w.simplefilter("ignore", PlanResolutionWarning)
            p = ServePlan().evolve(cache__cache_user_reps=False,
                                   mem__cold_tier=True,
                                   mem__promote_touches=9)
        # the resolved plan serializes clean: no warning on reload
        with w.catch_warnings():
            w.simplefilter("error", PlanResolutionWarning)
            p2 = ServePlan.from_json(p.to_json())
        assert p2 == p


# -- RepWarmer ---------------------------------------------------------------
class TestRepWarmer:
    def test_memoizes_shared_feed_objects_per_chunk(self):
        calls = []

        def s1(params, feeds):
            calls.append(1)
            return {"a": feeds["x"] * params}

        cold = ColdRepStore(1 << 20)
        w = RepWarmer(s1, cold, batch=3)
        shared = {"x": np.full((1, 8), 2.0, np.float32)}
        n = w.warm([(u, 0, shared) for u in range(7)], 3.0)
        assert n == 7 and len(cold) == 7
        # 7 users / batch 3 = 3 chunks, one launch per distinct feeds
        # object per chunk
        assert len(calls) == 3
        assert np.allclose(cold.get((4, 0))["a"], 6.0)

    def test_distinct_feeds_each_launch(self):
        def s1(params, feeds):
            return {"a": feeds["x"] + params}

        cold = ColdRepStore(1 << 20)
        w = RepWarmer(s1, cold, batch=8)
        items = [(u, 0, {"x": np.full((1, 4), float(u), np.float32)})
                 for u in range(5)]
        w.warm(items, 10.0)
        assert w.stage1_launches == 5
        for u in range(5):
            assert np.allclose(cold.get((u, 0))["a"], u + 10.0)
