"""The async coalescing serve runtime: cross-user stage-2 batching
(bit-identical to per-request scoring), bounded LRU user-rep cache, real
hedged execution, weight pre-concatenation, and candidate-axis sharding.
"""
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.models.recsys import build_din
from repro.serve import (CoalescingBatcher, HedgedRunner, HedgePolicy,
                         ServePlan, ServeRequest, ServingEngine)
from repro.serve.cache import DeviceRepStore, UserRepCache


@pytest.fixture(scope="module")
def paper():
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n, seed, version=0):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


def _assert_bit_identical(per, co):
    for p, c in zip(per, co):
        assert p.scores.shape == c.scores.shape
        assert np.array_equal(p.scores, c.scores), (
            f"coalesced diverged: max diff "
            f"{np.abs(p.scores - c.scores).max()}")


class TestUserRepCache:
    def test_lru_bound_and_evictions(self):
        c = UserRepCache(max_users=2)
        c.put((1, 0), {"x": 1})
        c.put((2, 0), {"x": 2})
        c.get((1, 0))                      # 1 is now most recent
        c.put((3, 0), {"x": 3})            # evicts LRU user 2
        assert c.evictions == 1
        assert (2, 0) not in c and (1, 0) in c and (3, 0) in c

    def test_version_supersede_not_counted_as_eviction(self):
        c = UserRepCache(max_users=8)
        c.put((1, 0), {"x": 1})
        c.put((1, 1), {"x": 2})
        assert len(c) == 1 and (1, 1) in c
        assert c.evictions == 0            # supersede, not capacity pressure

    def test_invalidate_user(self):
        c = UserRepCache()
        c.put((1, 0), {})
        c.put((2, 0), {})
        assert c.invalidate_user(1) == 1
        assert (1, 0) not in c and (2, 0) in c

    def test_unbounded_by_default(self):
        c = UserRepCache()
        for u in range(100):
            c.put((u, 0), {})
        assert len(c) == 100 and c.evictions == 0

    def test_engine_surfaces_evictions(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=32,
                            max_cached_users=2, hedging=False)
        for uid in range(4):
            eng.score(_request(graph, user_in, uid, 9, seed=uid))
        assert len(eng.cache) == 2
        assert eng.cache_evictions == 2
        # evicted user recomputes stage 1; resident user hits
        assert not eng.score(
            _request(graph, user_in, 0, 9, seed=0)).user_cache_hit
        assert eng.score(
            _request(graph, user_in, 3, 9, seed=3)).user_cache_hit


class TestCoalescedLossless:
    """Scores from the batcher (many users coalesced into one bucket) must
    match per-request ``score()`` EXACTLY — ragged tails, chunked pools, and
    cache hits/misses mixed in one batch."""

    @pytest.mark.parametrize("mode", ["vani", "uoi", "mari"])
    def test_modes_bit_identical(self, paper, mode):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode=mode, max_batch=128,
                            hedging=False)
        reqs = [_request(graph, user_in, 0, 23, seed=1),
                _request(graph, user_in, 1, 40, seed=2),
                _request(graph, user_in, 2, 7, seed=3),
                _request(graph, user_in, 0, 31, seed=4),   # repeat user
                _request(graph, user_in, 3, 64, seed=5)]
        per = [eng.score(r) for r in reqs]
        # max_coalesce == len(reqs) closes the group deterministically once
        # all requests are queued (no reliance on linger timing under load)
        with CoalescingBatcher(eng, linger_ms=2000.0,
                               max_coalesce=len(reqs)) as b:
            co = b.score_many(reqs)
        _assert_bit_identical(per, co)
        assert eng.coalesced_calls >= 1
        assert b.coalesced_requests == len(reqs)

    def test_mixed_hits_and_misses_one_batch(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=256,
                            hedging=False)
        warm = _request(graph, user_in, 7, 20, seed=7)
        ref_warm = eng.score(warm)                  # user 7 now cached
        fresh = [_request(graph, user_in, 8, 33, seed=8),
                 _request(graph, user_in, 9, 12, seed=9)]
        ref_fresh = [ServingEngine(graph, params, mode="mari", max_batch=256,
                                   hedging=False).score(r) for r in fresh]
        co = eng.score_coalesced([warm] + fresh)
        assert co[0].user_cache_hit and not co[1].user_cache_hit
        _assert_bit_identical([ref_warm] + ref_fresh, co)
        assert all(r.coalesced for r in co)

    def test_pool_larger_than_max_batch_spills_chunks(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            min_bucket=16, hedging=False)
        reqs = [_request(graph, user_in, 0, 150, seed=1),   # 64+64+22
                _request(graph, user_in, 1, 30, seed=2)]    # tail shares
        per = [eng.score(r) for r in reqs]
        co = eng.score_coalesced(reqs)
        _assert_bit_identical(per, co)
        # the 22-row tail and the 30-row pool coalesce into one 64 bucket
        assert co[0].n_batches == 3 and co[1].n_batches == 1
        assert eng.coalesced_calls >= 1

    def test_din_reparam_attention_coalesced(self):
        graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                             mlp=(24, 12), item_vocab=128)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            min_bucket=8, reparam_attention=True,
                            hedging=False)
        reqs = [_request(graph, user_in, u, n, seed=u + 1)
                for u, n in ((0, 11), (1, 17), (2, 5))]
        per = [eng.score(r) for r in reqs]
        co = eng.score_coalesced(reqs)
        _assert_bit_identical(per, co)

    def test_single_stage_fallback_coalesced(self):
        """A graph that cannot split (domain-less input in the user closure)
        serves single-stage; coalescing gathers raw user feeds row-wise and
        must still be exact."""
        from repro.graph.ir import GraphBuilder
        b = GraphBuilder()
        u = b.input("u", (6,), "user")
        ctx = b.input("ctx", (4,), None)
        i = b.input("i", (5,), "item")
        uc = b.concat("uc", [u, ctx])
        c = b.concat("c", [uc, i])
        f = b.dense("f", c, 8, activation="relu")
        out = b.dense("out", f, 1)
        b.output(out)
        graph = b.graph
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        eng = ServingEngine(graph, params, mode="mari", max_batch=32,
                            min_bucket=8, hedging=False)
        assert not eng.two_stage
        ks = jax.random.split(jax.random.PRNGKey(1), 12)
        reqs = []
        for uid, n in ((0, 5), (1, 9), (2, 3)):
            reqs.append(ServeRequest(
                uid,
                {"u": jax.random.normal(ks[2 * uid], (1, 6)),
                 "ctx": jax.random.normal(ks[2 * uid + 1], (1, 4))},
                {"i": jax.random.normal(ks[6 + uid], (n, 5))}))
        per = [eng.score(r) for r in reqs]
        co = eng.score_coalesced(reqs)
        _assert_bit_identical(per, co)

    def test_compiled_shape_family_bounded(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=128,
                            hedging=False)
        for n in (10, 50, 100):
            eng.score(_request(graph, user_in, 0, n, seed=n))
        eng.score_coalesced([_request(graph, user_in, u, 20, seed=u)
                             for u in range(3)])
        # U=1 (per-request) and U_pad=4 (3 users) at one bucket each
        assert eng.stage2_compilations <= 2


@pytest.fixture(scope="module")
def din():
    graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                         mlp=(24, 12), item_vocab=128)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


class TestGatherAttention:
    """Gather-aware attention (``gather_attention``): stage 2 consumes the
    decomposed-attention boundary tensors as stacked (U, ...) tables + a
    per-row user index, the gather folded into the contractions
    (``kernels.gather_einsum``), so the (B, L, D, h)-class gathered user
    blocks never materialize — while scores stay exact."""

    def _engine(self, din_fixture, **kw):
        graph, params, _ = din_fixture
        kw.setdefault("hedging", False)
        return ServingEngine(graph, params, mode="mari", max_batch=64,
                             min_bucket=8, reparam_attention=True, **kw)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_coalesced_bit_identical_and_matches_gather_off(
            self, din, use_pallas):
        graph, params, user_in = din
        eng = self._engine(din, gather_attention=True, use_pallas=use_pallas)
        # the attention boundary tensors actually ride the stacked path
        assert {"din_attn::T", "din_attn::u_part",
                "user_seq_emb"} <= eng.lazy_gather_inputs
        reqs = [_request(graph, user_in, u, n, seed=u + 1)
                for u, n in ((0, 11), (1, 17), (2, 5))]
        per = [eng.score(r) for r in reqs]
        co = eng.score_coalesced(reqs)
        _assert_bit_identical(per, co)
        assert eng.coalesced_calls >= 1
        off = self._engine(din, gather_attention=False,
                           use_pallas=use_pallas)
        for c, r in zip(co, off.score_coalesced(reqs)):
            np.testing.assert_allclose(c.scores, r.scores,
                                       rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("mode", ["vani", "uoi", "mari"])
    def test_modes_u1_vs_coalesced_bit_identical(self, din, mode):
        """Flag on in EVERY mode: mari exercises the gather path; vani/uoi
        have no decomposed attention (the flag is a no-op) — U=1 vs
        coalesced must stay exact throughout."""
        graph, params, user_in = din
        eng = ServingEngine(graph, params, mode=mode, max_batch=64,
                            min_bucket=8, reparam_attention=True,
                            gather_attention=True, hedging=False)
        if mode != "mari":
            assert not eng.lazy_gather_inputs
        reqs = [_request(graph, user_in, u, n, seed=u + 7)
                for u, n in ((0, 9), (1, 21), (2, 13))]
        per = [eng.score(r) for r in reqs]
        co = eng.score_coalesced(reqs)
        _assert_bit_identical(per, co)

    def test_sharded_gather_attention_matches_unsharded(self, din):
        """Candidate-axis sharding composes with the stacked-table path:
        (U, ...) tables replicate, the index shards, and no (B, ...) user
        block is ever all-gathered."""
        graph, params, user_in = din
        sh = self._engine(din, gather_attention=True, shard_candidates=True)
        ref = self._engine(din, gather_attention=True)
        reqs = [_request(graph, user_in, u, n, seed=u + 1)
                for u, n in ((0, 21), (1, 12))]
        _assert_bit_identical(ref.score_coalesced(reqs),
                              sh.score_coalesced(reqs))

    def test_out_of_range_user_index_clamps(self, din):
        """Padded-row hazard (the batcher pads ``user_index`` alongside the
        candidate rows): a poisoned index must CLAMP to the last real slot
        — with U=3 and index 7, wrapping would read slot 1 and jax's
        default take would NaN-fill the row; both are caught here."""
        graph, params, user_in = din
        eng = self._engine(din, gather_attention=True)
        reqs = [_request(graph, user_in, u, 4, seed=u + 1) for u in range(3)]
        eng.score_coalesced(reqs)                  # warm the rep cache
        reps = [eng.cache.get((u, 0)) for u in range(3)]
        table = {k: jnp.concatenate([r[k] for r in reps], axis=0)
                 for k in reps[0]}                 # U=3, deliberately non-pow2
        cand = {k: jnp.concatenate(
                    [r.candidate_feeds[k] for r in reqs], axis=0)
                for k in reqs[0].candidate_feeds}  # 12 rows
        good = np.repeat(np.arange(3, dtype=np.int32), 4)
        bad = good.copy()
        bad[-4:] = 7                               # clip->2 (== good), wrap->1
        out_bad = eng._stage2(eng._params_s2, table, jnp.asarray(bad), cand)
        out_good = eng._stage2(eng._params_s2, table, jnp.asarray(good), cand)
        for o in eng.outputs:
            assert np.isfinite(np.asarray(out_bad[o])).all()
            np.testing.assert_array_equal(np.asarray(out_bad[o]),
                                          np.asarray(out_good[o]))


class TestSingleStageCacheBypass:
    """Single-stage serving (vani, or an unsplittable graph) has no stage-1
    outputs to reuse — the rep cache must be a complete no-op there, not
    bookkeeping overhead on the hot path."""

    def test_vani_never_touches_cache(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="vani", max_batch=32,
                            hedging=False)
        assert not eng.two_stage and not eng.cache_user_reps
        for uid in range(3):
            r = eng.score(_request(graph, user_in, uid, 9, seed=uid))
            assert not r.user_cache_hit
        eng.score(_request(graph, user_in, 0, 9, seed=0))   # repeat user
        assert len(eng.cache) == 0
        assert eng.cache.hits == 0 and eng.cache.misses == 0

    def test_two_stage_still_caches(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=32,
                            hedging=False)
        assert eng.cache_user_reps
        eng.score(_request(graph, user_in, 5, 9, seed=5))
        assert eng.score(
            _request(graph, user_in, 5, 9, seed=5)).user_cache_hit


class TestPrecatWeights:
    """Grouped-weight pre-concat at engine build must not change a single
    bit — the streamed operands are identical, only the concat moves out of
    the per-call path."""

    @pytest.mark.parametrize("use_pallas", [False, True])
    @pytest.mark.parametrize("layout", ["group_by_domain", "fragment"])
    def test_bit_identical(self, paper, layout, use_pallas):
        graph, params, user_in = paper
        kw = {layout: True}
        engines = [ServingEngine(graph, params, mode="mari", max_batch=64,
                                 precat_weights=p, use_pallas=use_pallas,
                                 hedging=False, **kw) for p in (False, True)]
        reqs = [_request(graph, user_in, u, n, seed=u + 1)
                for u, n in ((0, 21), (1, 40))]
        r_off = engines[0].score_coalesced(reqs)
        r_on = engines[1].score_coalesced(reqs)
        _assert_bit_identical(r_off, r_on)

    def test_w_cat_present_on_stage2_nodes(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            group_by_domain=True, hedging=False)
        cats = [name for name, p in eng.params.items()
                if isinstance(p, dict) and "w_cat" in p]
        assert cats, "expected pre-concatenated weights on rewritten nodes"
        for name in cats:
            node = eng.split.stage2.nodes[name]
            ws = [eng.params[name][f"w_{lab}"]
                  for lab, _ in node.attrs["groups"] if lab != "user"]
            assert eng.params[name]["w_cat"].shape[0] == sum(
                w.shape[0] for w in ws)


class TestHedging:
    def test_runner_duplicates_straggler_first_result_wins(self):
        calls = []
        lock = threading.Lock()

        def flaky(x):
            with lock:
                calls.append(x)
                first = len(calls) == 1
            if first:
                time.sleep(0.25)           # primary straggles
            return x * 2

        policy = HedgePolicy(min_hedge_ms=20.0)
        runner = HedgedRunner(flaky, policy)
        try:
            # prime the window so the deadline is the 20ms floor
            for _ in range(20):
                policy.observe(1.0)
            result, outcome = runner.run(21)
            assert result == 42
            assert outcome.hedged and outcome.winner == "hedge"
            assert runner.hedges_launched == 1 and runner.hedge_wins == 1
            assert len(calls) == 2         # duplicate actually executed
        finally:
            runner.close()

    def test_fast_primary_not_hedged(self):
        runner = HedgedRunner(lambda x: x + 1, HedgePolicy(min_hedge_ms=500.0))
        try:
            result, outcome = runner.run(1)
            assert result == 2 and not outcome.hedged
            assert outcome.winner == "primary"
        finally:
            runner.close()

    def test_engine_hedges_and_scores_stay_exact(self, paper):
        graph, params, user_in = paper
        # a primed near-zero deadline plus a forced straggle on the primary
        # makes the duplicate deterministic — the staged dispatch path is
        # now fast enough that the primary can beat wait()'s own wake-up,
        # so a pure timing race would flake. The property under test is
        # that duplicate execution never changes scores.
        policy = HedgePolicy(min_hedge_ms=1e-4)
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=True, hedge_policy=policy)
        ref = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=False)
        req = _request(graph, user_in, 0, 30, seed=1)
        eng.score(req)                     # compile (never hedged)
        ref_scores = ref.score(req).scores
        dispatch = eng._hedged.fn

        def straggling(*args):
            time.sleep(0.003)              # >> deadline: always straggles
            return dispatch(*args)

        eng._hedged.fn = straggling
        hedged = 0
        for _ in range(5):
            # re-prime: run() observes its own (slowed) latencies, which
            # would otherwise lift the deadline past the straggle
            policy.lat.clear()
            for _ in range(32):
                policy.observe(1e-4)
            r = eng.score(req)
            hedged += r.hedged
            np.testing.assert_array_equal(r.scores, ref_scores)
        assert hedged >= 1
        eng.close()


class TestShardedStage2:
    def test_single_device_bit_identical(self, paper):
        graph, params, user_in = paper
        ref = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=False)
        sh = ServingEngine(graph, params, mode="mari", max_batch=64,
                           shard_candidates=True, hedging=False)
        reqs = [_request(graph, user_in, u, n, seed=u + 1)
                for u, n in ((0, 21), (1, 40))]
        _assert_bit_identical(ref.score_coalesced(reqs),
                              sh.score_coalesced(reqs))

    def test_multi_device_subprocess(self):
        """Real candidate-axis sharding over 8 forced host devices: sharded
        coalesced scores must match the unsharded engine."""
        script = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np
assert len(jax.devices()) == 8
from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve import ServeRequest, ServingEngine

graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.03))
params = init_graph_params(graph, jax.random.PRNGKey(0))
user_in = {n.name for n in graph.input_nodes()
           if n.attrs.get("domain") == "user"}
def req(uid, n, seed):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(uid, {k: v for k, v in feeds.items() if k in user_in},
                        {k: v for k, v in feeds.items() if k not in user_in})
reqs = [req(0, 21, 1), req(1, 40, 2), req(2, 9, 3)]
ref = ServingEngine(graph, params, mode="mari", max_batch=64, min_bucket=16,
                    hedging=False)
sh = ServingEngine(graph, params, mode="mari", max_batch=64, min_bucket=16,
                   shard_candidates=True, hedging=False)
assert sh.mesh.devices.size == 8, sh.mesh
a = ref.score_coalesced(reqs)
b = sh.score_coalesced(reqs)
for x, y in zip(a, b):
    np.testing.assert_allclose(x.scores, y.scores, rtol=1e-6, atol=1e-6)
print("SHARDED-OK")
"""
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        p = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=600)
        assert p.returncode == 0, p.stderr[-3000:]
        assert "SHARDED-OK" in p.stdout


class TestBatcherRuntime:
    def test_burst_coalesces_into_few_batches(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=256,
                            hedging=False)
        reqs = [_request(graph, user_in, u, 20, seed=u) for u in range(6)]
        eng.score(reqs[0])                       # compile before timing paths
        # group closes at max_coalesce, not on linger expiry — deterministic
        # even when the submitting thread stalls under suite load
        with CoalescingBatcher(eng, linger_ms=2000.0, max_coalesce=3) as b:
            results = b.score_many(reqs)
        assert all(r.scores.shape[0] == 20 for r in results)
        assert b.batches < len(reqs)             # actually coalesced
        assert b.requests == len(reqs)

    def test_submit_returns_future(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=False)
        with CoalescingBatcher(eng, linger_ms=1.0) as b:
            fut = b.submit(_request(graph, user_in, 0, 10, seed=1))
            res = fut.result(timeout=120)
        assert res.scores.shape[0] == 10

    def test_error_propagates_to_waiters(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=False)
        bad = ServeRequest(0, {}, {"item_feats": np.zeros((4, 3))})
        with CoalescingBatcher(eng, linger_ms=1.0) as b:
            fut = b.submit(bad)
            with pytest.raises(Exception):
                fut.result(timeout=120)

    def test_closed_batcher_rejects(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                            hedging=False)
        b = CoalescingBatcher(eng, auto_start=False)
        with pytest.raises(RuntimeError):
            b.submit(_request(graph, user_in, 0, 10, seed=1))


class _GatedSpyEngine:
    """Engine stand-in recording dispatch order; the FIRST group blocks
    until released, so requests submitted meanwhile pile up in the queue
    and their pop order becomes observable."""
    max_batch = 1 << 30

    def __init__(self):
        self.groups: list[list[int]] = []
        self.gate = threading.Event()

    def score_coalesced(self, reqs):
        self.groups.append([r.user_id for r in reqs])
        if len(self.groups) == 1:
            self.gate.wait(timeout=30)
        return [object()] * len(reqs)


class TestDeadlineScheduling:
    def test_deadline_request_jumps_queued_best_effort(self):
        """A deadline-tagged request submitted AFTER older best-effort
        ones is dispatched before them (priority pop, FIFO within class)."""
        spy = _GatedSpyEngine()
        req = lambda uid: ServeRequest(uid, {}, {"x": np.zeros((4, 2))})
        b = CoalescingBatcher(spy, linger_ms=0.0, max_coalesce=1)
        try:
            blocker = b.submit(req(99))
            for _ in range(300):             # worker holds group 1 open
                if spy.groups:
                    break
                time.sleep(0.01)
            assert spy.groups == [[99]]
            futs = [b.submit(req(uid)) for uid in (1, 2, 3)]
            futs.append(b.submit(req(9), slo="deadline"))
            spy.gate.set()
            for f in [blocker] + futs:
                f.result(timeout=30)
        finally:
            spy.gate.set()
            b.close()
        # deadline request 9 overtook the older best-effort 1, 2, 3
        assert spy.groups == [[99], [9], [1], [2], [3]]
        assert b.deadline_requests == 1

    def test_deadline_ms_implies_class_and_caps_linger(self):
        spy = _GatedSpyEngine()
        spy.gate.set()                       # never hold groups open
        b = CoalescingBatcher(spy, linger_ms=100.0, auto_start=False)
        from repro.serve.batcher import _PRIO, _Item, SLO_DEADLINE
        now = time.perf_counter()
        # deadline class shrinks the linger window to linger * frac
        it = _Item(prio=_PRIO[SLO_DEADLINE], seq=1)
        assert b._linger_until(it, now) - now == pytest.approx(
            0.1 * b.deadline_linger_frac, rel=1e-6)
        # a near-expiry deadline caps it further
        it2 = _Item(prio=_PRIO[SLO_DEADLINE], seq=2, deadline_at=now + 0.001)
        assert b._linger_until(it2, now) - now == pytest.approx(0.001,
                                                                rel=1e-6)
        # best-effort keeps the full linger
        it3 = _Item(prio=1, seq=3)
        assert b._linger_until(it3, now) - now == pytest.approx(0.1,
                                                                rel=1e-6)

    def test_bad_slo_rejected(self):
        spy = _GatedSpyEngine()
        spy.gate.set()
        b = CoalescingBatcher(spy, linger_ms=0.0)
        try:
            with pytest.raises(ValueError, match="SLO"):
                b.submit(ServeRequest(0, {}, {"x": np.zeros((2, 2))}),
                         slo="gold-plated")
        finally:
            b.close()


class TestDeviceRepStore:
    """The slot-allocated device tier in isolation: donated row writes,
    LRU steals honoring protection, drop-recycling, byte accounting."""

    @staticmethod
    def _reps(val, d=4):
        return {"a": jnp.full((1, d), float(val)),
                "b": jnp.full((1, 2, 3), float(val) + 0.5)}

    def test_slot_lifecycle_and_row_contents(self):
        st = DeviceRepStore(capacity=3)
        slots = st.ensure_rows([(1, 0, self._reps(1)),
                                (2, 0, self._reps(2))])
        assert slots == [0, 1] and st.writes == 2 and len(st) == 2
        # live (user, version): LRU bump, no write
        assert st.ensure_rows([(1, 0, self._reps(99))]) == [0]
        assert st.writes == 2 and st.hits == 1
        # the skipped write means the table still holds user 1's ORIGINAL
        # row — same-version reps are immutable by cache contract
        np.testing.assert_array_equal(
            np.asarray(st.tables["a"][0]), np.full((4,), 1.0))
        np.testing.assert_array_equal(
            np.asarray(st.tables["b"][1]), np.full((2, 3), 2.5))
        # version supersede rewrites the user's OWN slot in place
        assert st.ensure_rows([(1, 1, self._reps(7))]) == [0]
        assert st.writes == 3 and len(st) == 2
        np.testing.assert_array_equal(
            np.asarray(st.tables["a"][0]), np.full((4,), 7.0))

    def test_lru_steal_respects_protection(self):
        st = DeviceRepStore(capacity=2)
        st.ensure_rows([(1, 0, self._reps(1)), (2, 0, self._reps(2))])
        # user 1 is LRU but protected -> user 2's slot is stolen instead
        slots = st.ensure_rows([(3, 0, self._reps(3))], protect=[1])
        assert slots == [1] and st.recycles == 1
        assert st.slot_of(2) is None and st.slot_of(1) == 0
        # everything protected and no free slot -> overflow, not a steal
        slots = st.ensure_rows([(4, 0, self._reps(4))], protect=[1, 3])
        assert slots == [None] and st.overflows == 1
        assert len(st) == 2

    def test_drop_recycles_slot_without_touching_rows(self):
        st = DeviceRepStore(capacity=2)
        st.ensure_rows([(1, 0, self._reps(1)), (2, 0, self._reps(2))])
        st.drop(1)
        assert st.drops == 1 and len(st) == 1 and st.slot_of(1) is None
        # dead row contents are untouched (never zeroed) ...
        np.testing.assert_array_equal(
            np.asarray(st.tables["a"][0]), np.full((4,), 1.0))
        # ... and the freed slot integer is recycled by the next user
        assert st.ensure_rows([(5, 0, self._reps(5))]) == [0]
        np.testing.assert_array_equal(
            np.asarray(st.tables["a"][0]), np.full((4,), 5.0))

    def test_spec_validation_and_stats(self):
        st = DeviceRepStore(capacity=2, boundary_specs={"a": (4,),
                                                        "b": (2, 3)})
        with pytest.raises(ValueError, match="shape"):
            st.ensure_rows([(1, 0, {"a": jnp.zeros((1, 5)),
                                    "b": jnp.zeros((1, 2, 3))})])
        st.ensure_rows([(1, 0, self._reps(1))])
        s = st.stats()
        assert s["capacity"] == 2 and s["resident"] == 1
        assert s["free_slots"] == 1 and s["writes"] == 1
        # bytes account the FULL persistent tables, not one row
        expect = 2 * (4 + 2 * 3) * 4
        assert s["bytes"] == expect
        assert s["boundary_bytes"] == {"a": 2 * 4 * 4, "b": 2 * 6 * 4}


class TestDeviceResidentTier:
    """CachePlan.device_resident end to end: persistent device tables +
    donated bucket buffers must be bit-identical to the re-stacking path,
    across engine paradigms, coalesced multi-user packs, eviction churn,
    scoped invalidation, and dead/out-of-range slots."""

    PRESETS = {"vani": "vanilla", "uoi": "uoi", "mari": "paper"}

    def _plan(self, preset, **evolve):
        base = dict(batch__max_batch=64, batch__min_bucket=8,
                    batch__hedging=False)
        base.update(evolve)
        return ServePlan.preset(preset).evolve(**base)

    @pytest.mark.parametrize("mode", ["vani", "uoi", "mari"])
    def test_bit_identical_to_restacking(self, paper, mode):
        graph, params, user_in = paper
        ref = ServingEngine(graph, params, plan=self._plan(
            self.PRESETS[mode]))
        dev = ServingEngine(graph, params, plan=self._plan(
            self.PRESETS[mode], cache__device_resident=True))
        reqs = [_request(graph, user_in, u, n, seed=u + 7)
                for u, n in ((0, 21), (1, 40), (2, 12))]
        per_ref = [ref.score(r) for r in reqs]
        per_dev = [dev.score(r) for r in reqs]
        _assert_bit_identical(per_ref, per_dev)
        # coalesced multi-user pack over the SAME persistent tables (all
        # three users already resident -> zero new row writes)
        _assert_bit_identical(per_ref, dev.score_coalesced(reqs))
        if dev.two_stage:
            assert dev.device_resident and dev.device_store is not None
            assert dev.device_store.writes == 3
            assert len(dev.device_store) == 3
        else:
            # single-stage: no reps to keep resident — runtime gates the
            # tier off even though the plan asked for it
            assert not dev.device_resident and dev.device_store is None
        ref.close()
        dev.close()

    def test_eviction_churn_keeps_scores_exact(self, paper):
        """Host-tier LRU evictions recycle device slots via the removal
        listener; scores through the churn stay exact."""
        graph, params, user_in = paper
        ref = ServingEngine(graph, params, plan=self._plan("paper"))
        dev = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True,
            cache__max_cached_users=2, cache__device_slots=2))
        reqs = [_request(graph, user_in, u, 12, seed=u) for u in range(5)]
        for r in reqs:                       # cold sweep: 3 evictions
            _assert_bit_identical([ref.score(r)], [dev.score(r)])
        st = dev.device_store.stats()
        assert st["resident"] <= 2 and st["drops"] >= 3
        assert dev.cache.evictions >= 3
        # users 3,4 are live; re-scoring is a hit with NO new write,
        # user 0 was evicted and re-runs stage 1 into a recycled slot
        writes = st["writes"]
        _assert_bit_identical([ref.score(reqs[4])], [dev.score(reqs[4])])
        assert dev.device_store.writes == writes
        _assert_bit_identical([ref.score(reqs[0])], [dev.score(reqs[0])])
        assert dev.device_store.writes == writes + 1
        ref.close()
        dev.close()

    def test_scoped_invalidation_frees_slot(self, paper):
        """Engine-level invalidation under a cache scope reaches the
        device tier through the scoped listener key."""
        graph, params, user_in = paper
        dev = ServingEngine(graph, params,
                            plan=self._plan("paper",
                                            cache__device_resident=True),
                            cache=UserRepCache(max_users=8),
                            cache_scope="sA")
        r = _request(graph, user_in, 5, 12, seed=5)
        first = dev.score(r)
        assert dev.device_store.slot_of(("sA", 5)) is not None
        dev.invalidate_user(5)
        assert dev.device_store.slot_of(("sA", 5)) is None
        assert dev.device_store.drops == 1 and len(dev.device_store) == 0
        again = dev.score(r)                 # re-runs stage 1, re-writes
        assert not again.user_cache_hit
        assert dev.device_store.writes == 2
        np.testing.assert_array_equal(first.scores, again.scores)
        dev.close()

    def test_dead_and_out_of_range_slots_clamp(self, paper):
        """The safety contract of never zeroing dead rows: unreferenced
        slots can't perturb live rows, and an out-of-range index clamps
        (mode="clip") instead of faulting."""
        graph, params, user_in = paper
        dev = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True, cache__device_slots=4))
        r1 = _request(graph, user_in, 1, 16, seed=1)
        r2 = _request(graph, user_in, 2, 16, seed=2)
        s1, s2 = dev.score(r1), dev.score(r2)
        dev.invalidate_user(1)               # slot 0 is now dead
        s2b = dev.score(r2)                  # reads table with a dead row
        np.testing.assert_array_equal(s2.scores, s2b.scores)
        # direct stage-2 probe: indices past capacity clamp to the last
        # slot; negative indices clamp to slot 0. The stage-2 executable
        # donates uidx+cand, so every call gets fresh arrays.
        table = dev.device_store.tables
        cap = dev.device_store.capacity
        chunk = {k: np.asarray(v)
                 for k, v in r2.candidate_feeds.items()}
        mk_cand = lambda: {k: jnp.array(v) for k, v in chunk.items()}
        run = lambda idx: {
            k: np.asarray(v) for k, v in dev._stage2(
                dev._params_s2, table,
                jnp.array(np.full((16,), idx, np.int32)),
                mk_cand()).items()}
        out_hi, out_last = run(cap + 3), run(cap - 1)
        out_neg, out_zero = run(-5), run(0)
        for o in dev.outputs:
            np.testing.assert_array_equal(out_hi[o], out_last[o])
            np.testing.assert_array_equal(out_neg[o], out_zero[o])
        dev.close()

    def test_mixed_version_same_user_falls_back(self, paper):
        """One coalesced call carrying the SAME user under two feature
        versions: the device store keeps one slot per user, so resolving
        the second version would rewrite the slot the first version's
        rows read. Every pack touching that user must fall back to
        re-stacking — both versions packed together and split across
        packs — and stay bit-identical to the re-stacking engine."""
        graph, params, user_in = paper
        mk = lambda: [  # (user 1, v0), (user 1, v1), (user 2, v0)
            _request(graph, user_in, 1, 12, seed=11, version=0),
            _request(graph, user_in, 1, 12, seed=12, version=1),
            _request(graph, user_in, 2, 12, seed=13)]
        ref = ServingEngine(graph, params, plan=self._plan("paper"))
        # single pack: both versions' slot keys land in one ensure_rows
        one = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True))
        _assert_bit_identical(ref.score_coalesced(mk()),
                              one.score_coalesced(mk()))
        # split packs: a later pack's barrier write must not clobber a
        # slot an earlier pack references
        split = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True,
            batch__max_users_per_batch=1))
        _assert_bit_identical(ref.score_coalesced(mk()),
                              split.score_coalesced(mk()))
        # a version-clean follow-up call goes device-resident again
        follow = _request(graph, user_in, 3, 12, seed=14)
        _assert_bit_identical([ref.score(follow)], [one.score(follow)])
        assert one.device_store.writes >= 1
        ref.close()
        one.close()
        split.close()

    def test_feed_signature_drift_fails_fast(self, paper):
        """Staging buffers are shaped from the first request; a later
        request with a drifting candidate dtype must raise before any
        launch instead of being silently cast by the buffer fill."""
        graph, params, user_in = paper
        dev = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True))
        dev.score(_request(graph, user_in, 1, 8, seed=1))
        drifted = _request(graph, user_in, 2, 8, seed=2)
        k = next(iter(drifted.candidate_feeds))
        drifted.candidate_feeds = {
            **drifted.candidate_feeds,
            k: np.asarray(drifted.candidate_feeds[k], np.float64)}
        with pytest.raises(ValueError, match="signature drifted"):
            dev.score(drifted)
        dev.close()

    def test_restack_fallback_on_slot_overflow(self, paper):
        """More users in one coalesced call than device slots: the
        overflowing pack falls back to re-stacking, bit-identically."""
        graph, params, user_in = paper
        ref = ServingEngine(graph, params, plan=self._plan("paper"))
        dev = ServingEngine(graph, params, plan=self._plan(
            "paper", cache__device_resident=True, cache__device_slots=2,
            batch__max_users_per_batch=4))
        reqs = [_request(graph, user_in, u, 8, seed=u + 3)
                for u in range(4)]
        _assert_bit_identical(ref.score_coalesced(reqs),
                              dev.score_coalesced(reqs))
        assert dev.device_store.overflows >= 1
        ref.close()
        dev.close()
