"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Shapes sweep odd/aligned sizes and dtypes per the kernel contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (din_attention, dot_interaction, embedding_bag,
                           gather_einsum, gather_einsum_ref,
                           mari_matmul_fused, mari_matmul_fused_groups)
from repro.kernels.gather_einsum.kernel import parse_spec
from repro.kernels.din_attention.ref import din_attention_ref
from repro.kernels.dot_interaction.ref import dot_interaction_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.mari_matmul.ref import (mari_matmul_groups_ref,
                                           mari_matmul_ref)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


class TestMariMatmul:
    @pytest.mark.parametrize("B,Du,Dr,d", [
        (1, 8, 8, 8), (16, 100, 50, 64), (100, 4000 // 8, 1000 // 8, 512 // 8),
        (257, 33, 129, 65), (512, 128, 256, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, Du, Dr, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(B + Du), 5)
        xu = jax.random.normal(ks[0], (1, Du), dtype)
        xr = jax.random.normal(ks[1], (B, Dr), dtype)
        wu = jax.random.normal(ks[2], (Du, d), dtype)
        wr = jax.random.normal(ks[3], (Dr, d), dtype)
        b = jax.random.normal(ks[4], (d,), dtype)
        out = mari_matmul_fused(xu, xr, wu, wr, b)
        ref = mari_matmul_ref(xu, xr, wu, wr, b)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   **_tol(dtype))

    def test_no_bias(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        out = mari_matmul_fused(jax.random.normal(ks[0], (1, 16)),
                                jax.random.normal(ks[1], (32, 24)),
                                jax.random.normal(ks[2], (16, 8)),
                                jax.random.normal(ks[3], (24, 8)))
        assert out.shape == (32, 8) and np.isfinite(out).all()

    @pytest.mark.parametrize("activation", ["relu", "sigmoid", "gelu", "tanh"])
    @pytest.mark.parametrize("B,Du,Dr,d", [(64, 48, 96, 32), (257, 33, 129, 65)])
    def test_activation_epilogue(self, activation, B, Du, Dr, d):
        """Bias + activation fused into the kernel epilogue (non-aligned
        shapes included) match the jnp oracle."""
        ks = jax.random.split(jax.random.PRNGKey(d), 5)
        xu = jax.random.normal(ks[0], (1, Du))
        xr = jax.random.normal(ks[1], (B, Dr))
        wu = jax.random.normal(ks[2], (Du, d))
        wr = jax.random.normal(ks[3], (Dr, d))
        b = jax.random.normal(ks[4], (d,))
        out = mari_matmul_fused(xu, xr, wu, wr, b, activation=activation)
        ref = mari_matmul_groups_ref([(xu, wu), (xr, wr)], b,
                                     activation=activation)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestMariMatmulGroups:
    """Multi-group / fragmented variant: Σ_g x_g W_g with batch-1 (user)
    operands folded into the accumulator-init row."""

    def _parts(self, key, layout, B, d):
        parts = []
        for j, (dom, w_) in enumerate(layout):
            x = jax.random.normal(jax.random.fold_in(key, j),
                                  (1 if dom == "u" else B, w_))
            w = jax.random.normal(jax.random.fold_in(key, 100 + j), (w_, d))
            parts.append((x, w))
        return parts

    @pytest.mark.parametrize("activation", ["identity", "relu", "sigmoid"])
    def test_fragmented_interleaved(self, activation):
        B, d = 53, 17   # deliberately non-aligned
        layout = [("u", 5), ("i", 9), ("u", 13), ("i", 3), ("u", 4)]
        parts = self._parts(jax.random.PRNGKey(1), layout, B, d)
        b = jax.random.normal(jax.random.PRNGKey(2), (d,))
        out = mari_matmul_fused_groups(parts, b, activation=activation)
        ref = mari_matmul_groups_ref(parts, b, activation=activation)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_matches_vanilla_tiled(self):
        """Groups form == vanilla (B, D) @ (D, d) over tiled features."""
        from repro.core.mari import matmul_vanilla
        B, d = 31, 8
        layout = [("u", 6), ("i", 4), ("u", 5)]
        parts = self._parts(jax.random.PRNGKey(3), layout, B, d)
        tiled = jnp.concatenate(
            [jnp.broadcast_to(x, (B,) + x.shape[1:]) for x, _ in parts], -1)
        w = jnp.concatenate([w for _, w in parts], 0)
        out = mari_matmul_fused_groups(parts)
        np.testing.assert_allclose(out, matmul_vanilla(tiled, w),
                                   rtol=2e-4, atol=2e-4)

    def test_acc0_row(self):
        """Precomputed (1, d) partial (two-stage serving) seeds the
        accumulator."""
        B, d = 16, 8
        parts = self._parts(jax.random.PRNGKey(4), [("i", 7)], B, d)
        acc0 = jax.random.normal(jax.random.PRNGKey(5), (1, d))
        out = mari_matmul_fused_groups(parts, acc0=acc0, activation="relu")
        ref = mari_matmul_groups_ref(parts, acc0=acc0, activation="relu")
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_batch_one_all_user(self):
        parts = self._parts(jax.random.PRNGKey(6), [("u", 5), ("u", 3)], 1, 4)
        out = mari_matmul_fused_groups(parts)
        ref = mari_matmul_groups_ref(parts)
        assert out.shape == (1, 4)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestExecutorPallasPath:
    """kernel == _run_mari_dense (jnp) == vanilla dense graph, with bias,
    activation, and non-aligned shapes."""

    def _graph(self, activation="relu", use_bias=True):
        from repro.graph.ir import GraphBuilder
        b = GraphBuilder()
        u = b.input("u", (19,), "user")
        i = b.input("i", (11,), "item")
        x = b.input("x", (6,), "cross")
        c = b.concat("c", [u, i, x])
        f1 = b.dense("f1", c, 21, activation=activation, use_bias=use_bias)
        f2 = b.dense("f2", f1, 1)
        b.output(f2)
        return b.graph

    @pytest.mark.parametrize("activation", ["relu", "sigmoid"])
    @pytest.mark.parametrize("use_bias", [True, False])
    @pytest.mark.parametrize("fragment", [False, True])
    def test_three_way_equivalence(self, activation, use_bias, fragment):
        from repro.core import apply_mari
        from repro.graph.executor import Executor, init_graph_params
        g = self._graph(activation, use_bias)
        params = init_graph_params(g, jax.random.PRNGKey(0))
        feeds = {
            "u": jax.random.normal(jax.random.PRNGKey(1), (1, 19)),
            "i": jax.random.normal(jax.random.PRNGKey(2), (13, 11)),
            "x": jax.random.normal(jax.random.PRNGKey(3), (13, 6)),
        }
        ref = Executor(g, "vani").run(params, feeds)["f2"]   # vanilla dense
        mg, mp, _ = apply_mari(g, params, fragment=fragment)
        out_jnp = Executor(mg, "uoi").run(mp, feeds)["f2"]
        out_pal = Executor(mg, "uoi", use_pallas=True).run(mp, feeds)["f2"]
        np.testing.assert_allclose(out_jnp, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out_pal, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out_pal, out_jnp, rtol=1e-4, atol=1e-4)


class TestGatherEinsum:
    """Gather-aware einsum family (attention-side analogue of the
    mari_matmul kernel gather): the stacked (U, ...) table is indexed by
    ``user_index`` inside the contraction; the gathered (B, ...) operand
    never materializes. Must match jnp.take(mode="clip") + einsum."""

    SPECS = ("bd,uldh->blh", "bl,uld->bd", "blh,uh->bl")

    def _args(self, spec, sizes, seed=0, idx_high=None):
        x_sub, t_sub, _, row_spec = parse_spec(spec)
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(ks[0], tuple(sizes[c] for c in x_sub))
        t = jax.random.normal(ks[1], tuple(sizes[c] for c in t_sub))
        idx = jax.random.randint(ks[2], (sizes["b"],), 0,
                                 idx_high or sizes["u"])
        return x, t, idx, row_spec

    @pytest.mark.parametrize("U", [1, 2, 3, 5, 8])   # non-pow2 included
    @pytest.mark.parametrize("spec", SPECS)
    def test_matches_take_einsum(self, spec, U):
        sizes = dict(u=U, b=13, l=7, d=6, h=5)
        x, t, idx, row_spec = self._args(spec, sizes, seed=U)
        out = gather_einsum(spec, x, t, idx, interpret=True)
        expected = jnp.einsum(row_spec, x,
                              jnp.take(t, idx, axis=0, mode="clip"))
        np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(out, gather_einsum_ref(spec, x, t, idx),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("B,L,D,h", [
        (1, 3, 4, 2), (53, 12, 9, 17), (300, 33, 18, 16),
    ])
    @pytest.mark.parametrize("spec", SPECS)
    def test_shape_sweep(self, spec, B, L, D, h):
        """Odd / tile-crossing shapes (B above and below the 256-row block,
        non-aligned feature dims)."""
        sizes = dict(u=3, b=B, l=L, d=D, h=h)
        x, t, idx, _ = self._args(spec, sizes, seed=B + L)
        out = gather_einsum(spec, x, t, idx, interpret=True)
        ref = gather_einsum_ref(spec, x, t, idx)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("spec", SPECS)
    def test_u1_rows_bit_identical_to_coalesced(self, spec):
        """Row b depends only on (x[b], table[idx[b]]): slicing one user's
        table down to U=1 reproduces that user's rows BIT-identically —
        the invariant that makes a single request the degenerate case of
        the coalesced batch."""
        sizes = dict(u=4, b=24, l=5, d=6, h=3)
        x, t, idx, _ = self._args(spec, sizes, seed=11)
        out = gather_einsum(spec, x, t, idx, interpret=True)
        for u in range(sizes["u"]):
            rows = np.asarray(idx) == u
            if not rows.any():
                continue
            out_u1 = gather_einsum(spec, x, t[u:u + 1],
                                   jnp.zeros_like(idx), interpret=True)
            np.testing.assert_array_equal(np.asarray(out)[rows],
                                          np.asarray(out_u1)[rows])

    @pytest.mark.parametrize("spec", SPECS)
    def test_out_of_range_index_clamps(self, spec):
        """Padded-row hazard: an out-of-range index must read the last
        real slot (clip), never wrap (numpy) or NaN-fill (jax default)."""
        sizes = dict(u=3, b=9, l=4, d=5, h=2)
        x, t, idx, _ = self._args(spec, sizes, seed=7, idx_high=9)
        assert (np.asarray(idx) >= sizes["u"]).any()   # seed chosen to OOB
        out = gather_einsum(spec, x, t, idx, interpret=True)
        assert np.isfinite(np.asarray(out)).all()
        clamped = jnp.clip(idx, 0, sizes["u"] - 1)
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(gather_einsum(spec, x, t, clamped, interpret=True)))

    @pytest.mark.parametrize("bad", [
        "ud,bld->bl",        # operands swapped
        "bd,uldh->ulh",      # output keyed by user, not row
        "bdd,ud->bd",        # repeated dim
        "bd,uldh,bl->blh",   # three operands
        "bd,uldh->blz",      # output dim from nowhere
    ])
    def test_bad_specs_raise(self, bad):
        with pytest.raises(ValueError):
            parse_spec(bad)


class TestEmbeddingBag:
    @pytest.mark.parametrize("V,D,S,nnz", [
        (16, 8, 4, 20), (100, 32, 17, 123), (1000, 128, 64, 512),
    ])
    @pytest.mark.parametrize("combiner", ["sum", "mean"])
    def test_sweep(self, V, D, S, nnz, combiner):
        ks = jax.random.split(jax.random.PRNGKey(V + nnz), 3)
        table = jax.random.normal(ks[0], (V, D))
        ids = jax.random.randint(ks[1], (nnz,), 0, V)
        segs = jax.random.randint(ks[2], (nnz,), 0, S)
        out = embedding_bag(table, ids, segs, num_segments=S, combiner=combiner)
        ref = embedding_bag_ref(table, ids, segs, S, combiner)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_empty_segments_zero(self):
        table = jnp.ones((8, 4))
        ids = jnp.array([0, 1], jnp.int32)
        segs = jnp.array([2, 2], jnp.int32)   # segments 0,1,3 empty
        out = embedding_bag(table, ids, segs, num_segments=4)
        np.testing.assert_array_equal(out[0], 0)
        np.testing.assert_array_equal(out[1], 0)
        np.testing.assert_array_equal(out[3], 0)
        np.testing.assert_array_equal(out[2], 2 * jnp.ones(4))

    def test_unsorted_input(self):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        table = jax.random.normal(ks[0], (50, 16))
        ids = jax.random.randint(ks[1], (64,), 0, 50)
        segs = jax.random.permutation(
            ks[2], jnp.repeat(jnp.arange(8), 8))
        out = embedding_bag(table, ids, segs, num_segments=8)
        ref = embedding_bag_ref(table, ids, segs, 8)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


class TestDotInteraction:
    @pytest.mark.parametrize("B,F,D", [(8, 4, 8), (37, 27, 16), (128, 27, 128)])
    @pytest.mark.parametrize("keep_self", [False, True])
    def test_sweep(self, B, F, D, keep_self):
        x = jax.random.normal(jax.random.PRNGKey(B + F), (B, F, D))
        out = dot_interaction(x, keep_self=keep_self)
        ref = dot_interaction_ref(x, keep_self=keep_self)
        assert out.shape[1] == (F * (F + 1) // 2 if keep_self
                                else F * (F - 1) // 2)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestDinAttention:
    @pytest.mark.parametrize("B,L,D", [(4, 5, 8), (33, 20, 18), (128, 100, 18)])
    def test_sweep(self, B, L, D):
        h1, h2 = 16, 8
        ks = jax.random.split(jax.random.PRNGKey(B + L), 6)
        q = jax.random.normal(ks[0], (B, D))
        keys = jax.random.normal(ks[1], (L, D))
        mask = jax.random.bernoulli(ks[2], 0.9, (L,)).at[0].set(True)
        w1 = jax.random.normal(ks[3], (4 * D, h1)) * 0.2
        w2 = jax.random.normal(ks[4], (h1, h2)) * 0.2
        w3 = jax.random.normal(ks[5], (h2, 1)) * 0.2
        b1, b2, b3 = jnp.zeros(h1), jnp.zeros(h2), jnp.zeros(1)
        out = din_attention(q, keys, mask, w1, b1, w2, b2, w3, b3)
        ref = din_attention_ref(q, keys, mask, w1, b1, w2, b2, w3, b3)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_matches_nn_target_attention(self):
        """Kernel agrees with the graph executor's target_attention op."""
        from repro.nn.attention import target_attention
        from repro.nn.layers import dense_apply
        B, L, D, h1, h2 = 9, 7, 6, 12, 5
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        q = jax.random.normal(ks[0], (B, D))
        keys = jax.random.normal(ks[1], (1, L, D))
        mask = jnp.ones((1, L), bool)
        p = {"layer_0": {"w": jax.random.normal(ks[2], (4 * D, h1)) * 0.3,
                         "b": jnp.zeros(h1)},
             "layer_1": {"w": jax.random.normal(ks[3], (h1, h2)) * 0.3,
                         "b": jnp.zeros(h2)},
             "layer_2": {"w": jax.random.normal(ks[4], (h2, 1)) * 0.3,
                         "b": jnp.zeros(1)}}

        def mlp(x):
            x = jax.nn.relu(dense_apply(p["layer_0"], x))
            x = jax.nn.relu(dense_apply(p["layer_1"], x))
            return dense_apply(p["layer_2"], x)

        ref = target_attention(q, keys, mask, mlp)
        out = din_attention(q, keys[0], mask[0],
                            p["layer_0"]["w"], p["layer_0"]["b"],
                            p["layer_1"]["w"], p["layer_1"]["b"],
                            p["layer_2"]["w"], p["layer_2"]["b"])
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
