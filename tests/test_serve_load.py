"""Continuous dispatch loop + SLO admission control + close-drain.

Three contracts of the PR-7 serving loop:

* the continuous loop changes WHEN groups launch, never WHAT they
  compute — scores stay bit-identical to per-request scoring and to the
  lockstep batcher, including across the copy-on-write generation forks
  cold users force mid-stream;
* admission control sheds/degrades best_effort work before deadline work
  under overload, and a shed future fails FAST with a typed
  ``AdmissionError`` — it never hangs;
* ``close()`` drains: every admitted request is scored (or failed with
  the scoring error), never silently abandoned.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve import (AdmissionError, BatcherClosedError,
                         CoalescingBatcher, RankingService, ServePlan,
                         ServeRequest, ServeResult, ServingEngine)


@pytest.fixture(scope="module")
def paper():
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n, seed, version=0):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


def _plan(**over):
    base = dict(batch__max_batch=128, batch__hedging=False,
                cache__device_resident=True, cache__device_slots=8)
    base.update(over)
    return ServePlan().evolve(**base)


class TestContinuousLoopIdentity:
    """Bit-identity of the continuous loop vs per-request and lockstep."""

    def _mixed_stream(self, graph, user_in):
        # repeat users (all-resident overlap path) interleaved with cold
        # users (each forces a generation fork before its table write)
        reqs = []
        for i in range(12):
            uid = i % 3 if i % 2 == 0 else 100 + i    # hot trio + cold tail
            reqs.append(_request(graph, user_in, uid, 10 + (i % 4) * 3,
                                 seed=i))
        return reqs

    def test_continuous_matches_per_request(self, paper):
        graph, params, user_in = paper
        reqs = self._mixed_stream(graph, user_in)
        ref_eng = ServingEngine(graph, params, plan=_plan())
        ref = [ref_eng.score(r) for r in reqs]

        eng = ServingEngine(graph, params, plan=_plan())
        with CoalescingBatcher(eng, linger_ms=20.0, max_coalesce=4,
                               continuous=True, max_inflight=2) as b:
            futs = [b.submit(r) for r in reqs]
            out = [f.result(timeout=120) for f in futs]
        for p, c in zip(ref, out):
            assert np.array_equal(p.scores, c.scores)
        assert b.batches >= 1 and b.requests == len(reqs)

    def test_continuous_matches_lockstep(self, paper):
        graph, params, user_in = paper
        reqs = self._mixed_stream(graph, user_in)
        outs = {}
        for continuous in (False, True):
            eng = ServingEngine(graph, params, plan=_plan())
            with CoalescingBatcher(eng, linger_ms=20.0, max_coalesce=4,
                                   continuous=continuous) as b:
                futs = [b.submit(r) for r in reqs]
                outs[continuous] = [f.result(timeout=120) for f in futs]
        for lock, cont in zip(outs[False], outs[True]):
            assert np.array_equal(lock.scores, cont.scores)

    def test_two_phase_api_overlap_and_fork(self, paper):
        """Direct engine contract: an all-resident call overlaps freely;
        a call needing a table write forks the table generation (copy-on-
        write) instead of draining — both stay bit-identical."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_plan())
        ref_eng = ServingEngine(graph, params, plan=_plan())

        warm = [_request(graph, user_in, u, 12, seed=u) for u in (0, 1)]
        eng.score_coalesced(warm)               # users 0, 1 now resident
        ref_eng.score_coalesced(warm)

        again = [_request(graph, user_in, u, 9, seed=10 + u) for u in (0, 1)]
        cold = [_request(graph, user_in, 7, 9, seed=20)]    # needs a write
        h1 = eng.begin_coalesced(again)
        assert eng.pipeline_forks == 0
        h2 = eng.begin_coalesced(cold)          # forks the generation —
        assert eng.pipeline_forks == 1          # h1 stays in flight
        assert eng.device_store.stats()["forks"] == 1
        r2 = eng.collect(h2)                    # out-of-order collect is fine
        r1 = eng.collect(h1)
        for got, ref in zip(r1 + r2,
                            ref_eng.score_coalesced(again)
                            + ref_eng.score_coalesced(cold)):
            assert np.array_equal(got.scores, ref.scores)

        with pytest.raises(RuntimeError, match="not in flight"):
            eng.collect(h1)                     # each handle collects once

    def test_overlap_launch_all_resident(self, paper):
        """Two all-resident calls in flight at once never fork (hits read
        the shared table generation — no copy, no drain)."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_plan())
        eng.score_coalesced([_request(graph, user_in, u, 8, seed=u)
                             for u in (0, 1)])
        h1 = eng.begin_coalesced([_request(graph, user_in, 0, 8, seed=5)])
        h2 = eng.begin_coalesced([_request(graph, user_in, 1, 8, seed=6)])
        eng.collect(h1)
        eng.collect(h2)
        assert eng.pipeline_forks == 0

    def test_overlapped_transfer_buffers_are_private(self, paper):
        """Regression: a pack's host->device transfer copy executes
        asynchronously on the device stream, behind every in-flight
        executable — so a later same-bucket pack must never reuse the
        earlier pack's host buffer. A shared per-bucket staging buffer
        let the second call's refill win that race and silently score
        the first call's request against the second call's candidate
        rows (re-stacking path; the device tier masks nothing here,
        candidates ride the same buffers)."""
        graph, params, user_in = paper
        plan = ServePlan().evolve(batch__max_batch=1024,
                                  batch__hedging=False,
                                  cache__device_resident=False)
        eng = ServingEngine(graph, params, plan=plan)
        # big fills 6 full packs; victim lands alone in a 7th pack whose
        # transfer copy queues behind all 6 executables — the widest
        # possible race window for attacker's same-bucket refill
        big = _request(graph, user_in, 0, 6 * 1024, seed=0)
        victim = _request(graph, user_in, 1, 1000, seed=1)    # bucket 1024
        attacker = _request(graph, user_in, 2, 900, seed=2)   # bucket 1024
        ref = [eng.score(r) for r in (big, victim, attacker)]
        for _ in range(3):
            h1 = eng.begin_coalesced([big, victim])
            h2 = eng.begin_coalesced([attacker])  # same-bucket refill while
            out = eng.collect(h1) + eng.collect(h2)   # victim copy pends
            for got, want in zip(out, ref):
                assert np.array_equal(got.scores, want.scores)

    def test_loop_profiler_phases(self, paper):
        """The loop's queue_idle/overlap phases surface in the profile."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_plan())
        with CoalescingBatcher(eng, linger_ms=0.0, continuous=True) as b:
            futs = [b.submit(_request(graph, user_in, u % 2, 8, seed=u))
                    for u in range(8)]
            for f in futs:
                f.result(timeout=120)
            time.sleep(0.12)                    # an idle tick or two
        snap = eng.profiler.snapshot()
        assert snap["queue_idle"]["calls"] >= 1
        # overlap may legitimately be zero on a fast box (the queue can
        # drain before a second group forms), so only check presence
        assert "overlap" in snap


class _GatedResultEngine:
    """Engine stand-in: the FIRST group blocks on a gate so submissions
    pile up behind it; every request's rows and SLO-visible shape are
    recorded; results are real ServeResult objects."""
    max_batch = 1 << 30

    def __init__(self):
        self.scored: list[ServeRequest] = []
        self.gate = threading.Event()
        self.first_group = threading.Event()

    def _rows(self, req):
        return next(iter(req.candidate_feeds.values())).shape[0]

    def score_coalesced(self, reqs):
        hold = not self.first_group.is_set()
        self.first_group.set()
        self.scored.extend(reqs)
        if hold:
            self.gate.wait(timeout=30)
        return [ServeResult(scores=np.zeros((self._rows(r), 1)),
                            latency_ms=0.0, n_batches=1,
                            user_cache_hit=False) for r in reqs]


def _tiny_req(uid, n=8):
    return ServeRequest(uid, {}, {"x": np.zeros((n, 2), np.float32)})


class TestAdmissionControl:
    def _held_batcher(self, **kw):
        spy = _GatedResultEngine()
        b = CoalescingBatcher(spy, linger_ms=0.0, max_coalesce=1,
                              admission=True, **kw)
        blocker = b.submit(_tiny_req(999))
        assert spy.first_group.wait(timeout=30)   # worker now held mid-group
        return spy, b, blocker

    def test_best_effort_shed_fails_fast_and_typed(self):
        spy, b, blocker = self._held_batcher(shed_queue_depth=3)
        try:
            admitted = [b.submit(_tiny_req(u)) for u in range(3)]
            t0 = time.perf_counter()
            shed = b.submit(_tiny_req(50))
            waited = time.perf_counter() - t0
            assert shed.done()                    # failed at submit: no hang
            assert waited < 1.0
            with pytest.raises(AdmissionError) as ei:
                shed.result(timeout=1)
            assert ei.value.slo == "best_effort"
            assert ei.value.queue_depth >= 3
            spy.gate.set()
            for f in [blocker] + admitted:
                f.result(timeout=30)
        finally:
            spy.gate.set()
            b.close()
        assert b.shed_requests == 1 and b.shed_best_effort == 1
        assert b.shed_deadline == 0
        # shed user 50 never reached the engine
        assert 50 not in [r.user_id for r in spy.scored]

    def test_deadline_never_shed_while_best_effort_is(self):
        """The satellite contract: at a depth where best_effort is shed,
        deadline-class submissions are still admitted and scored."""
        spy, b, blocker = self._held_batcher(shed_queue_depth=2)
        try:
            filler = [b.submit(_tiny_req(u)) for u in range(2)]
            for u in (60, 61):                    # depth >= shed threshold
                with pytest.raises(AdmissionError):
                    b.submit(_tiny_req(u)).result(timeout=1)
            dl = [b.submit(_tiny_req(70 + i), slo="deadline")
                  for i in range(3)]
            spy.gate.set()
            for f in [blocker] + filler + dl:
                f.result(timeout=30)              # every admitted one scored
        finally:
            spy.gate.set()
            b.close()
        assert b.shed_best_effort == 2 and b.shed_deadline == 0
        scored = [r.user_id for r in spy.scored]
        assert all(70 + i in scored for i in range(3))

    def test_infeasible_deadline_shed(self):
        spy = _GatedResultEngine()
        spy.gate.set()
        with CoalescingBatcher(spy, linger_ms=0.0, admission=True,
                               deadline_headroom_ms=5.0) as b:
            with pytest.raises(AdmissionError, match="headroom"):
                b.submit(_tiny_req(1), deadline_ms=2.0).result(timeout=1)
            ok = b.submit(_tiny_req(2), deadline_ms=50.0)
            ok.result(timeout=30)
            assert b.shed_deadline == 1

    def test_degrade_truncates_best_effort_only(self):
        spy, b, blocker = self._held_batcher(degrade_queue_depth=1,
                                             degrade_frac=0.5)
        try:
            filler = b.submit(_tiny_req(1))       # depth 1: degrades follow
            deg = b.submit(_tiny_req(2, n=8))
            dl = b.submit(_tiny_req(3, n=8), slo="deadline")
            spy.gate.set()
            res = deg.result(timeout=30)
            assert res.degraded is True
            assert res.scores.shape[0] == 4       # ceil(8 * 0.5)
            assert dl.result(timeout=30).degraded is False
            for f in (blocker, filler):
                f.result(timeout=30)
        finally:
            spy.gate.set()
            b.close()
        assert b.degraded_requests == 1
        rows = {r.user_id: next(iter(r.candidate_feeds.values())).shape[0]
                for r in spy.scored}
        assert rows[2] == 4 and rows[3] == 8      # deadline kept its pool

    def test_admission_off_never_sheds(self):
        spy, b, blocker = self._held_batcher(shed_queue_depth=1)
        b.admission = False                       # thresholds present, off
        try:
            futs = [b.submit(_tiny_req(u)) for u in range(4)]
            spy.gate.set()
            for f in [blocker] + futs:
                f.result(timeout=30)
        finally:
            spy.gate.set()
            b.close()
        assert b.shed_requests == 0

    def test_service_stats_surface_shed_counters(self):
        plan = ServePlan().evolve(batch__hedging=False, batch__admission=True,
                                  batch__shed_queue_depth=64,
                                  batch__deadline_headroom_ms=1.0)
        with RankingService(plan, smoke=True, seed=0) as svc:
            svc.register("din")
            feeds = make_recsys_feeds(svc.source_graph("din"), 6,
                                      jax.random.PRNGKey(1))
            uf, cf = svc.split_feeds("din", feeds)
            svc.score("din", ServeRequest(1, uf, cf))
            with pytest.raises(AdmissionError):
                svc.submit("din", ServeRequest(2, uf, cf),
                           deadline_ms=0.5).result(timeout=1)
            sc = svc.stats()["scenarios"]["din"]
        assert sc["shed_requests"] == 1 and sc["shed_deadline"] == 1
        assert sc["shed_best_effort"] == 0
        assert sc["degraded_requests"] == 0
        assert "pipeline_forks" in sc


class TestCloseDrain:
    def test_close_scores_queued_requests(self, paper):
        """The close() bugfix: queued-but-unclaimed requests are scored
        during the drain, not abandoned — even mid-linger."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_plan())
        eng.score(_request(graph, user_in, 0, 10, seed=0))   # precompile
        # a huge linger would strand queued items without the drain: the
        # old worker lingered per group even while stopping
        b = CoalescingBatcher(eng, linger_ms=60_000.0, max_coalesce=2)
        futs = [b.submit(_request(graph, user_in, u, 10, seed=u))
                for u in range(6)]
        b.close()                                 # must drain, fast
        for f in futs:
            res = f.result(timeout=1)             # already resolved
            assert res.scores.shape[0] == 10

    def test_close_under_load_leaves_nothing_hanging(self):
        """Close fired while the worker is mid-group: the held group AND
        everything queued behind it still resolve."""
        spy = _GatedResultEngine()
        b = CoalescingBatcher(spy, linger_ms=0.0, max_coalesce=1)
        blocker = b.submit(_tiny_req(0))
        assert spy.first_group.wait(timeout=30)
        futs = [b.submit(_tiny_req(u)) for u in range(1, 8)]
        closer = threading.Thread(target=b.close)
        closer.start()
        time.sleep(0.05)
        spy.gate.set()                            # release the held group
        closer.join(timeout=30)
        assert not closer.is_alive()
        for f in [blocker] + futs:
            assert f.result(timeout=5) is not None
        assert len(spy.scored) == 8

    def test_stranded_future_fails_typed(self):
        """The backstop: items a dead worker never claimed fail with
        BatcherClosedError instead of hanging their waiter."""
        from concurrent.futures import Future

        from repro.serve.batcher import _Item
        spy = _GatedResultEngine()
        spy.gate.set()
        b = CoalescingBatcher(spy, auto_start=False)
        fut = Future()
        b._q.put(_Item(prio=1, seq=b._next_seq(), req=_tiny_req(1), fut=fut,
                       submitted_at=time.perf_counter()))
        b.close()                                 # no worker ever ran
        with pytest.raises(BatcherClosedError):
            fut.result(timeout=1)
