"""Substrate tests: optimizers, losses, checkpointing, data, serving engine,
sharding rules, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree_allclose
from repro.train.losses import auc, bce_with_logits, softmax_xent
from repro.train.optim import (WarmupCosine, adafactor, adam, adamw,
                               apply_updates, clip_by_global_norm, sgd)


def _quadratic_converges(opt, steps=150, tol=1e-2):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


class TestOptim:
    @pytest.mark.parametrize("opt", [
        sgd(0.1), sgd(0.05, momentum=0.9), adam(0.1),
        adamw(0.1, weight_decay=0.0), adafactor(0.3),
    ], ids=["sgd", "sgd_m", "adam", "adamw", "adafactor"])
    def test_converges(self, opt):
        assert _quadratic_converges(opt) < 1e-2

    def test_master_weights_bf16(self):
        """bf16 params + f32 master must out-converge pure bf16 updates."""
        opt = adamw(0.01, weight_decay=0.0, master_weights=True)
        target = jnp.full((8,), 0.3337)
        params = {"w": jnp.zeros(8, jnp.bfloat16)}
        state = opt.init(params)
        assert state["master"]["w"].dtype == jnp.float32
        for _ in range(300):
            grads = {"w": (params["w"].astype(jnp.float32)
                           - target).astype(jnp.bfloat16)}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        err = float(jnp.abs(state["master"]["w"] - target).max())
        assert err < 5e-3

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 20.0) < 1e-4
        cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
        assert abs(cn - 1.0) < 1e-4

    def test_warmup_cosine(self):
        sch = WarmupCosine(1.0, 10, 100)
        assert float(sch(jnp.int32(0))) == 0.0
        assert abs(float(sch(jnp.int32(10))) - 1.0) < 1e-5
        assert float(sch(jnp.int32(100))) <= 0.11


class TestLosses:
    def test_bce_matches_manual(self):
        logits = jnp.array([0.5, -1.0, 2.0])
        labels = jnp.array([1.0, 0.0, 1.0])
        manual = -(labels * jnp.log(jax.nn.sigmoid(logits))
                   + (1 - labels) * jnp.log(1 - jax.nn.sigmoid(logits))).mean()
        assert abs(float(bce_with_logits(logits, labels) - manual)) < 1e-5

    def test_bce_extreme_logits_stable(self):
        v = float(bce_with_logits(jnp.array([1000.0, -1000.0]),
                                  jnp.array([1.0, 0.0])))
        assert np.isfinite(v) and v < 1e-3

    def test_auc_perfect_and_random(self):
        scores = np.array([0.9, 0.8, 0.2, 0.1])
        labels = np.array([1, 1, 0, 0])
        assert auc(scores, labels) == 1.0
        assert auc(-scores, labels) == 0.0
        assert abs(auc(np.ones(4), labels) - 0.5) < 1e-9

    def test_softmax_xent_uniform(self):
        logits = jnp.zeros((5, 7))
        labels = jnp.arange(5) % 7
        assert abs(float(softmax_xent(logits, labels)) - np.log(7)) < 1e-5


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.ckpt.manager import restore_pytree, save_pytree
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16),
                      "d": jnp.int32(7)}}
        save_pytree(tree, str(tmp_path / "ck"), {"step": 3})
        out = restore_pytree(tree, str(tmp_path / "ck"))
        assert tree_allclose(tree, out)
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_retention_and_latest(self, tmp_path):
        from repro.ckpt.manager import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2,
                                async_save=False)
        tree = {"w": jnp.zeros(2)}
        for s in [1, 2, 3, 4]:
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4

    def test_async_save(self, tmp_path):
        from repro.ckpt.manager import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(5, {"w": jnp.ones(3)})
        mgr.wait()
        got, meta = mgr.restore({"w": jnp.zeros(3)})
        assert meta["step"] == 5
        np.testing.assert_array_equal(got["w"], 1.0)

    def test_shape_mismatch_rejected(self, tmp_path):
        from repro.ckpt.manager import restore_pytree, save_pytree
        save_pytree({"w": jnp.zeros((2, 2))}, str(tmp_path / "ck"))
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_pytree({"w": jnp.zeros((3, 3))}, str(tmp_path / "ck"))


class TestShardingRules:
    def test_lm_pspecs_cover_tree(self):
        from repro import configs as cfgreg
        from repro.dist.sharding import lm_param_pspecs, zero1_pspecs
        for arch in ["mixtral-8x7b", "qwen3-14b"]:
            cfg = cfgreg.get_config(arch).CONFIG
            from repro.models.transformer import lm_param_specs
            shapes = lm_param_specs(cfg)
            pp = lm_param_pspecs(cfg)
            # same tree structure
            jax.tree_util.tree_map(lambda a, b: None, shapes, pp,
                                   is_leaf=lambda x: not isinstance(x, dict))
            zp = zero1_pspecs(pp, shapes)

            def check(spec, shape):
                parts = list(spec)
                flat = [a for p in parts if p
                        for a in (p if isinstance(p, tuple) else (p,))]
                assert len(set(flat)) == len(flat), "axis reused in one spec"
            jax.tree_util.tree_map(
                check, zp, shapes,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    def test_vocab_padding_divisible(self):
        from repro import configs as cfgreg
        for arch in ["mixtral-8x7b", "granite-moe-3b-a800m", "deepseek-67b",
                     "qwen3-14b", "yi-9b"]:
            cfg = cfgreg.get_config(arch).CONFIG
            assert cfg.vocab_padded % 256 == 0
            assert cfg.vocab_padded >= cfg.vocab

    def test_recsys_big_tables_sharded(self):
        from repro import configs as cfgreg
        from repro.dist.sharding import recsys_param_pspecs
        graph, _ = cfgreg.get_config("dlrm-mlperf").BUILD()
        pp = recsys_param_pspecs(graph)
        big = pp["sparse_0_emb"]["table"]
        small = pp["sparse_5_emb"]["table"]   # vocab 3
        assert big[0] == "model" and small[0] is None


class TestGradientCompression:
    def test_compressed_psum_unbiased_over_steps(self):
        """Error feedback: accumulated compressed sums converge to the true
        mean (single-device shard_map exercises the collective path)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.compress import compressed_psum

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        g = {"w": jnp.linspace(-1.0, 1.0, 16)}

        def f(g):
            out, err = compressed_psum(g, "data")
            return out, err

        fm = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))
        out, err = fm(g)
        # single participant: mean == value up to quantization error
        np.testing.assert_allclose(out["w"], g["w"], atol=2 / 127)
        # error feedback captures exactly the residual
        np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                                   g["w"], atol=1e-6)


class TestSamplerAndData:
    def test_neighbor_sampler_invariants(self):
        from repro.data.sampler import NeighborSampler, random_graph
        g = random_graph(200, 1000, 8)
        s = NeighborSampler(g["senders"], g["receivers"], 200, (5, 3))
        rng = np.random.default_rng(0)
        samp = s.sample(np.arange(32), rng)
        ne = int(samp["edge_mask"].sum())
        nn = int(samp["node_mask"].sum())
        assert ne <= s.max_sample_edges(32)
        assert nn <= s.max_sample_nodes(32)
        # all real edges reference sampled-local node indices
        snd = samp["senders"][samp["edge_mask"]]
        rcv = samp["receivers"][samp["edge_mask"]]
        assert snd.max() < nn and rcv.max() < nn
        # every real edge exists in the original graph
        edges = set(zip(g["senders"].tolist(), g["receivers"].tolist()))
        nodes = samp["nodes"]
        for u, v in zip(snd[:50], rcv[:50]):
            assert (int(nodes[u]), int(nodes[v])) in edges

    def test_feeds_match_graph(self):
        from repro import configs as cfgreg
        from repro.data.features import make_recsys_feeds
        graph, _ = cfgreg.get_config("deepfm").smoke_build()()
        feeds = make_recsys_feeds(graph, 5, jax.random.PRNGKey(0))
        for n in graph.input_nodes():
            v = feeds[n.name]
            expect = 1 if n.attrs.get("domain") == "user" else 5
            assert v.shape == (expect,) + tuple(n.attrs["shape"])


class TestServingEngine:
    def test_minibatch_and_cache(self):
        from repro.data.features import make_recsys_feeds
        from repro.graph.executor import init_graph_params
        from repro.models.recsys import build_din
        from repro.serve.engine import ServeRequest, ServingEngine
        graph, _ = build_din(embed_dim=4, seq_len=6, attn_mlp=(8, 4),
                             mlp=(8,), item_vocab=32, user_profile_dim=6,
                             context_dim=3)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        eng = ServingEngine(graph, params, mode="mari", max_batch=16)
        feeds = make_recsys_feeds(graph, 40, jax.random.PRNGKey(1))
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        req = ServeRequest(
            user_id=1,
            user_feeds={k: v for k, v in feeds.items() if k in user_in},
            candidate_feeds={k: v for k, v in feeds.items()
                             if k not in user_in})
        r1 = eng.score(req)
        assert r1.scores.shape[0] == 40
        assert r1.n_batches == 3       # 16+16+8(padded)
        assert not r1.user_cache_hit
        r2 = eng.score(req)
        assert r2.user_cache_hit
        np.testing.assert_allclose(r1.scores, r2.scores, atol=1e-6)

    def test_hedge_policy(self):
        from repro.ft.failures import HedgePolicy
        h = HedgePolicy(quantile=0.9, window=100, min_hedge_ms=1.0)
        for _ in range(50):
            h.observe(10.0)
        h.observe(100.0)
        assert not h.should_hedge(5.0)
        assert h.should_hedge(150.0)
