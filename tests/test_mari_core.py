"""Unit tests for the paper's core: GCA (Alg. 1), MaRI rewrite (Eq. 7),
parameter conversion, reorganization (§2.4), FLOPs accounting (App. B.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Color, apply_mari, convert_params, convert_params_reorg,
                        detect_in_jaxpr, mari_rewrite, reorganize, run_gca,
                        WeightPartition)
from repro.core.mari import (matmul_mari, matmul_mari3, matmul_mari_fragmented,
                             matmul_vanilla, mari_flops, vanilla_flops)
from repro.graph import Executor, GraphBuilder, init_graph_params
from repro.models.ranking import (PaperRankingConfig, build_paper_ranking_model,
                                  expected_eligible)


def _simple_graph():
    b = GraphBuilder()
    u = b.input("u", (12,), "user")
    i = b.input("i", (8,), "item")
    x = b.input("x", (4,), "cross")
    c = b.concat("c", [u, i, x])
    f1 = b.dense("f1", c, 16, activation="relu")
    f2 = b.dense("f2", f1, 1)
    b.output(f2)
    return b.graph


class TestGCA:
    def test_colors(self):
        g = _simple_graph()
        r = run_gca(g)
        assert r.colors["u"] is Color.YELLOW
        assert r.colors["i"] is Color.BLUE
        assert r.colors["c"] is Color.BLUE          # blue dominates
        assert r.colors["f1"] is Color.BLUE

    def test_eligible_first_matmul_only(self):
        r = run_gca(_simple_graph())
        assert r.eligible == {"f1": "c"}

    def test_transparent_path(self):
        b = GraphBuilder()
        u = b.input("u", (4,), "user")
        i = b.input("i", (4,), "item")
        c = b.concat("c", [u, i])
        idn = b.identity("idn", c)
        cast = b.cast("cst", idn, "float32")
        f = b.dense("f", cast, 8)
        b.output(f)
        r = run_gca(b.graph)
        assert "f" in r.eligible

    def test_computational_path_blocks(self):
        b = GraphBuilder()
        u = b.input("u", (4,), "user")
        i = b.input("i", (4,), "item")
        c = b.concat("c", [u, i])
        a = b.act("a", c, "relu")           # computational: breaks the path
        f = b.dense("f", a, 8)
        b.output(f)
        r = run_gca(b.graph)
        assert "f" not in r.eligible

    def test_all_user_concat_not_boundary(self):
        b = GraphBuilder()
        u1 = b.input("u1", (4,), "user")
        u2 = b.input("u2", (4,), "user")
        c = b.concat("c", [u1, u2])
        f = b.dense("f", c, 8)
        b.output(f)
        r = run_gca(b.graph)
        assert r.boundary_concats == [] and r.eligible == {}

    def test_paper_model_sites(self):
        cfg = PaperRankingConfig().scaled(0.05)
        g, cfg = build_paper_ranking_model(cfg)
        r = run_gca(g)
        assert expected_eligible(cfg) <= set(r.eligible)

    def test_user_subgraph_one_shot(self):
        g = _simple_graph()
        r = run_gca(g)
        assert "u" in r.user_subgraph and "f1" not in r.user_subgraph


class TestMaRIEquivalence:
    @pytest.fixture
    def setup(self):
        g = _simple_graph()
        params = init_graph_params(g, jax.random.PRNGKey(0))
        feeds = {
            "u": jax.random.normal(jax.random.PRNGKey(1), (1, 12)),
            "i": jax.random.normal(jax.random.PRNGKey(2), (7, 8)),
            "x": jax.random.normal(jax.random.PRNGKey(3), (7, 4)),
        }
        ref = Executor(g, "vani").run(params, feeds)["f2"]
        return g, params, feeds, ref

    def test_uoi(self, setup):
        g, params, feeds, ref = setup
        out = Executor(g, "uoi").run(params, feeds)["f2"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_mari_grouped(self, setup):
        g, params, feeds, ref = setup
        mg, mp, conv = apply_mari(g, params)
        assert [r.dense for r in conv.rewrites] == ["f1"]
        out = Executor(mg, "uoi").run(mp, feeds)["f2"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_mari_by_domain_three_groups(self, setup):
        g, params, feeds, ref = setup
        mg, mp, conv = apply_mari(g, params, group_by_domain=True)
        labels = [lab for lab, _ in conv.rewrites[0].groups]
        assert labels == ["user", "item", "cross"]
        out = Executor(mg, "uoi").run(mp, feeds)["f2"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_mari_fragmented(self, setup):
        g, params, feeds, ref = setup
        mg, mp, conv = apply_mari(g, params, fragment=True)
        out = Executor(mg, "uoi").run(mp, feeds)["f2"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_batch_one(self, setup):
        g, params, feeds, _ = setup
        feeds = {k: v[:1] for k, v in feeds.items()}
        ref = Executor(g, "vani").run(params, feeds)["f2"]
        mg, mp, _ = apply_mari(g, params)
        out = Executor(mg, "uoi").run(mp, feeds)["f2"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_dce_removes_dead_concat(self, setup):
        g, params, _, _ = setup
        mg, _, _ = apply_mari(g, params)
        assert "c" not in mg.nodes   # concat consumed only by rewritten dense


class TestFunctionalForms:
    def test_eq7_two_group(self):
        key = jax.random.PRNGKey(0)
        xu = jax.random.normal(key, (1, 10))
        xr = jax.random.normal(key, (32, 6))
        wu = jax.random.normal(key, (10, 4))
        wr = jax.random.normal(key, (6, 4))
        b = jnp.ones((4,))
        tiled = jnp.concatenate([jnp.broadcast_to(xu, (32, 10)), xr], -1)
        w = jnp.concatenate([wu, wr], 0)
        np.testing.assert_allclose(matmul_mari(xu, xr, wu, wr, b),
                                   matmul_vanilla(tiled, w, b), atol=1e-5)

    def test_eq7_three_group(self):
        key = jax.random.PRNGKey(1)
        xu, xi, xc = (jax.random.normal(key, (1, 5)),
                      jax.random.normal(key, (8, 3)),
                      jax.random.normal(key, (8, 2)))
        wu, wi, wc = (jax.random.normal(key, (5, 4)),
                      jax.random.normal(key, (3, 4)),
                      jax.random.normal(key, (2, 4)))
        tiled = jnp.concatenate([jnp.broadcast_to(xu, (8, 5)), xi, xc], -1)
        w = jnp.concatenate([wu, wi, wc], 0)
        np.testing.assert_allclose(matmul_mari3(xu, xi, xc, wu, wi, wc),
                                   matmul_vanilla(tiled, w), atol=1e-5)

    def test_fragmented_equals_grouped(self):
        key = jax.random.PRNGKey(2)
        segs = []
        tiled_parts, w_parts = [], []
        B = 16
        for j, (w_, dom) in enumerate([(4, "u"), (3, "i"), (5, "u"), (2, "i")]):
            x = jax.random.normal(jax.random.fold_in(key, j),
                                  (1 if dom == "u" else B, w_))
            wm = jax.random.normal(jax.random.fold_in(key, 10 + j), (w_, 6))
            segs.append((x, wm))
            tiled_parts.append(jnp.broadcast_to(x, (B, w_)))
            w_parts.append(wm)
        ref = matmul_vanilla(jnp.concatenate(tiled_parts, -1),
                             jnp.concatenate(w_parts, 0))
        np.testing.assert_allclose(matmul_mari_fragmented(segs), ref, atol=1e-5)

    def test_flops_eq8_eq9_match_table2(self):
        # Varying-B regime (D_user=4000, D_item=D_cross=1000): speedup -> 3.0
        part = WeightPartition(4000, 1000, 1000, 512)
        assert vanilla_flops(2000, 6000, 512) == part.flops_vanilla(2000)
        assert mari_flops(2000, 4000, 2000, 512) == part.flops_mari(2000)
        assert abs(part.flops_speedup(100) - 2.94) < 0.01    # Table 2 row B=100
        assert abs(part.flops_speedup(2000) - 3.00) < 0.01   # Table 2 row B=2000
        # Varying D_item/cross regime (D_rest total): 500 -> 8.96, 1000 -> 4.99
        assert abs(WeightPartition(4000, 500, 0, 512).flops_speedup(2000)
                   - 8.96) < 0.01
        assert abs(WeightPartition(4000, 1000, 0, 512).flops_speedup(2000)
                   - 4.99) < 0.01
        # saving ratio -> Du/(Du+Di+Dc) for B >> 1 (App. B.2)
        ratio = 1 - part.flops_mari(100000) / part.flops_vanilla(100000)
        assert abs(ratio - 4000 / 6000) < 1e-3


class TestReorg:
    def test_interleaved_roundtrip(self):
        b = GraphBuilder()
        segs = [("a", 5, "user"), ("b", 3, "item"), ("c", 4, "user"),
                ("d", 2, "cross"), ("e", 6, "item")]
        names = [b.input(n, (w,), d) for n, w, d in segs]
        c = b.concat("cc", names)
        f = b.dense("f", c, 8)
        b.output(f)
        g = b.graph
        params = init_graph_params(g, jax.random.PRNGKey(0))
        B = 6
        feeds = {n: jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(1), i),
                                      ((1 if d == "user" else B), w))
                 for i, (n, w, d) in enumerate(segs)}
        ref = Executor(g, "vani").run(params, feeds)["f"]
        g2, plans = reorganize(g)
        assert plans and plans[0].new_order == ("a", "c", "b", "e", "d")
        p2 = convert_params_reorg(plans, params)
        out = Executor(g2, "uoi").run(p2, feeds)["f"]
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_neat_layout_noop(self):
        g = _simple_graph()
        _, plans = reorganize(g)
        assert plans == []

    def test_restore_node_for_other_consumer(self):
        b = GraphBuilder()
        i = b.input("i", (3,), "item")
        u = b.input("u", (2,), "user")
        c = b.concat("cc", [i, u])          # item first -> reorg permutes
        f = b.dense("f", c, 4)
        a = b.act("other", c, "relu")       # non-matmul consumer
        b.output(f, a)
        g = b.graph
        params = init_graph_params(g, jax.random.PRNGKey(0))
        feeds = {"i": jnp.arange(12.).reshape(4, 3), "u": jnp.ones((1, 2))}
        ref = Executor(g, "vani").run(params, feeds)
        g2, plans = reorganize(g)
        assert plans[0].restored_consumers == ("other",)
        p2 = convert_params_reorg(plans, params)
        out = Executor(g2, "uoi").run(p2, feeds)
        for k in ref:
            np.testing.assert_allclose(out[k], ref[k], atol=1e-5)


class TestJaxprGCA:
    def test_detects_dot_general(self):
        def model(params, feeds):
            z = jnp.concatenate(
                [jnp.broadcast_to(feeds["user_x"], (feeds["item_x"].shape[0], 4)),
                 feeds["item_x"]], -1)
            return jax.nn.relu(z @ params["w"])

        rep = detect_in_jaxpr(
            model, {"user_x": "user", "item_x": "item"},
            {"w": jnp.zeros((8, 3))},
            {"user_x": jnp.zeros((1, 4)), "item_x": jnp.zeros((5, 4))})
        assert len(rep.mixed_concats) == 1
        assert len(rep.eligible) == 1
        assert rep.eligible[0].rhs_shape == (8, 3)

    def test_no_false_positive_after_nonlinearity(self):
        def model(params, feeds):
            z = jnp.concatenate(
                [jnp.broadcast_to(feeds["user_x"], (feeds["item_x"].shape[0], 4)),
                 feeds["item_x"]], -1)
            return jax.nn.relu(z) @ params["w"]

        rep = detect_in_jaxpr(
            model, {"user_x": "user", "item_x": "item"},
            {"w": jnp.zeros((8, 3))},
            {"user_x": jnp.zeros((1, 4)), "item_x": jnp.zeros((5, 4))})
        assert len(rep.eligible) == 0


class TestConvertParams:
    def test_row_partition_matches_eq3(self):
        g = _simple_graph()
        params = init_graph_params(g, jax.random.PRNGKey(0))
        conv = mari_rewrite(g)
        mp = convert_params(conv, params)
        w = params["f1"]["w"]
        np.testing.assert_array_equal(mp["f1"]["w_user"], w[:12])
        np.testing.assert_array_equal(mp["f1"]["w_rest"], w[12:])
        np.testing.assert_array_equal(mp["f1"]["b"], params["f1"]["b"])

    def test_other_params_shared(self):
        g = _simple_graph()
        params = init_graph_params(g, jax.random.PRNGKey(0))
        _, mp, _ = apply_mari(g, params)
        assert mp["f2"] is params["f2"]


class TestAttentionReparam:
    """Beyond-paper: Eq. 7 pushed through the DIN local-activation unit."""

    def _setup(self):
        from repro.models.recsys import build_din
        graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                             mlp=(24, 12), item_vocab=128)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        from repro.data.features import make_recsys_feeds
        feeds = make_recsys_feeds(graph, 11, jax.random.PRNGKey(1))
        return graph, params, feeds

    def test_lossless(self):
        graph, params, feeds = self._setup()
        ref = Executor(graph, "vani").run(params, feeds)["logit"]
        conv = mari_rewrite(graph, reparam_attention=True)
        assert [a.node for a in conv.attn_rewrites] == ["din_attn"]
        mp = convert_params(conv, params)
        out = Executor(conv.graph, "uoi").run(mp, feeds)["logit"]
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_weight_identity(self):
        """w_kd = W_k + W_d and w_qd = W_q - W_d recover the original MLP."""
        graph, params, feeds = self._setup()
        conv = mari_rewrite(graph, reparam_attention=True)
        mp = convert_params(conv, params)
        w1 = params["din_attn"]["layer_0"]["w"]
        d = conv.attn_rewrites[0].d
        np.testing.assert_allclose(mp["din_attn"]["layer_0"]["w_kd"],
                                   w1[:d] + w1[2 * d:3 * d], atol=1e-6)
        np.testing.assert_allclose(mp["din_attn"]["layer_0"]["w_qd"],
                                   w1[d:2 * d] - w1[2 * d:3 * d], atol=1e-6)
        np.testing.assert_allclose(mp["din_attn"]["layer_0"]["w_p"],
                                   w1[3 * d:], atol=1e-6)

    def test_skipped_when_keys_not_user_side(self):
        b = GraphBuilder()
        q = b.input("q", (8,), "item")
        keys = b.input("keys", (5, 8), "item")   # item-side keys: ineligible
        att = b.target_attention("att", q, keys)
        out = b.dense("out", att, 1)
        b.output(out)
        conv = mari_rewrite(b.graph, reparam_attention=True)
        assert conv.attn_rewrites == []
