"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finite values.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgreg
from repro.data.features import make_labels, make_recsys_feeds
from repro.graph.executor import Executor, init_graph_params
from repro.models.transformer import (init_kv_cache, init_lm_params,
                                      lm_decode_step, lm_logits, lm_loss)
from repro.train.losses import bce_with_logits
from repro.train.optim import adam, apply_updates

LM_ARCHS = ["mixtral-8x7b", "granite-moe-3b-a800m", "deepseek-67b",
            "qwen3-14b", "yi-9b"]
RECSYS_ARCHS = ["dlrm-mlperf", "fm", "din", "deepfm", "paper-ranking"]


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestLMSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = cfgreg.get_config(arch).smoke_config()
        params = init_lm_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B, S = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        logits = lm_logits(params, cfg, toks)
        assert logits.shape == (B, S, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        loss, grads = jax.value_and_grad(lm_loss)(
            params, cfg, toks, jnp.roll(toks, -1, 1))
        assert np.isfinite(float(loss))
        gnorms = [float(jnp.abs(g).max())
                  for g in jax.tree_util.tree_leaves(grads)]
        assert all(np.isfinite(gnorms))

    def test_decode_step(self, arch):
        cfg = cfgreg.get_config(arch).smoke_config()
        params = init_lm_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B = 2
        cache = init_kv_cache(cfg, B, 32, jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        logits, cache2 = lm_decode_step(params, cfg, cache, toks, jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert cache2["k"].shape == cache["k"].shape


class TestMixtralSWA:
    def test_ring_buffer_decode_matches_full(self):
        """SWA ring-buffer decode == full-cache decode once past the window."""
        cfg = cfgreg.get_config("mixtral-8x7b").smoke_config()
        cfg = dataclasses.replace(cfg, moe_experts=0, moe_top_k=0, window=8)
        params = init_lm_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B, T = 1, 24
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
        # ring cache: capacity = window
        ring = init_kv_cache(cfg, B, T, jnp.float32)
        assert ring["k"].shape[2] == 8
        # full-cache reference: same arch without window capacity limit
        cfg_full = dataclasses.replace(cfg, window=None)
        full = init_kv_cache(cfg_full, B, T, jnp.float32)
        for t in range(T):
            lr, ring = lm_decode_step(params, cfg, ring, toks[:, t:t+1],
                                      jnp.int32(t))
            # full cache but SWA masking comes from cfg.window in attention:
            lf, full = lm_decode_step(params, cfg, full, toks[:, t:t+1],
                                      jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
class TestRecSysSmoke:
    def test_three_modes_and_train_step(self, arch):
        mod = cfgreg.get_config(arch)
        graph, *_ = mod.smoke_build()()
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        B = 6
        feeds = make_recsys_feeds(graph, B, jax.random.PRNGKey(1))
        outs = {m: Executor(graph, m).run(params, feeds)
                for m in ("vani", "uoi")}
        for o in graph.outputs:
            assert outs["vani"][o].shape[0] == B
            assert np.isfinite(outs["vani"][o]).all()
            np.testing.assert_allclose(outs["uoi"][o], outs["vani"][o],
                                       rtol=1e-4, atol=1e-4)
        # one train step decreases nothing but must be finite
        ex = Executor(graph, "vani")
        opt = adam(1e-3)
        state = {"params": params, "opt": opt.init(params)}
        labels = make_labels(B, jax.random.PRNGKey(2), len(graph.outputs))
        tfeeds = make_recsys_feeds(graph, B, jax.random.PRNGKey(3),
                                   tile_user=True)

        def loss_fn(p):
            out = ex.run(p, tfeeds)
            return bce_with_logits(
                jnp.concatenate([out[o] for o in graph.outputs], -1), labels)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        assert np.isfinite(float(loss))
        updates, _ = opt.update(grads, state["opt"], state["params"])
        newp = apply_updates(state["params"], updates)
        assert np.isfinite(
            float(jnp.abs(jax.tree_util.tree_leaves(newp)[0]).max()))


class TestSchNetSmoke:
    def test_all_four_regimes(self):
        from repro.data.sampler import (NeighborSampler, batched_molecules,
                                        random_graph)
        from repro.models.schnet import (init_schnet_params, schnet_forward,
                                         schnet_graph_readout)
        cfg = cfgreg.get_config("schnet").smoke_config()
        # full-graph node classification
        scfg = dataclasses.replace(cfg, d_feat=24, n_out=5)
        params = init_schnet_params(scfg, jax.random.PRNGKey(0))
        g = random_graph(60, 200, 24, n_classes=5)
        out = schnet_forward(params, scfg, jnp.asarray(g["features"]),
                             jnp.asarray(g["positions"]),
                             jnp.asarray(g["senders"]),
                             jnp.asarray(g["receivers"]))
        assert out.shape == (60, 5) and np.isfinite(out).all()
        # sampled minibatch with edge masking
        s = NeighborSampler(g["senders"], g["receivers"], 60, (4, 3))
        samp = s.sample(np.arange(8), np.random.default_rng(0))
        feats = jnp.asarray(g["features"])[samp["nodes"]]
        pos = jnp.asarray(g["positions"])[samp["nodes"]]
        out = schnet_forward(params, scfg, feats, pos,
                             jnp.asarray(samp["senders"]),
                             jnp.asarray(samp["receivers"]),
                             edge_mask=jnp.asarray(samp["edge_mask"]))
        assert out.shape[0] == s.max_sample_nodes(8)
        assert np.isfinite(out).all()
        # molecules (atom-type embedding + graph readout)
        mcfg = dataclasses.replace(cfg, d_feat=0, n_out=1)
        mparams = init_schnet_params(mcfg, jax.random.PRNGKey(1))
        mol = batched_molecules(4, 10, 20)
        no = schnet_forward(mparams, mcfg, jnp.asarray(mol["atom_types"]),
                            jnp.asarray(mol["positions"]),
                            jnp.asarray(mol["senders"]),
                            jnp.asarray(mol["receivers"]))
        en = schnet_graph_readout(no, jnp.asarray(mol["graph_ids"]), 4)
        assert en.shape == (4, 1) and np.isfinite(en).all()

    def test_train_step_improves(self):
        from repro.data.sampler import random_graph
        from repro.models.schnet import init_schnet_params, schnet_forward
        from repro.train.losses import softmax_xent
        cfg = cfgreg.get_config("schnet").smoke_config()
        scfg = dataclasses.replace(cfg, d_feat=16, n_out=4)
        params = init_schnet_params(scfg, jax.random.PRNGKey(0))
        g = random_graph(40, 120, 16, n_classes=4)
        batch = {k: jnp.asarray(v) for k, v in g.items()}
        opt = adam(5e-3)
        opt_state = opt.init(params)

        @jax.jit
        def step(params, opt_state):
            def loss_fn(p):
                out = schnet_forward(p, scfg, batch["features"],
                                     batch["positions"], batch["senders"],
                                     batch["receivers"])
                return softmax_xent(out, batch["labels"])
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state2, loss

        losses = []
        for _ in range(20):
            params, opt_state, loss = step(params, opt_state)
            losses.append(float(loss))
        assert losses[-1] < losses[0]


class TestRegistry:
    def test_all_cells_enumerates_40(self):
        cells = cfgreg.all_cells()
        assert len(cells) == 40
        skips = [c for c in cells if c.skip_reason]
        # 4 documented long_500k skips for pure full-attention archs
        assert len(skips) == 4
        assert all(c.shape == "long_500k" for c in skips)
        assert {c.arch for c in skips} == {
            "granite-moe-3b-a800m", "deepseek-67b", "qwen3-14b", "yi-9b"}

    def test_mixtral_runs_long_500k(self):
        cells = cfgreg.all_cells()
        cell = next(c for c in cells
                    if c.arch == "mixtral-8x7b" and c.shape == "long_500k")
        assert cell.skip_reason is None
