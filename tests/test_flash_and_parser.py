"""Extra coverage: flash block attention vs a naive oracle (the LM-family
compute core), and the dry-run HLO collective parser (trip-count logic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# pre-existing seed situation: hypothesis is not installed in the tier-1
# container — skip the whole module there (CI runs it in a dedicated
# non-blocking step that installs hypothesis)
hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.transformer import flash_attention


def naive_attention(q, k, v, q_pos, kv_pos, causal, window, kv_valid=None):
    scale = 1.0 / np.sqrt(q.shape[-1])
    hq, hkv = q.shape[2], k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    dist = q_pos[:, :, None] - kv_pos[:, None, :]
    mask = jnp.ones_like(dist, bool)
    if causal:
        mask &= dist >= 0
    if window is not None:
        mask &= dist < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    logits = jnp.where(mask[:, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vv)


class TestFlashAttention:
    @pytest.mark.parametrize("s,window,qc,kc", [
        (32, None, 8, 8), (32, 8, 8, 16), (64, 16, 16, 8), (32, None, 32, 32),
    ])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    def test_matches_naive(self, s, window, qc, kc, hq, hkv):
        b, hd = 2, 16
        ks = jax.random.split(jax.random.PRNGKey(s + hq), 3)
        q = jax.random.normal(ks[0], (b, s, hq, hd))
        k = jax.random.normal(ks[1], (b, s, hkv, hd))
        v = jax.random.normal(ks[2], (b, s, hkv, hd))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out = flash_attention(q, k, v, pos, pos, causal=True, window=window,
                              q_chunk=qc, kv_chunk=kc)
        ref = naive_attention(q, k, v, pos, pos, True, window)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    @given(seed=st.integers(0, 2**30), sq=st.sampled_from([8, 16]),
           sk=st.sampled_from([16, 32]))
    @settings(max_examples=10, deadline=None)
    def test_cross_lengths_with_validity(self, seed, sq, sk):
        """Decode-style: query shorter than KV, ring-buffer validity mask."""
        b, hq, hkv, hd = 1, 4, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (b, sq, hq, hd))
        k = jax.random.normal(ks[1], (b, sk, hkv, hd))
        v = jax.random.normal(ks[2], (b, sk, hkv, hd))
        q_pos = jnp.broadcast_to(jnp.arange(sk - sq, sk)[None], (b, sq))
        kv_pos = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
        valid = jax.random.bernoulli(ks[3], 0.8, (b, sk)).at[:, -1].set(True)
        out = flash_attention(q, k, v, q_pos, kv_pos, causal=True,
                              window=None, kv_valid=valid, q_chunk=8,
                              kv_chunk=8)
        ref = naive_attention(q, k, v, q_pos, kv_pos, True, None, valid)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestCollectiveParser:
    def test_trip_count_multiplication(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
%cond.1 (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%it, %c), direction=LT
}

%body.1 (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %ar = f32[1024,256] all-reduce(%x), to_apply=%sum
  ROOT %t = (s32[]) tuple(%it)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = f32[2048] all-gather(%a), dimensions={0}
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[8] add(%a, %a)
}
"""
        out = parse_collectives(hlo)
        # all-reduce inside the 7-trip loop: 1024*256*4 bytes * 7 * 2 (ring)
        assert out["all-reduce"] == 1024 * 256 * 4 * 7
        assert out["all-gather"] == 2048 * 4
        assert out["max_loop_trip"] == 7
        assert out["traffic_bytes"] == 2 * out["all-reduce"] + out["all-gather"]

    def test_nested_loops(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
%cond_in (p: (s32[])) -> pred[] {
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body_in (p: (s32[])) -> (s32[]) {
  %cp = bf16[64] collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %t = (s32[]) tuple(%i)
}

%cond_out (p: (s32[])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body_out (p: (s32[])) -> (s32[]) {
  %w2 = (s32[]) while(%init2), condition=%cond_in, body=%body_in
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[]) while(%init), condition=%cond_out, body=%body_out
  ROOT %r = f32[4] add(%a, %a)
}
"""
        out = parse_collectives(hlo)
        assert out["collective-permute"] == 64 * 2 * 15   # bf16, 3*5 trips
        assert out["max_loop_trip"] == 15

    def test_done_ops_not_double_counted(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %s = f32[1024] all-gather-start(%a), dimensions={0}
  %d = f32[1024] all-gather-done(%s)
  ROOT %r = f32[8] add(%a, %a)
}
"""
        out = parse_collectives(hlo)
        assert out["all-gather"] == 1024 * 4
        assert out["count"] == 1
