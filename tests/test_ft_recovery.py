"""Self-healing recovery matrix for the fault-injection framework.

The PR's contracts, smallest-scope first:

* fault specs parse/validate deterministically and the ``FaultPlan``
  section rejects or resolves malformed configs at construction;
* the ``FaultInjector`` fires the SAME pokes every run (seeded, counted,
  disarmed pokes advance nothing);
* the ``CircuitBreaker`` walks closed -> open -> half_open -> closed on
  an injectable clock, and a half-open failure re-opens it;
* the batcher's retry path recovers transient failures, respects the
  remaining deadline budget, and resolves exhausted retries with a typed
  ``RetryExhausted`` — never a hang;
* a crashed dispatch loop is respawned on the same thread and every
  future it was holding resolves typed;
* device-tier quarantine rebuilds lazily and stays bit-identical;
  detected corruption is never served.
"""
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.ft import (CORRUPT, FaultInjector, FaultSpec, HeartbeatMonitor,
                      parse_fault_spec, plan_elastic_remesh)
from repro.ft.recovery import CLOSED, HALF_OPEN, OPEN, CircuitBreaker, \
    RetryPolicy
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.serve import (AdmissionError, BatcherClosedError,
                         CircuitOpenError, CoalescingBatcher, FaultInjected,
                         PlanError, PlanResolutionWarning, RetryExhausted,
                         ServePlan, ServeRequest, ServeResult, ServingEngine,
                         WorkerCrashedError)
from repro.serve.hedging import HedgedRunner, HedgePolicy


@pytest.fixture(scope="module")
def paper():
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.05))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n, seed, version=0):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


def _plan(**over):
    base = dict(batch__max_batch=128, batch__hedging=False,
                cache__device_resident=True, cache__device_slots=8)
    base.update(over)
    return ServePlan().evolve(**base)


# ---------------------------------------------------------------------------
# Fault specs + FaultPlan validation
# ---------------------------------------------------------------------------

class TestFaultSpecs:
    def test_parse_roundtrip(self):
        s = parse_fault_spec("stage2_dispatch:error:after=10,count=3")
        assert s == FaultSpec(site="stage2_dispatch", kind="error",
                              after=10, count=3)
        assert parse_fault_spec(s.describe()) == s

    def test_delay_param(self):
        s = parse_fault_spec("transfer_copy:delay:delay_ms=25")
        assert s.kind == "delay" and s.delay_ms == 25.0

    @pytest.mark.parametrize("bad", [
        "nope:error",                     # unknown site
        "stage1:explode",                 # unknown kind
        "stage1:error:p=0",               # p outside (0, 1]
        "stage1:error:count=0",           # count < 1
        "stage1:error:after=-1",          # negative after
        "stage1:error:delay_ms=5",        # delay_ms on non-delay kind
        "stage1:error:count",             # malformed k=v
        "stage1:error:zap=1",             # unknown param
        "",                               # empty
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_fault_spec(bad)

    def test_plan_rejects_bad_specs_and_knobs(self):
        with pytest.raises(PlanError, match="ft.sites"):
            ServePlan(ft={"inject": True, "sites": ["stage1:explode"]})
        with pytest.raises(PlanError):
            ServePlan(ft={"retries": -1})
        with pytest.raises(PlanError):
            ServePlan(ft={"retry_jitter": 1.5})
        with pytest.raises(PlanError):
            ServePlan(ft={"breaker_probes": 0})

    def test_plan_drop_and_warn_sites_without_inject(self):
        with pytest.warns(PlanResolutionWarning, match="inject"):
            p = ServePlan(ft={"sites": ["stage1:error"]})
        assert p.ft.sites == () and not p.ft.inject

    def test_plan_json_roundtrip_with_ft(self):
        p = _plan(ft__inject=True, ft__seed=7,
                  ft__sites=("slot_write:error:count=2",),
                  ft__retries=3, ft__breaker_failures=2)
        rt = ServePlan.from_json(p.to_json())
        assert rt == p and rt.ft.sites == ("slot_write:error:count=2",)


class TestFaultInjector:
    def test_count_after_and_determinism(self):
        def fires(seed):
            inj = FaultInjector(("stage1:error:after=2,count=2",), seed=seed)
            out = []
            for i in range(8):
                try:
                    inj.poke("stage1")
                    out.append(False)
                except FaultInjected as e:
                    assert e.site == "stage1"
                    out.append(True)
            return out
        assert fires(0) == [False, False, True, True,
                            False, False, False, False]
        assert fires(0) == fires(0)

    def test_probabilistic_streams_are_seed_stable(self):
        def stream(seed):
            inj = FaultInjector(("pack:corrupt:p=0.5",), seed=seed)
            return [inj.poke("pack") is CORRUPT for _ in range(64)]
        assert stream(3) == stream(3)
        assert stream(3) != stream(4)
        assert any(stream(3)) and not all(stream(3))

    def test_disarmed_pokes_advance_nothing(self):
        inj = FaultInjector(("stage1:error:count=1",))
        inj.set_armed(False)
        for _ in range(5):
            assert inj.poke("stage1") is None
        inj.set_armed(True)
        with pytest.raises(FaultInjected):
            inj.poke("stage1")            # warmup did not consume the count
        assert inj.stats()["total_fired"] == 1

    def test_unknown_site_is_noop(self):
        inj = FaultInjector(("stage1:error",))
        assert inj.poke("collect") is None


# ---------------------------------------------------------------------------
# CircuitBreaker + RetryPolicy units
# ---------------------------------------------------------------------------

class TestCircuitBreaker:
    def _clocked(self, **kw):
        t = [0.0]
        br = CircuitBreaker(clock=lambda: t[0], **kw)
        return br, t

    def test_full_walk(self):
        seen = []
        t = [0.0]
        br = CircuitBreaker(failures=2, cooldown_ms=100.0, probes=2,
                            clock=lambda: t[0],
                            on_transition=lambda a, b: seen.append((a, b)))
        assert br.state == CLOSED and br.allow()
        br.record_failure()
        assert br.state == CLOSED          # 1 < threshold
        br.record_failure()
        assert br.state == OPEN and not br.allow()
        t[0] = 0.05
        assert not br.allow()              # cooldown not elapsed
        t[0] = 0.11
        assert br.allow() and br.state == HALF_OPEN
        br.record_success()
        assert br.state == HALF_OPEN       # 1 of 2 probes
        br.record_success()
        assert br.state == CLOSED
        assert seen == [(CLOSED, OPEN), (OPEN, HALF_OPEN),
                        (HALF_OPEN, CLOSED)]
        st = br.stats()
        assert st["opens"] == 1 and st["closes"] == 1

    def test_half_open_failure_reopens(self):
        br, t = self._clocked(failures=1, cooldown_ms=50.0)
        br.record_failure()
        t[0] = 0.06
        assert br.allow() and br.state == HALF_OPEN
        br.record_failure()
        assert br.state == OPEN
        t[0] = 0.08                        # cooldown restarted at reopen
        assert not br.allow()
        t[0] = 0.12
        assert br.allow() and br.state == HALF_OPEN

    def test_success_resets_consecutive_failures(self):
        br, _ = self._clocked(failures=2)
        br.record_failure()
        br.record_success()
        br.record_failure()
        assert br.state == CLOSED          # never 2 consecutive

    def test_call_raises_typed_while_open(self):
        br, t = self._clocked(failures=1, cooldown_ms=1000.0)
        with pytest.raises(RuntimeError):
            br.call(lambda: (_ for _ in ()).throw(RuntimeError("x")))
        assert br.state == OPEN
        with pytest.raises(CircuitOpenError):
            br.call(lambda: 1)
        t[0] = 2.0
        assert br.call(lambda: 41) == 41 and br.state == CLOSED

    def test_ctor_validation(self):
        for kw in (dict(failures=0), dict(cooldown_ms=-1), dict(probes=0)):
            with pytest.raises(ValueError):
                CircuitBreaker(**kw)


class TestRetryPolicy:
    def test_backoff_doubles_without_jitter(self):
        p = RetryPolicy(retries=3, backoff_ms=2.0, jitter=0.0)
        assert [p.backoff_s(a) for a in range(3)] == [0.002, 0.004, 0.008]

    def test_jitter_bounded(self):
        import random
        p = RetryPolicy(retries=1, backoff_ms=10.0, jitter=0.5)
        rng = random.Random(0)
        for a in range(6):
            base = 10.0 * 2 ** a / 1e3
            assert base <= p.backoff_s(a, rng=rng) <= base * 1.5


# ---------------------------------------------------------------------------
# Batcher: retry, deadline budget, worker supervision
# ---------------------------------------------------------------------------

class _FlakyEngine:
    """Spy engine: fails the first ``fail_calls`` score_coalesced calls,
    then succeeds; records call sizes."""
    max_batch = 1 << 30
    _multiproc = False

    def __init__(self, fail_calls=0, exc=None):
        self.fail_calls = fail_calls
        self.exc = exc or FaultInjected("boom", site="stage2_dispatch")
        self.calls: list[int] = []

    def score_coalesced(self, reqs):
        self.calls.append(len(reqs))
        if len(self.calls) <= self.fail_calls:
            raise self.exc
        return [ServeResult(scores=np.full((self._rows(r), 1),
                                           float(r.user_id)),
                            latency_ms=0.0, n_batches=1,
                            user_cache_hit=False) for r in reqs]

    @staticmethod
    def _rows(r):
        return next(iter(r.candidate_feeds.values())).shape[0]


def _tiny_req(uid, n=8):
    return ServeRequest(uid, {}, {"x": np.zeros((n, 2), np.float32)})


class TestBatcherRetry:
    def test_retry_recovers_transient_failure(self):
        eng = _FlakyEngine(fail_calls=1)
        with CoalescingBatcher(eng, linger_ms=0.5, continuous=False,
                               retries=2, retry_backoff_ms=0.1,
                               retry_jitter=0.0) as b:
            res = b.submit(_tiny_req(7)).result(timeout=10)
        assert float(res.scores[0, 0]) == 7.0
        assert b.retries_attempted == 1 and b.retries_exhausted == 0
        assert eng.calls == [1, 1]         # group, then the retry

    def test_retry_exhausted_is_typed_with_cause(self):
        eng = _FlakyEngine(fail_calls=100)
        with CoalescingBatcher(eng, linger_ms=0.5, continuous=False,
                               retries=2, retry_backoff_ms=0.1,
                               retry_jitter=0.0) as b:
            fut = b.submit(_tiny_req(1))
            with pytest.raises(RetryExhausted) as ei:
                fut.result(timeout=10)
        assert ei.value.attempts == 2
        assert isinstance(ei.value.__cause__, FaultInjected)
        assert b.retries_exhausted == 1

    def test_retry_respects_deadline_budget(self):
        eng = _FlakyEngine(fail_calls=100)
        with CoalescingBatcher(eng, linger_ms=0.0, continuous=False,
                               retries=5, retry_backoff_ms=200.0,
                               retry_jitter=0.0) as b:
            t0 = time.perf_counter()
            fut = b.submit(_tiny_req(1), deadline_ms=20.0)
            with pytest.raises(RetryExhausted) as ei:
                fut.result(timeout=10)
            elapsed = time.perf_counter() - t0
        # the 200 ms backoff exceeded the 20 ms budget: zero sleeps taken
        assert ei.value.attempts == 0
        assert elapsed < 1.0
        assert isinstance(ei.value.__cause__, FaultInjected)

    def test_zero_retries_propagates_original_error(self):
        eng = _FlakyEngine(fail_calls=100)
        with CoalescingBatcher(eng, linger_ms=0.5,
                               continuous=False) as b:
            fut = b.submit(_tiny_req(1))
            with pytest.raises(FaultInjected):
                fut.result(timeout=10)

    def test_typed_refusals_never_retried(self):
        eng = _FlakyEngine(fail_calls=100,
                           exc=AdmissionError("no", slo="best_effort",
                                              queue_depth=0))
        with CoalescingBatcher(eng, linger_ms=0.5, continuous=False,
                               retries=3, retry_backoff_ms=0.1) as b:
            fut = b.submit(_tiny_req(1))
            with pytest.raises(AdmissionError):
                fut.result(timeout=10)
        assert b.retries_attempted == 0

    def test_from_plan_wires_ft_retry_knobs(self):
        eng = _FlakyEngine()
        plan = _plan(ft__inject=True, ft__sites=("stage1:error:count=1",),
                     ft__retries=4, ft__retry_backoff_ms=3.0)
        b = CoalescingBatcher.from_plan(eng, plan.batch, plan.ft,
                                        auto_start=False)
        assert b.retries == 4
        assert b._retry_policy.backoff_ms == 3.0


class TestWorkerSupervision:
    def _crashy_engine(self, count=1):
        eng = _FlakyEngine()
        eng.fault_injector = FaultInjector(
            (f"worker_loop:error:count={count}",))
        return eng

    def test_respawn_resolves_crash_victims_via_retry(self):
        eng = self._crashy_engine()
        with CoalescingBatcher(eng, linger_ms=0.5, continuous=False,
                               retries=2, retry_backoff_ms=0.1,
                               retry_jitter=0.0) as b:
            r1 = b.submit(_tiny_req(3)).result(timeout=10)
            # the loop crashed forming the first group; the victim was
            # re-scored individually and the loop respawned for the rest
            r2 = b.submit(_tiny_req(4)).result(timeout=10)
        assert float(r1.scores[0, 0]) == 3.0
        assert float(r2.scores[0, 0]) == 4.0
        assert b.worker_crashes == 1 and b.worker_respawns == 1

    def test_crash_without_retries_fails_typed_never_hangs(self):
        eng = self._crashy_engine()
        with CoalescingBatcher(eng, linger_ms=0.5,
                               continuous=False) as b:
            fut = b.submit(_tiny_req(3))
            with pytest.raises(WorkerCrashedError) as ei:
                fut.result(timeout=10)
            assert isinstance(ei.value.__cause__, FaultInjected)
            # the respawned loop serves subsequent traffic normally
            r2 = b.submit(_tiny_req(4)).result(timeout=10)
        assert float(r2.scores[0, 0]) == 4.0
        assert b.worker_crashes == 1 and b.worker_respawns == 1

    def test_close_after_crash_strands_nothing(self):
        eng = self._crashy_engine(count=2)
        b = CoalescingBatcher(eng, linger_ms=0.5, continuous=False,
                              retries=1, retry_backoff_ms=0.1,
                              retry_jitter=0.0)
        futs = [b.submit(_tiny_req(i)) for i in range(6)]
        b.close()
        for f in futs:
            assert f.done()                # resolved, one way or another
            if f.exception() is not None:
                assert isinstance(f.exception(),
                                  (WorkerCrashedError, BatcherClosedError))


# ---------------------------------------------------------------------------
# Engine: quarantine, breaker, corruption (real two-stage fixture)
# ---------------------------------------------------------------------------

class TestEngineSelfHealing:
    def _reqs(self, graph, user_in, uids, n=12):
        return [_request(graph, user_in, u, n, seed=u) for u in uids]

    def test_quarantine_then_rebuild_is_bit_identical(self, paper):
        graph, params, user_in = paper
        reqs = self._reqs(graph, user_in, [0, 1, 2, 0, 1, 2])
        ref_eng = ServingEngine(graph, params, plan=_plan())
        refs = [r.scores for r in [ref_eng.score(q) for q in reqs]]

        eng = ServingEngine(graph, params, plan=_plan(
            ft__inject=True, ft__sites=("slot_write:error:count=1",)))
        out = [eng.score(q).scores for q in reqs]
        # the faulted write quarantined the tier; the pack fell back to
        # re-stacking, later calls rebuilt the table — scores never moved
        for a, b in zip(out, refs):
            assert np.array_equal(a, b)
        assert eng.device_store.stats()["quarantines"] == 1
        assert eng.device_store.stats()["resident"] > 0   # rebuilt lazily

    def test_breaker_open_fallback_halfopen_close(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=_plan(
            ft__inject=True, ft__sites=("slot_write:error:count=3",),
            ft__breaker_failures=2, ft__breaker_cooldown_ms=40.0,
            ft__breaker_probes=1))
        ref_eng = ServingEngine(graph, params, plan=_plan())
        transitions = []
        orig = eng.breaker._on_transition
        eng.breaker._on_transition = \
            lambda a, b: (transitions.append((a, b)), orig(a, b))

        def score(uid):
            r = _request(graph, user_in, uid, 12, seed=uid)
            got = eng.score(r).scores
            assert np.array_equal(got, ref_eng.score(r).scores)

        score(0)                           # fault 1 -> quarantine
        score(1)                           # fault 2 -> quarantine -> OPEN
        assert eng.breaker.state == OPEN
        fb0 = eng.fallback_packs
        score(2)                           # while open: re-stack fallback
        assert eng.fallback_packs > fb0
        time.sleep(0.06)                   # past the cooldown
        score(3)                           # half-open probe: fault 3 reopens
        assert eng.breaker.state == OPEN
        time.sleep(0.06)
        score(4)                           # clean probe -> CLOSED
        assert eng.breaker.state == CLOSED
        assert (CLOSED, OPEN) in transitions
        assert (OPEN, HALF_OPEN) in transitions
        assert (HALF_OPEN, CLOSED) in transitions
        assert eng.breaker.stats()["opens"] == 2

    def test_corruption_detected_never_served(self, paper):
        graph, params, user_in = paper
        req = _request(graph, user_in, 0, 12, seed=0)
        ref = ServingEngine(graph, params,
                            plan=_plan()).score(req).scores
        eng = ServingEngine(graph, params, plan=_plan(
            ft__inject=True, ft__sites=("collect:corrupt:count=1",)))
        with pytest.raises(FaultInjected, match="corrupt"):
            eng.score_coalesced([req])
        assert eng.corruptions_detected == 1
        # the retry (here: a plain re-score) recomputes clean rows
        assert np.array_equal(eng.score_coalesced([req])[0].scores, ref)

    def test_corrupt_slot_write_detected_and_requarantined(self, paper):
        graph, params, user_in = paper
        req = _request(graph, user_in, 5, 12, seed=5)
        ref = ServingEngine(graph, params,
                            plan=_plan()).score(req).scores
        eng = ServingEngine(graph, params, plan=_plan(
            ft__inject=True, ft__sites=("slot_write:corrupt:count=1",)))
        # the poisoned device row NaNs the scores; collect detects it,
        # quarantines the tier, and raises rather than serving garbage
        with pytest.raises(FaultInjected):
            eng.score_coalesced([req])
        assert eng.corruptions_detected == 1
        assert eng.device_store.stats()["quarantines"] == 1
        assert np.array_equal(eng.score_coalesced([req])[0].scores, ref)

    def test_retry_through_batcher_stays_bit_identical(self, paper):
        graph, params, user_in = paper
        reqs = self._reqs(graph, user_in, [0, 1, 2, 3])
        ref_eng = ServingEngine(graph, params, plan=_plan())
        refs = [ref_eng.score(q).scores for q in reqs]
        eng = ServingEngine(graph, params, plan=_plan(
            ft__inject=True, ft__sites=("stage2_dispatch:error:count=2",),
            ft__retries=3, ft__retry_backoff_ms=0.5))
        plan = _plan(ft__retries=3, ft__retry_backoff_ms=0.5,
                     ft__retry_jitter=0.0)
        with CoalescingBatcher.from_plan(eng, plan.batch, plan.ft) as b:
            futs = [b.submit(q) for q in reqs]
            out = [f.result(timeout=60).scores for f in futs]
        for a, b_ in zip(out, refs):
            assert np.array_equal(a, b_)


# ---------------------------------------------------------------------------
# Satellites: heartbeat resurrection, remesh edges, hedging pool
# ---------------------------------------------------------------------------

class TestHeartbeatSticky:
    def test_removed_worker_stays_removed_on_stray_beat(self):
        t = [0.0]
        hb = HeartbeatMonitor(["a", "b"], timeout=1.0, clock=lambda: t[0])
        hb.remove("a")
        hb.heartbeat("a")                  # stray beat from the removed
        assert "a" not in hb.alive() and "a" not in hb.dead()
        t[0] = 2.0
        assert hb.dead() == ["b"] and "a" not in hb.dead()

    def test_explicit_add_rejoins(self):
        t = [0.0]
        hb = HeartbeatMonitor(["a"], timeout=1.0, clock=lambda: t[0])
        hb.remove("a")
        t[0] = 5.0
        hb.add("a")                        # explicit rejoin, fresh clock
        assert hb.alive() == ["a"]
        hb.heartbeat("a")                  # beats register again
        t[0] = 5.5
        assert hb.alive() == ["a"]


class TestElasticRemeshEdges:
    def test_non_pow2_survivors_round_down(self):
        p = plan_elastic_remesh((4, 2), ("data", "model"), 6)
        assert p.new_shape == (2, 2)       # dp budget 3 -> largest pow2 2
        assert p.dropped_devices == 2
        assert p.global_batch_scale == 0.5

    def test_pod_collapses_into_data(self):
        p = plan_elastic_remesh((2, 2, 2), ("pod", "data", "model"), 4)
        assert p.new_shape == (1, 2, 2)
        assert p.global_batch_scale == 0.5

    def test_tp_unpreservable_raises(self):
        with pytest.raises(ValueError, match="TP"):
            plan_elastic_remesh((2, 4), ("data", "model"), 3)


class TestHedgingPool:
    def test_policy_concurrent_observe_and_read(self):
        pol = HedgePolicy(window=64, min_hedge_ms=1.0)
        stop = threading.Event()
        errs = []

        def writer():
            i = 0
            while not stop.is_set():
                pol.observe(float(i % 37))
                i += 1

        def reader():
            try:
                while not stop.is_set():
                    pol.hedge_deadline_ms()
            except Exception as e:         # pragma: no cover - the bug
                errs.append(e)

        ts = [threading.Thread(target=f) for f in (writer, reader, reader)]
        for th in ts:
            th.start()
        time.sleep(0.2)
        stop.set()
        for th in ts:
            th.join()
        assert not errs

    def test_pool_exhaustion_runs_inline(self):
        r = HedgedRunner(lambda x: x * 2, max_workers=1)
        with r._olock:
            r._outstanding = 1             # simulate a zombie-held worker
        out, outcome = r.run(21)
        assert out == 42 and not outcome.hedged
        assert r.pool_exhausted == 1
        with r._olock:
            r._outstanding = 0
        out, _ = r.run(5)                  # slot free again: normal path
        assert out == 10 and r.pool_exhausted == 1
        r.close()

    def test_no_duplicate_when_pool_full_awaits_primary(self):
        pol = HedgePolicy(min_hedge_ms=0.1)   # deadline 1 ms pre-window
        r = HedgedRunner(lambda: (time.sleep(0.05), 7)[1],
                         policy=pol, max_workers=1)
        out, outcome = r.run()
        # the primary held the only worker past the hedge deadline; the
        # duplicate could not get a slot, so the runner awaited the
        # primary instead of queueing a pointless copy behind it
        assert out == 7 and not outcome.hedged
        assert r.hedges_launched == 0 and r.pool_exhausted == 1
        r.close()


class TestErrorTaxonomy:
    def test_batcher_reexports_are_canonical(self):
        import repro.serve.batcher as B
        import repro.serve.errors as E
        assert B.AdmissionError is E.AdmissionError
        assert B.BatcherClosedError is E.BatcherClosedError
        from repro.serve import AdmissionError as SA
        assert SA is E.AdmissionError

    def test_hierarchy(self):
        from repro.serve.errors import ServeError
        for ex in (AdmissionError, BatcherClosedError, FaultInjected,
                   RetryExhausted, CircuitOpenError, WorkerCrashedError):
            assert issubclass(ex, ServeError)
            assert issubclass(ex, RuntimeError)

    def test_future_from_stdlib_still_typed(self):
        # the taxonomy is stdlib-importable: no jax needed to CATCH
        fut = Future()
        fut.set_exception(RetryExhausted("x", attempts=2))
        assert isinstance(fut.exception(), RetryExhausted)
        assert fut.exception().attempts == 2
