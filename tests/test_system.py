"""End-to-end behaviour tests for the MaRI system.

The paper's deployment claim: train normally, convert with GCA+MaRI, serve —
with ZERO accuracy change ("training AUC remains unchanged", §3.2) and the
same scores up to float reassociation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import apply_mari, run_gca
from repro.data.features import make_recsys_feeds
from repro.graph import Executor, init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.train.losses import bce_with_logits, valid_task_aucs
from repro.train.optim import adam, apply_updates


@pytest.fixture(scope="module")
def trained_model():
    """Train the (reduced) paper ranking model for a few hundred steps."""
    cfg = PaperRankingConfig().scaled(0.02)
    graph, cfg = build_paper_ranking_model(cfg)
    ex = Executor(graph, "vani")
    outputs = list(graph.outputs)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    opt = adam(2e-3)
    opt_state = opt.init(params)

    # fixed synthetic "ground truth" teacher so AUC is meaningful
    teacher = init_graph_params(graph, jax.random.PRNGKey(99))

    def gen_batch(key, B=32):
        feeds = make_recsys_feeds(graph, B, key, tile_user=True)
        t_out = ex.run(teacher, feeds)
        logits = jnp.concatenate([t_out[o] for o in outputs], -1)
        # per-task median threshold: a GLOBAL median over the (B, T)
        # concat can land between the task columns' logit ranges, making
        # every task slice single-class (degenerate ROC — the old NaN-AUC
        # seed failure); per-task thresholds keep labels ~balanced within
        # each task, which is also the meaningful ranking target
        labels = (logits > jnp.median(logits, axis=0, keepdims=True)
                  ).astype(jnp.float32)
        return feeds, labels

    @jax.jit
    def step(params, opt_state, feeds, labels):
        def loss_fn(p):
            out = ex.run(p, feeds)
            return bce_with_logits(
                jnp.concatenate([out[o] for o in outputs], -1), labels)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    key = jax.random.PRNGKey(1)
    losses = []
    for _ in range(200):
        key, k = jax.random.split(key)
        feeds, labels = gen_batch(k)
        params, opt_state, loss = step(params, opt_state, feeds, labels)
        losses.append(float(loss))
    assert losses[-1] < losses[0], "training must improve"
    return graph, cfg, params, gen_batch, outputs


class TestTrainThenConvert:
    def test_auc_unchanged_after_mari(self, trained_model):
        graph, cfg, params, gen_batch, outputs = trained_model
        feeds, labels = gen_batch(jax.random.PRNGKey(777), B=256)
        base = Executor(graph, "vani").run(params, feeds)
        base_logits = np.asarray(
            jnp.concatenate([base[o] for o in outputs], -1))
        mg, mp, conv = apply_mari(graph, params)
        assert len(conv.rewrites) >= 5
        # serving feeds: user at batch 1 (the tiled batch replicated one user)
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        sfeeds = {k: (v[:1] if k in user_in else v) for k, v in feeds.items()}
        out = Executor(mg, "uoi").run(mp, sfeeds)
        mari_logits = np.asarray(
            jnp.concatenate([out[o] for o in outputs], -1))
        np.testing.assert_allclose(mari_logits, base_logits,
                                   rtol=1e-4, atol=1e-4)
        # per-task AUCs, guarded against degenerate label slices: a task
        # whose eval labels come out single-class has no defined ROC and
        # is skipped rather than poisoning the comparison with NaN
        base_aucs = valid_task_aucs(base_logits, labels)
        mari_aucs = valid_task_aucs(mari_logits, labels)
        assert base_aucs, "every task label slice degenerate — the " \
                          "per-task median labels should prevent this"
        assert base_aucs.keys() == mari_aucs.keys()
        for t, a0 in base_aucs.items():
            assert abs(a0 - mari_aucs[t]) < 1e-9, (
                f"lossless: task {t} AUC must be identical "
                f"({a0} vs {mari_aucs[t]})")

    def test_every_rewrite_hoists_user_rows(self, trained_model):
        graph, cfg, params, _, _ = trained_model
        _, _, conv = apply_mari(graph, params)
        for r in conv.rewrites:
            du = sum(w for w, g in zip(r.seg_widths, r.seg_groups)
                     if g == "user")
            assert du > 0

    def test_hlo_no_longer_contains_full_matmul(self, trained_model):
        """VanI's HLO contains the full (B × D_total) fusion matmul; MaRI's
        must not — the rewrite does what XLA CSE cannot (DESIGN.md §3)."""
        graph, cfg, params, gen_batch, outputs = trained_model
        feeds, _ = gen_batch(jax.random.PRNGKey(5), B=64)
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        sfeeds = {k: (v[:1] if k in user_in else v) for k, v in feeds.items()}

        gca = run_gca(graph)
        from repro.graph.ir import infer_shapes
        shapes = infer_shapes(graph)
        concat = graph.nodes[gca.eligible["expert0_fc0"]]
        d_total = shapes["fusion"][-1]

        vani_hlo = jax.jit(Executor(graph, "vani").run).lower(
            params, feeds).as_text()
        mg, mp, _ = apply_mari(graph, params)
        mari_hlo = jax.jit(Executor(mg, "uoi").run).lower(mp, sfeeds).as_text()
        assert f"64x{d_total}" in vani_hlo.replace(" ", "")
        assert f"64x{d_total}" not in mari_hlo.replace(" ", "")


class TestCheckpointRestart:
    def test_crash_and_resume(self, tmp_path):
        from repro.ckpt.manager import CheckpointManager
        from repro.train.loop import LoopConfig, train_loop

        opt = adam(1e-2)
        w0 = {"w": jnp.ones((4,))}
        state0 = {"params": w0, "opt": opt.init(w0)}

        def step(state, batch):
            def loss_fn(p):
                return jnp.sum((p["w"] - batch) ** 2)
            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            updates, opt_state = opt.update(grads, state["opt"],
                                            state["params"])
            return ({"params": apply_updates(state["params"], updates),
                     "opt": opt_state}, {"loss": loss})

        def batches():
            while True:
                yield jnp.zeros((4,))

        cfgl = LoopConfig(total_steps=40, ckpt_every=10, log_every=100)
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        with pytest.raises(RuntimeError, match="injected failure"):
            train_loop(step, state0, batches(), mgr, cfgl, fail_at=25,
                       log=lambda *_: None)
        assert mgr.latest_step() == 20
        # restart: resumes at 21 and completes
        state, _ = train_loop(step, state0, batches(), mgr, cfgl,
                              log=lambda *_: None)
        assert mgr.latest_step() == 39
        assert float(jnp.abs(state["params"]["w"]).max()) < 1.0


class TestElastic:
    def test_remesh_preserves_tp(self):
        from repro.ft.failures import plan_elastic_remesh
        plan = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"), 300)
        assert plan.new_shape[plan.axes.index("model")] == 16
        assert int(np.prod(plan.new_shape)) <= 300
        assert plan.global_batch_scale < 1.0

    def test_remesh_refuses_sub_tp(self):
        from repro.ft.failures import plan_elastic_remesh
        with pytest.raises(ValueError):
            plan_elastic_remesh((16, 16), ("data", "model"), 8)

    def test_heartbeat_detection(self):
        from repro.ft.failures import HeartbeatMonitor
        t = [0.0]
        mon = HeartbeatMonitor(["w0", "w1"], timeout=5.0, clock=lambda: t[0])
        t[0] = 3.0
        mon.heartbeat("w0")
        t[0] = 7.0
        assert mon.dead() == ["w1"]
        assert mon.alive() == ["w0"]
