"""The repro.obs subsystem: ring-buffer tracer (concurrency contracts),
log-bucketed histograms, Chrome trace export, ObsPlan on the plan spine,
and the tracing/metrics wiring through engine + batcher + service.
"""
import json
import threading
import time
import warnings

import jax
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.ranking import PaperRankingConfig, build_paper_ranking_model
from repro.obs import (Histogram, MetricsRegistry, Tracer, chrome_events,
                       merge_trace_files, trace_payload, write_trace)
from repro.serve import (CoalescingBatcher, ObsPlan, PlanError,
                         PlanResolutionWarning, RankingService, ServePlan,
                         ServeRequest, ServingEngine, StageProfiler)

from benchmarks.check_trace import validate


@pytest.fixture(scope="module")
def paper():
    graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.03))
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n, seed, version=0):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


TRACE_PLAN = ServePlan().evolve(obs__trace=True, batch__hedging=False)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_ring_wrap_keeps_newest(self):
        t = Tracer(capacity=8)
        for i in range(24):
            t.instant("e", i=i)
        assert len(t) == 8
        assert t.dropped == 16 and t.recorded == 24
        kept = [e[6]["i"] for e in t.events()]
        assert kept == list(range(16, 24))      # newest win

    def test_span_kinds_and_thread_stamp(self):
        t = Tracer()
        with t.span("work", group=1):
            pass
        t.begin("group", track="group:0", group=1)
        t.end("group", track="group:0", group=1)
        t.instant("hit", user=3)
        phases = [e[0] for e in t.events()]
        assert phases == ["X", "B", "E", "i"]
        tid = threading.get_ident()
        assert all(e[4] == tid for e in t.events())
        assert t.thread_names()[tid] == threading.current_thread().name

    def test_sampling(self):
        t = Tracer(sample_every=4)
        assert [s for s in range(9) if t.sampled(s)] == [0, 4, 8]
        assert all(Tracer().sampled(s) for s in range(5))

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)
        with pytest.raises(ValueError):
            Tracer(sample_every=0)

    def test_concurrent_writers_no_negative_or_orphaned_spans(self):
        """Direct threads hammering one tracer: every complete span keeps a
        non-negative duration, B/E pairs stay balanced per synthetic
        track, and nothing is lost below capacity."""
        t = Tracer(capacity=100_000)
        n_threads, per = 8, 300

        def work(wid):
            for i in range(per):
                with t.span("op", wid=wid, i=i):
                    pass
                track = f"group:{wid}"
                t.begin("group", track=track, group=wid * per + i)
                t.instant("hit", wid=wid)
                t.end("group", track=track, group=wid * per + i)

        threads = [threading.Thread(target=work, args=(w,))
                   for w in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        evs = t.events()
        assert len(evs) == n_threads * per * 4 and t.dropped == 0
        assert all(e[3] >= 0.0 for e in evs if e[0] == "X")
        # balanced + never-negative depth per track, in buffer order
        depth = {}
        for ph, _, _, _, _, track, _ in evs:
            if ph == "B":
                depth[track] = depth.get(track, 0) + 1
            elif ph == "E":
                depth[track] = depth.get(track, 0) - 1
                assert depth[track] >= 0, "E before its B on one track"
        assert all(d == 0 for d in depth.values())
        # OS thread ids can be recycled across short-lived threads, so the
        # exact name count is not deterministic — but every recorded tid
        # must have been named
        assert {e[4] for e in evs} <= set(t.thread_names())


# ---------------------------------------------------------------------------
# Histogram / MetricsRegistry
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_percentiles_close_to_exact(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(mean=2.0, sigma=1.0, size=20_000)
        h = Histogram("lat")
        for v in vals:
            h.record(float(v))
        for q in (50, 90, 99):
            exact = float(np.percentile(vals, q))
            est = h.percentile(q)
            # quarter-octave buckets: ±9% worst-case resolution
            assert abs(est - exact) / exact < 0.09, (q, est, exact)
        snap = h.snapshot()
        assert snap["count"] == len(vals)
        assert snap["min"] == pytest.approx(vals.min())
        assert snap["max"] == pytest.approx(vals.max())
        assert snap["mean"] == pytest.approx(vals.mean())

    def test_empty_and_single_value(self):
        h = Histogram()
        assert h.snapshot()["p99"] == 0.0 and h.snapshot()["count"] == 0
        h.record(7.25)
        # single observation: every percentile IS that value (clamping)
        for q in (50, 90, 99):
            assert h.percentile(q) == pytest.approx(7.25)

    def test_nonpositive_underflow_bucket(self):
        h = Histogram()
        for v in (0.0, -3.0, 5.0):
            h.record(v)
        snap = h.snapshot()
        assert snap["count"] == 3 and snap["min"] == -3.0
        assert h.percentile(99) == pytest.approx(5.0, rel=0.09)

    def test_reset_windows_the_distribution(self):
        h = Histogram()
        h.record(1000.0)                 # "warmup compile" outlier
        h.reset()
        for _ in range(50):
            h.record(2.0)
        assert h.snapshot()["max"] == 2.0 and h.snapshot()["count"] == 50

    def test_concurrent_record(self):
        h = Histogram()
        n_threads, per = 8, 2000

        def work(wid):
            for i in range(per):
                h.record(float(wid + 1))

        threads = [threading.Thread(target=work, args=(w,))
                   for w in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        snap = h.snapshot()
        assert snap["count"] == n_threads * per
        assert snap["total"] == pytest.approx(
            sum((w + 1) * per for w in range(n_threads)))

    def test_registry_gauges_and_histograms(self):
        reg = MetricsRegistry()
        assert reg.histogram("lat") is reg.histogram("lat")
        reg.histogram("lat").record(5.0)
        state = {"hits": 3}
        reg.gauge("hits", lambda: state["hits"])
        reg.gauge("dead", lambda: 1 / 0)
        snap = reg.snapshot()
        assert snap["hits"] == 3
        assert snap["lat"]["count"] == 1
        assert snap["dead"] is None      # dead gauge must not raise


# ---------------------------------------------------------------------------
# StageProfiler atomic snapshot (the satellite race fix)
# ---------------------------------------------------------------------------

class TestProfilerAtomicSnapshot:
    def test_snapshot_reset_loses_no_events(self):
        """Adder threads race a snapshot(reset=True) poller: the sum of all
        windowed snapshots plus the final remainder must equal exactly the
        number of adds — the old snapshot();reset() pair dropped whatever
        landed between the two calls."""
        prof = StageProfiler()
        n_threads, per = 6, 4000
        seen = [0]
        stop = threading.Event()

        def adder():
            for _ in range(per):
                prof.add("pack", 1e-9)

        def poller():
            while not stop.is_set():
                seen[0] += prof.snapshot(reset=True)["pack"]["calls"]

        threads = [threading.Thread(target=adder) for _ in range(n_threads)]
        pt = threading.Thread(target=poller)
        pt.start()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        stop.set()
        pt.join()
        seen[0] += prof.snapshot(reset=True)["pack"]["calls"]
        assert seen[0] == n_threads * per

    def test_snapshot_without_reset_preserves(self):
        prof = StageProfiler()
        prof.add("pack", 0.001)
        assert prof.snapshot()["pack"]["calls"] == 1
        assert prof.snapshot()["pack"]["calls"] == 1
        assert prof.snapshot(reset=True)["pack"]["calls"] == 1
        assert prof.snapshot()["pack"]["calls"] == 0


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

class TestExport:
    def _tracer(self):
        t = Tracer()
        with t.span("pack", group=1):
            pass
        t.begin("group", track="group:0", group=1)
        t.instant("cache_hit", user="u1")
        t.end("group", track="group:0", group=1)
        return t

    def test_chrome_events_shape(self):
        evs, base = self._tracer(), None
        events, base = chrome_events(evs, pid=3, process_name="din")
        meta = [e for e in events if e["ph"] == "M"]
        assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
        # synthetic group track far above compacted real tids
        gtrack = [e for e in meta if e["args"]["name"] == "group:0"]
        assert gtrack and gtrack[0]["tid"] >= 1000
        real = [e for e in events if e["ph"] != "M"]
        assert all(e["pid"] == 3 for e in events)
        assert min(e["ts"] for e in real) == 0.0     # rebased to earliest
        x = [e for e in real if e["ph"] == "X"]
        assert x and all(e["dur"] >= 0.0 for e in x)

    def test_payload_validates_and_is_json(self, tmp_path):
        payload = write_trace(str(tmp_path / "t.json"),
                              {"a": self._tracer(), "b": self._tracer()})
        assert validate(payload) == []
        reloaded = json.loads((tmp_path / "t.json").read_text())
        assert validate(reloaded) == []
        assert {e["pid"] for e in reloaded["traceEvents"]} == {0, 1}

    def test_merge_assigns_shard_pids(self, tmp_path):
        paths = []
        for i in range(3):
            p = str(tmp_path / f"w{i}.json")
            write_trace(p, self._tracer())
            paths.append(p)
        merged = merge_trace_files(paths, str(tmp_path / "merged.json"))
        assert validate(merged) == []
        assert {e["pid"] for e in merged["traceEvents"]} == {0, 1, 2}
        names = {e["args"]["name"] for e in merged["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert names == {"shard-0", "shard-1", "shard-2"}

    def test_validator_catches_violations(self):
        ok = trace_payload(self._tracer())
        assert validate(ok) == []
        bad = json.loads(json.dumps(ok))
        bad["traceEvents"].append({"name": "group", "ph": "E",
                                   "pid": 9, "tid": 9, "ts": 1.0})
        assert any("E without open B" in m for m in validate(bad))
        neg = json.loads(json.dumps(ok))
        for e in neg["traceEvents"]:
            if e["ph"] == "X":
                e["dur"] = -1.0
        assert any("bad dur" in m for m in validate(neg))
        assert any("absent" in m
                   for m in validate(ok, require=["no_such_event"]))


# ---------------------------------------------------------------------------
# ObsPlan on the plan spine
# ---------------------------------------------------------------------------

class TestObsPlan:
    def test_defaults(self):
        plan = ServePlan()
        assert plan.obs == ObsPlan()
        assert plan.obs.trace is False and plan.obs.metrics is True

    def test_round_trip(self):
        plan = ServePlan().evolve(obs__trace=True, obs__trace_capacity=4096,
                                  obs__sample_every=8, obs__metrics=False)
        again = ServePlan.from_json(plan.to_json())
        assert again == plan and again.obs.trace_capacity == 4096

    def test_rejects(self):
        with pytest.raises(PlanError):
            ServePlan(obs=ObsPlan(trace=True, trace_capacity=0))
        with pytest.raises(PlanError):
            ServePlan(obs=ObsPlan(trace=True, sample_every=0))

    def test_resolves_trace_knobs_without_trace(self):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            plan = ServePlan(obs=ObsPlan(trace=False, trace_capacity=4096,
                                         sample_every=8))
        assert any(issubclass(x.category, PlanResolutionWarning) for x in w)
        assert plan.obs.trace_capacity is None
        assert plan.obs.sample_every == 1
        assert any("without trace=True" in n for n in plan.resolution_notes)


# ---------------------------------------------------------------------------
# Engine + batcher + service wiring
# ---------------------------------------------------------------------------

class TestEngineTracing:
    def test_off_by_default(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=ServePlan())
        assert eng.tracer is None and eng.metrics is not None
        eng.close()

    def test_linkage_survives_out_of_order_collect(self, paper):
        """Two in-flight groups collected in reverse order: each group's
        B/E pair lands on ITS OWN synthetic track with its own gid, so
        the overlap renders instead of corrupting."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=TRACE_PLAN)
        h1 = eng.begin_coalesced([_request(graph, user_in, 1, 9, seed=1)])
        h2 = eng.begin_coalesced([_request(graph, user_in, 2, 9, seed=2)])
        eng.collect(h2)                          # out of order
        eng.collect(h1)
        assert h1.gid != h2.gid
        assert h1.track != h2.track
        groups = [e for e in eng.tracer.events() if e[1] == "group"]
        by_track = {}
        for ph, _, _, _, _, track, args in groups:
            by_track.setdefault(track, []).append((ph, args["group"]))
        for track, seq in by_track.items():
            phs = [p for p, _ in seq]
            gids = {g for _, g in seq}
            assert phs == ["B", "E"], (track, phs)
            assert len(gids) == 1                # B and E carry the same gid
        # slots freed: a third group reuses the lowest slot
        h3 = eng.begin_coalesced([_request(graph, user_in, 3, 9, seed=3)])
        assert h3.track == "group:0"
        eng.collect(h3)
        assert validate(trace_payload(eng.tracer)) == []
        eng.close()

    def test_exception_in_begin_closes_group_span(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=TRACE_PLAN)
        req = _request(graph, user_in, 1, 9, seed=1)
        # uncached user with no user feeds: stage 1 fails mid-begin
        bad = ServeRequest(user_id=999, user_feeds={},
                           candidate_feeds=req.candidate_feeds)
        with pytest.raises(Exception):
            eng.begin_coalesced([bad])
        assert validate(trace_payload(eng.tracer)) == []   # B/E balanced
        h = eng.begin_coalesced([req])           # slot was released
        assert h.track == "group:0"
        eng.collect(h)
        eng.close()

    def test_batcher_stream_trace_and_stats(self, paper):
        """The full wiring under the batcher's worker thread + submitter
        threads: spans stay well-formed, request→group linkage holds, and
        the histogram surface reports percentiles."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=TRACE_PLAN.evolve(
            batch__continuous=True, batch__max_inflight=2))
        reqs = [_request(graph, user_in, i % 3, 7 + (i % 3) * 8, seed=i)
                for i in range(18)]
        with CoalescingBatcher.from_plan(eng, eng.plan.batch) as b:
            futs = []
            def submit(chunk):
                futs_local = [b.submit(r) for r in chunk]
                futs.extend(futs_local)
            threads = [threading.Thread(target=submit,
                                        args=(reqs[i::3],))
                       for i in range(3)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            res = [f.result() for f in futs]
        assert len(res) == len(reqs)

        evs = eng.tracer.events()
        names = {e[1] for e in evs}
        assert {"submit", "queue_claim", "group_launch", "resolve",
                "group", "pack", "dispatch", "collect"} <= names
        assert {"cache_hit", "cache_miss"} & names
        # linkage: every group_launch's req seqs were also submitted, and
        # its gid matches a traced group span
        submitted = {e[6]["req"] for e in evs if e[1] == "submit"}
        gids = {e[6]["group"] for e in evs if e[1] == "group"}
        launches = [e[6] for e in evs if e[1] == "group_launch"]
        assert launches
        for args in launches:
            assert set(args["reqs"]) <= submitted
            if args.get("group") is not None:
                assert args["group"] in gids
        assert validate(trace_payload(eng.tracer)) == []

        # histogram surface: percentiles + compat total
        lat = b.request_latency.snapshot()
        assert lat["count"] == len(reqs) and lat["p99"] >= lat["p50"] > 0
        qw = b.queue_wait.snapshot()
        assert qw["count"] == len(reqs)
        assert b.queue_wait_ms == pytest.approx(qw["total"])
        snap = b.metrics.snapshot()
        assert snap["requests"] == len(reqs)
        assert snap["cache_hits"] == eng.cache.hits
        eng.close()

    def test_service_stats_percentiles(self, paper):
        graph, params, user_in = paper
        svc = RankingService(TRACE_PLAN)
        svc.register("ranking", graph=graph, params=params)
        for i in range(6):
            svc.score("ranking", _request(graph, user_in, i % 2, 9, seed=i))
        st = svc.stats()["scenarios"]["ranking"]
        lat = st["latency"]
        assert lat["request_ms"]["count"] == 6
        assert lat["request_ms"]["p99"] >= lat["request_ms"]["p50"] > 0
        assert lat["queue_wait_ms"]["count"] == 6
        assert st["metrics"]["cache_hits"] == st["cache_hits"] \
            if "cache_hits" in st else True
        assert st["metrics"]["pipeline_forks"] == st["pipeline_forks"]
        assert st["queue_wait_ms"] == pytest.approx(
            lat["queue_wait_ms"]["total"])
        svc.close()

    def test_metrics_off_keeps_compat_surface(self, paper):
        """obs.metrics=False: the engine registry is gone, but the batcher
        falls back to a private registry so queue_wait_ms and the latency
        snapshots keep working."""
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=ServePlan().evolve(
            obs__metrics=False, batch__hedging=False))
        assert eng.metrics is None
        with CoalescingBatcher(eng, linger_ms=1.0) as b:
            b.submit(_request(graph, user_in, 0, 9, seed=0)).result()
            b.submit(_request(graph, user_in, 0, 9, seed=1)).result()
            assert b.queue_wait_ms >= 0.0
            assert b.request_latency.snapshot()["count"] == 2
        svc_stats_like = b.metrics.snapshot()
        assert svc_stats_like["requests"] == 2
        eng.close()

    def test_tracing_engine_scores_bit_identical(self, paper):
        graph, params, user_in = paper
        reqs = [_request(graph, user_in, i, 9 + i, seed=i) for i in range(3)]
        plain = ServingEngine(graph, params, plan=ServePlan().evolve(
            batch__hedging=False))
        traced = ServingEngine(graph, params, plan=TRACE_PLAN)
        for r in reqs:
            a = plain.score(r)
            bres = traced.score(r)
            assert np.array_equal(a.scores, bres.scores)
        assert len(traced.tracer) > 0
        plain.close()
        traced.close()

    def test_sample_every_thins_request_events(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=TRACE_PLAN.evolve(
            obs__sample_every=1000))
        with CoalescingBatcher(eng, linger_ms=1.0) as b:
            for i in range(5):
                b.submit(_request(graph, user_in, 0, 9, seed=i)).result()
        names = [e[1] for e in eng.tracer.events()]
        # group-level spans are never thinned; per-request instants are
        assert "group" in names and "pack" in names
        assert names.count("submit") <= 1
        eng.close()

    def test_cache_evict_and_store_instants(self, paper):
        graph, params, user_in = paper
        eng = ServingEngine(graph, params, plan=TRACE_PLAN.evolve(
            cache__max_cached_users=2))
        for uid in range(4):
            eng.score(_request(graph, user_in, uid, 9, seed=uid))
        names = [e[1] for e in eng.tracer.events()]
        assert "cache_evict" in names
        eng.close()
