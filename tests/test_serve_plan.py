"""ServePlan (the declarative serving config spine) + RankingService.

Covers: JSON round-trip, preset equality, frozen-ness, the documented
resolution table (reject vs auto-resolve, including through the legacy
kwargs shim), bit-identical scores between legacy-kwargs engines and the
equivalent plan-built engines across vani/uoi/mari, and the multi-scenario
RankingService router (interleaved requests bit-identical to standalone
per-scenario engines, shared rep-cache budget with scenario-scoped keys).
"""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.data.features import make_recsys_feeds
from repro.graph.executor import init_graph_params
from repro.models.recsys import build_din
from repro.serve import (PRESETS, BatchPlan, CachePlan, GraphPlan,
                         KernelPlan, PlanError, PlanResolutionWarning,
                         RankingService, ServePlan, ServeRequest,
                         ServingEngine, ShardPlan)

SCENARIOS = ("din", "deepfm", "fm")


@pytest.fixture(scope="module")
def din_problem():
    graph, _ = build_din(embed_dim=8, seq_len=12, attn_mlp=(16, 8),
                         mlp=(24, 12), item_vocab=128)
    params = init_graph_params(graph, jax.random.PRNGKey(0))
    user_in = {n.name for n in graph.input_nodes()
               if n.attrs.get("domain") == "user"}
    return graph, params, user_in


def _request(graph, user_in, uid, n, seed, version=0):
    feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
    return ServeRequest(
        user_id=uid,
        user_feeds={k: v for k, v in feeds.items() if k in user_in},
        candidate_feeds={k: v for k, v in feeds.items() if k not in user_in},
        feature_version=version)


class TestServePlanBasics:
    def test_json_round_trip_all_presets(self):
        for name, plan in PRESETS.items():
            rt = ServePlan.from_json(plan.to_json())
            assert rt == plan, name
            assert rt.preset_name() == name

    def test_round_trip_of_nondefault_plan(self):
        plan = ServePlan(
            graph=GraphPlan(mode="uoi", two_stage=True),
            batch=BatchPlan(max_batch=256, min_bucket=32, hedging=False,
                            linger_ms=7.5),
            shard=ShardPlan(shard_candidates=2),
            cache=CachePlan(max_cached_users=100))
        rt = ServePlan.from_json(plan.to_json())
        assert rt == plan
        assert rt.shard.shard_candidates == 2      # int survives, not bool
        assert rt.preset_name() is None

    def test_preset_equality_and_identity(self):
        assert ServePlan.preset("paper") == ServePlan()
        assert ServePlan.preset("vanilla").graph.mode == "vani"
        assert ServePlan.preset("tpu").kernel.use_pallas
        assert ServePlan.preset("distributed").shard.shard_candidates
        # distributed preset must be SPMD-safe out of the box
        assert not ServePlan.preset("distributed").batch.hedging
        with pytest.raises(PlanError, match="unknown preset"):
            ServePlan.preset("bogus")

    def test_frozen(self):
        plan = ServePlan()
        with pytest.raises(dataclasses.FrozenInstanceError):
            plan.graph = GraphPlan(mode="uoi")
        with pytest.raises(dataclasses.FrozenInstanceError):
            plan.graph.mode = "uoi"

    def test_evolve(self):
        plan = ServePlan().evolve(graph__mode="uoi", batch__max_batch=64)
        assert plan.graph.mode == "uoi" and plan.batch.max_batch == 64
        # untouched sections are shared (frozen => safe) and equal
        assert plan.kernel == ServePlan().kernel
        with pytest.raises(TypeError):
            plan.evolve(nosection__x=1)
        with pytest.raises(TypeError):
            plan.evolve(graph__nofield=1)
        with pytest.raises(TypeError):
            plan.evolve(mode="uoi")                # missing section prefix

    def test_from_dict_rejects_unknown_sections_and_fields(self):
        with pytest.raises(PlanError, match="unknown plan sections"):
            ServePlan.from_dict({"graphs": {}})
        with pytest.raises(PlanError, match="unknown graph-plan fields"):
            ServePlan.from_dict({"graph": {"mde": "mari"}})

    def test_malformed_sections_raise_plan_error(self):
        """A hand-edited plan file with a null/scalar section must fail
        with the documented PlanError, not a bare TypeError."""
        for bad in ('{"graph": null}', '{"graph": "mari"}', '"mari"'):
            with pytest.raises(PlanError):
                ServePlan.from_json(bad)

    def test_wrong_typed_scalars_raise_plan_error(self):
        """Quoted numbers / stringy booleans in a plan file fail with the
        documented PlanError naming the field, not a bare TypeError."""
        for bad, field in ((' {"batch": {"max_batch": "64"}}', "max_batch"),
                           ('{"graph": {"mode": 3}}', "mode"),
                           ('{"kernel": {"use_pallas": "yes"}}',
                            "use_pallas"),
                           ('{"batch": {"max_batch": true}}', "max_batch"),
                           ('{"cache": {"max_cached_users": "10"}}',
                            "max_cached_users")):
            with pytest.raises(PlanError, match=field):
                ServePlan.from_json(bad)

    def test_sections_accept_dicts(self):
        plan = ServePlan(graph={"mode": "uoi"}, batch={"max_batch": 32})
        assert plan.graph.mode == "uoi"
        assert plan.batch.max_batch == 32 and plan.batch.min_bucket == 32

    def test_save_load(self, tmp_path):
        p = tmp_path / "plan.json"
        plan = ServePlan.preset("tpu")
        plan.save(str(p))
        assert ServePlan.load(str(p)) == plan

    def test_dist_runner_plan_file_fields_survive(self, tmp_path):
        """The SPMD runner layers only its operating requirements (sharding
        on, hedging off) on a --plan file — the file's max_batch/min_bucket/
        compress_scores must survive unless flags explicitly override."""
        import argparse
        from repro.dist.runner import build_plan
        path = tmp_path / "plan.json"
        ServePlan(batch=BatchPlan(max_batch=1024, min_bucket=64),
                  shard=ShardPlan(shard_candidates=True,
                                  compress_scores=True)).save(str(path))
        ns = lambda **kw: argparse.Namespace(
            **{"plan": str(path), "max_batch": None, "min_bucket": None,
               "compress_scores": False, **kw})
        plan = build_plan(ns())
        assert plan.batch.max_batch == 1024
        assert plan.batch.min_bucket == 64
        assert plan.shard.compress_scores          # file value survives
        assert plan.shard.shard_candidates and not plan.batch.hedging
        # an explicit shard COUNT in the file survives the forced-on rule
        path2 = tmp_path / "plan2.json"
        ServePlan(shard=ShardPlan(shard_candidates=2)).save(str(path2))
        assert build_plan(ns(plan=str(path2))).shard.shard_candidates == 2
        # explicit flag beats the file
        assert build_plan(ns(max_batch=128)).batch.max_batch == 128
        # no file: the runner's own defaults
        bare = build_plan(argparse.Namespace(
            plan=None, max_batch=None, min_bucket=None,
            compress_scores=False))
        assert bare.batch.max_batch == 256 and bare.batch.min_bucket == 16


class TestResolutionTable:
    """Every previously-silent invalid combo now rejects or auto-resolves
    per the documented table — at plan construction, not deep inside the
    engine."""

    def test_unknown_mode_rejected(self):
        with pytest.raises(PlanError, match="unknown mode"):
            ServePlan(graph=GraphPlan(mode="bogus"))

    def test_compress_scores_requires_shard_candidates(self):
        with pytest.raises(PlanError, match="shard_candidates"):
            ServePlan(shard=ShardPlan(compress_scores=True))

    def test_two_stage_vani_rejected(self):
        with pytest.raises(PlanError, match="user-only stage"):
            ServePlan(graph=GraphPlan(mode="vani", two_stage=True))

    @pytest.mark.parametrize("section,field,value", [
        ("batch", "max_batch", 0),
        ("batch", "min_bucket", 0),
        ("batch", "max_users_per_batch", 0),
        ("batch", "max_coalesce", 0),
        ("batch", "linger_ms", -1.0),
        ("batch", "deadline_linger_frac", 1.5),
        ("cache", "max_cached_users", 0),
        ("shard", "shard_candidates", -2),
    ])
    def test_bad_scalars_rejected(self, section, field, value):
        with pytest.raises(PlanError):
            ServePlan(**{section: {field: value}})

    def test_kernel_gather_without_pallas_resolves(self):
        with pytest.warns(PlanResolutionWarning, match="kernel_gather"):
            plan = ServePlan(kernel=KernelPlan(kernel_gather=True))
        assert not plan.kernel.kernel_gather
        assert plan.resolution_notes

    def test_gather_attention_without_decomposed_attention_resolves(self):
        # vani: no decomposed attention at all
        with pytest.warns(PlanResolutionWarning, match="gather_attention"):
            plan = ServePlan(graph=GraphPlan(mode="vani"),
                             kernel=KernelPlan(gather_attention=True))
        assert not plan.kernel.gather_attention
        # mari without reparam_attention: still nothing to gather from
        with pytest.warns(PlanResolutionWarning, match="gather_attention"):
            plan = ServePlan(kernel=KernelPlan(gather_attention=True))
        assert not plan.kernel.gather_attention
        # the VALID combo stays untouched (and silent)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            plan = ServePlan(graph=GraphPlan(reparam_attention=True),
                             kernel=KernelPlan(gather_attention=True))
        assert plan.kernel.gather_attention

    def test_rewrite_knobs_outside_mari_resolve(self):
        with pytest.warns(PlanResolutionWarning, match="MaRI rewrite"):
            plan = ServePlan(graph=GraphPlan(mode="uoi",
                                             reparam_attention=True,
                                             fragment=True))
        assert not plan.graph.reparam_attention
        assert not plan.graph.fragment

    def test_min_bucket_clamped_silently(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")       # normalization, no warning
            plan = ServePlan(batch=BatchPlan(max_batch=16))
        assert plan.batch.min_bucket == 16

    def test_resolution_is_idempotent_through_json(self):
        with pytest.warns(PlanResolutionWarning):
            plan = ServePlan(kernel=KernelPlan(kernel_gather=True,
                                               gather_attention=True))
        with warnings.catch_warnings():
            warnings.simplefilter("error")       # resolved plan is valid
            rt = ServePlan.from_json(plan.to_json())
        assert rt == plan


class TestAdmissionPlanFields:
    """The continuous-loop/admission knobs ride the ServePlan spine:
    validated scalars, documented resolutions, JSON round-trip — not
    ad-hoc kwargs."""

    def test_defaults(self):
        b = BatchPlan()
        assert b.continuous is True and b.max_inflight == 2
        assert b.admission is False
        assert b.shed_queue_depth is None and b.degrade_queue_depth is None
        assert b.degrade_frac == 0.5 and b.deadline_headroom_ms == 0.0

    @pytest.mark.parametrize("field,value", [
        ("max_inflight", 0),
        ("shed_queue_depth", 0),
        ("degrade_queue_depth", -1),
        ("degrade_frac", 0.0),
        ("degrade_frac", 1.5),
        ("deadline_headroom_ms", -1.0),
    ])
    def test_bad_scalars_rejected(self, field, value):
        with pytest.raises(PlanError):
            ServePlan(batch=BatchPlan(admission=True, **{field: value}))

    def test_degrade_above_shed_rejected(self):
        with pytest.raises(PlanError, match="degrade"):
            ServePlan(batch=BatchPlan(admission=True, shed_queue_depth=8,
                                      degrade_queue_depth=16))
        # the legal ordering (degrade engages at or before shed) is silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ServePlan(batch=BatchPlan(admission=True, shed_queue_depth=16,
                                      degrade_queue_depth=8))

    def test_thresholds_without_admission_resolve(self):
        with pytest.warns(PlanResolutionWarning, match="admission"):
            plan = ServePlan(batch=BatchPlan(shed_queue_depth=8,
                                             deadline_headroom_ms=2.0))
        assert plan.batch.shed_queue_depth is None
        assert plan.batch.deadline_headroom_ms == 0.0
        assert plan.resolution_notes

    def test_json_round_trip(self):
        plan = ServePlan().evolve(batch__continuous=False,
                                  batch__max_inflight=4,
                                  batch__admission=True,
                                  batch__shed_queue_depth=64,
                                  batch__degrade_queue_depth=32,
                                  batch__degrade_frac=0.25,
                                  batch__deadline_headroom_ms=3.0)
        rt = ServePlan.from_json(plan.to_json())
        assert rt == plan
        assert rt.batch.continuous is False and rt.batch.max_inflight == 4
        assert rt.batch.shed_queue_depth == 64
        assert rt.batch.degrade_queue_depth == 32

    def test_type_table_covers_new_fields(self):
        with pytest.raises(PlanError, match="max_inflight"):
            ServePlan(batch={"max_inflight": "2"})
        with pytest.raises(PlanError, match="shed_queue_depth"):
            ServePlan(batch={"shed_queue_depth": 1.5})
        with pytest.raises(PlanError, match="continuous"):
            ServePlan(batch={"continuous": 1})

    def test_from_plan_wires_batcher(self, din_problem):
        """CoalescingBatcher.from_plan carries every batch-section knob."""
        from repro.serve import CoalescingBatcher
        graph, params, _ = din_problem
        plan = ServePlan().evolve(batch__hedging=False,
                                  batch__continuous=False,
                                  batch__max_inflight=3,
                                  batch__admission=True,
                                  batch__shed_queue_depth=9,
                                  batch__degrade_queue_depth=4,
                                  batch__degrade_frac=0.75,
                                  batch__deadline_headroom_ms=1.5,
                                  batch__linger_ms=7.0)
        eng = ServingEngine(graph, params, plan=plan)
        b = CoalescingBatcher.from_plan(eng, plan.batch, auto_start=False)
        assert (b.continuous, b.max_inflight, b.admission) == (False, 3,
                                                               True)
        assert b.shed_queue_depth == 9 and b.degrade_queue_depth == 4
        assert b.degrade_frac == 0.75 and b.deadline_headroom_ms == 1.5
        assert b.linger_ms == 7.0


class TestDeviceResidentPlan:
    """The ``CachePlan.device_resident`` knob follows the same spine rules
    as every other plan field: validated scalars, documented resolutions,
    idempotent through JSON."""

    def test_bad_device_slots_rejected(self):
        with pytest.raises(PlanError, match="device_slots"):
            ServePlan(cache=CachePlan(device_resident=True, device_slots=0))

    def test_device_resident_without_cache_resolves_off(self):
        with pytest.warns(PlanResolutionWarning, match="device_resident"):
            plan = ServePlan(cache=CachePlan(cache_user_reps=False,
                                             device_resident=True))
        assert not plan.cache.device_resident
        assert plan.resolution_notes

    def test_device_resident_drops_hedging(self):
        # BatchPlan defaults hedging=True; the device tier wins (hedged
        # duplicates would replay donated dispatches)
        with pytest.warns(PlanResolutionWarning, match="hedging"):
            plan = ServePlan(cache=CachePlan(device_resident=True))
        assert plan.cache.device_resident
        assert not plan.batch.hedging

    def test_device_slots_without_device_resident_dropped(self):
        with pytest.warns(PlanResolutionWarning, match="device_slots"):
            plan = ServePlan(cache=CachePlan(device_slots=8))
        assert plan.cache.device_slots is None

    def test_valid_combo_silent_and_roundtrips(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            plan = ServePlan(batch=BatchPlan(hedging=False),
                             cache=CachePlan(device_resident=True,
                                             device_slots=32))
            rt = ServePlan.from_json(plan.to_json())
        assert rt == plan
        assert rt.cache.device_resident and rt.cache.device_slots == 32


class TestLegacyShim:
    """ServingEngine(**kwargs) still works: it builds the equivalent plan,
    emits a DeprecationWarning, and fails fast on the combos that used to
    no-op silently."""

    def test_legacy_kwargs_deprecation_warning(self, din_problem):
        graph, params, _ = din_problem
        with pytest.warns(DeprecationWarning, match="ServePlan"):
            eng = ServingEngine(graph, params, mode="uoi", max_batch=32,
                                hedging=False)
        assert eng.plan == ServePlan(graph=GraphPlan(mode="uoi"),
                                     batch=BatchPlan(max_batch=32,
                                                     hedging=False))
        eng.close()

    def test_plan_path_does_not_warn(self, din_problem):
        graph, params, _ = din_problem
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            eng = ServingEngine(graph, params,
                                plan=ServePlan().evolve(batch__max_batch=32))
            eng.close()
            # no kwargs at all is the default plan, also not deprecated
            eng = ServingEngine(graph, params)
            eng.close()

    def test_plan_and_kwargs_mutually_exclusive(self, din_problem):
        graph, params, _ = din_problem
        with pytest.raises(TypeError, match="not both"):
            ServingEngine(graph, params, plan=ServePlan(), mode="mari")

    def test_unknown_kwarg_rejected(self, din_problem):
        graph, params, _ = din_problem
        with pytest.raises(TypeError, match="unknown ServingEngine kwargs"):
            ServingEngine(graph, params, mod="mari")

    def test_preset_name_accepted_as_plan(self, din_problem):
        graph, params, _ = din_problem
        eng = ServingEngine(graph, params, plan="vanilla")
        assert eng.mode == "vani" and not eng.two_stage
        eng.close()

    # satellite: the previously-silent no-op combos, through the shim
    def test_shim_kernel_gather_without_pallas_warns(self, din_problem):
        graph, params, _ = din_problem
        with pytest.warns(PlanResolutionWarning, match="kernel_gather"):
            eng = ServingEngine(graph, params, kernel_gather=True,
                                hedging=False)
        assert not eng.kernel_gather
        eng.close()

    def test_shim_gather_attention_vani_warns(self, din_problem):
        graph, params, _ = din_problem
        with pytest.warns(PlanResolutionWarning, match="gather_attention"):
            eng = ServingEngine(graph, params, mode="vani",
                                gather_attention=True, hedging=False)
        assert not eng.gather_attention
        eng.close()

    def test_shim_compress_scores_without_shard_raises(self, din_problem):
        graph, params, _ = din_problem
        with pytest.raises(ValueError, match="shard_candidates"):
            ServingEngine(graph, params, compress_scores=True)

    @pytest.mark.parametrize("mode", ["vani", "uoi", "mari"])
    def test_legacy_vs_plan_engines_bit_identical(self, din_problem, mode):
        """The shim builds the SAME engine the plan path builds — scores
        are bit-identical across all three paradigms."""
        graph, params, user_in = din_problem
        reqs = [_request(graph, user_in, 0, 9, seed=1),
                _request(graph, user_in, 1, 21, seed=2)]
        with pytest.warns(DeprecationWarning):
            legacy = ServingEngine(graph, params, mode=mode, max_batch=32,
                                   min_bucket=8, hedging=False)
        plan_eng = ServingEngine(graph, params, plan=ServePlan().evolve(
            graph__mode=mode, batch__max_batch=32, batch__min_bucket=8,
            batch__hedging=False))
        assert legacy.plan == plan_eng.plan
        for a, b in zip(legacy.score_coalesced(reqs),
                        plan_eng.score_coalesced(reqs)):
            np.testing.assert_array_equal(a.scores, b.scores)
        legacy.close()
        plan_eng.close()


class TestRankingService:
    """The multi-scenario router: per-scenario engines behind one
    submit(scenario, request) API, shared rep-cache budget."""

    @pytest.fixture(scope="class")
    def svc_plan(self):
        return ServePlan().evolve(batch__max_batch=64, batch__min_bucket=16,
                                  batch__hedging=False,
                                  batch__linger_ms=20.0,
                                  batch__max_coalesce=4)

    def _interleaved(self, svc, n=9):
        """Round-robin requests across scenarios; SAME user ids in every
        scenario on purpose — proves scenario-scoped cache keys."""
        items = []
        for r in range(n):
            sc = SCENARIOS[r % len(SCENARIOS)]
            feeds = make_recsys_feeds(svc.source_graph(sc), 7 + r,
                                      jax.random.PRNGKey(100 + r))
            uf, cf = svc.split_feeds(sc, feeds)
            items.append((sc, ServeRequest(user_id=r % 2, user_feeds=uf,
                                           candidate_feeds=cf)))
        return items

    def test_three_scenarios_bit_identical_to_standalone(self, svc_plan):
        """THE acceptance-criteria test: a service hosting din/deepfm/fm
        smoke builds scores an interleaved stream; per-scenario results are
        bit-identical to standalone per-scenario engines built the same
        way from the registry."""
        from repro import configs as cfgreg
        with RankingService(svc_plan, smoke=True, seed=0) as svc:
            for sc in SCENARIOS:
                svc.register(sc)
            assert svc.scenarios == sorted(SCENARIOS)
            items = self._interleaved(svc)
            results = svc.score_many(items)
            for sc in SCENARIOS:
                graph = cfgreg.get_config(sc).smoke_build()()[0]
                params = init_graph_params(graph, jax.random.PRNGKey(0))
                ref = ServingEngine(graph, params, plan=svc_plan)
                for (s, req), res in zip(items, results):
                    if s != sc:
                        continue
                    np.testing.assert_array_equal(
                        ref.score(req).scores, res.scores,
                        err_msg=f"{sc} diverged from standalone engine")
                ref.close()
            stats = svc.stats()
            assert set(stats["scenarios"]) == set(SCENARIOS)
            # interleaving actually exercised every scenario's engine
            assert all(v["stage2_calls"] >= 1
                       for v in stats["scenarios"].values())

    def test_shared_cache_is_scoped_per_scenario(self, svc_plan):
        with RankingService(svc_plan, shared_cache_users=16) as svc:
            for sc in SCENARIOS:
                svc.register(sc)
            svc.score_many(self._interleaved(svc, n=6))
            keys = svc.shared_cache.keys()
            # same raw user ids across scenarios live as DISTINCT entries
            scopes = {uid[0] for uid, _ in keys}
            assert scopes == set(SCENARIOS)
            assert len(keys) == 6                 # 3 scenarios x 2 users
            # scoped invalidation only touches the named scenario
            svc.engine("din").invalidate_user(0)
            assert len(svc.shared_cache) == 5
            assert ("din", 0) not in {uid for uid, _ in
                                      svc.shared_cache.keys()}

    def test_shared_budget_evicts_across_scenarios(self, svc_plan):
        """ONE LRU budget spans all scenarios: capping it below the live
        user count forces cross-scenario evictions."""
        with RankingService(svc_plan, shared_cache_users=2) as svc:
            for sc in SCENARIOS:
                svc.register(sc)
            svc.score_many(self._interleaved(svc, n=6))   # 6 scoped users
            assert len(svc.shared_cache) == 2
            assert svc.shared_cache.evictions >= 4

    def test_register_validation(self, svc_plan):
        with RankingService(svc_plan) as svc:
            svc.register("din")
            with pytest.raises(ValueError, match="already registered"):
                svc.register("din")
            with pytest.raises(KeyError, match="not registered"):
                svc.score("deepfm", None)
            with pytest.raises(ValueError, match="together"):
                svc.register("fm", graph=object())
            assert "din" in svc and "deepfm" not in svc

    def test_per_scenario_plan_override(self, svc_plan):
        """A scenario may carry its own plan (e.g. a vanilla baseline next
        to the paper engine) — the service still routes correctly."""
        with RankingService(svc_plan, smoke=True) as svc:
            svc.register("din")
            svc.register("fm", plan=svc_plan.evolve(graph__mode="vani"))
            assert svc.engine("din").mode == "mari"
            assert svc.engine("fm").mode == "vani"
            items = [(sc, self._req_for(svc, sc, seed))
                     for seed, sc in enumerate(("din", "fm", "din", "fm"))]
            results = svc.score_many(items)
            assert all(r.scores.shape[0] > 0 for r in results)

    def _req_for(self, svc, sc, seed):
        feeds = make_recsys_feeds(svc.source_graph(sc), 5 + seed,
                                  jax.random.PRNGKey(seed))
        uf, cf = svc.split_feeds(sc, feeds)
        return ServeRequest(user_id=seed, user_feeds=uf, candidate_feeds=cf)

    def test_stats_expose_profile_and_device_store(self, svc_plan):
        """Observability contract of this subsystem: per-scenario stats
        carry the stage-boundary profile, queue wait, the device-tier
        counters, and the shared cache's byte accounting."""
        plan = svc_plan.evolve(cache__device_resident=True)
        with RankingService(plan, smoke=True, seed=0) as svc:
            svc.register("din")
            svc.score("din", self._req_for(svc, "din", seed=3))
            st = svc.stats()
            sc = st["scenarios"]["din"]
            assert sc["device_resident"] is True
            prof = sc["profile"]
            assert set(prof) == {"stage1", "pack", "dispatch", "device",
                                 "unpack", "queue_idle", "overlap"}
            assert prof["pack"]["calls"] >= 1
            assert prof["pack"]["total_ms"] >= 0.0
            ds = sc["device_store"]
            assert ds["resident"] == 1 and ds["writes"] == 1
            assert ds["bytes"] > 0
            assert set(ds["boundary_bytes"]) == set(
                svc.engine("din").split.boundary)
            assert sc["queue_wait_ms"] >= 0.0
            # host-tier byte accounting mirrors the same boundary names
            cache_stats = st["shared_cache"]
            assert cache_stats["bytes"] > 0
            assert set(cache_stats["boundary_bytes"]) == set(
                svc.engine("din").split.boundary)
