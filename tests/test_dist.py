"""repro.dist subsystem tests: serving pspecs, the sharding policy, int8
compression, the collective-aware bucket planner, the kernel-side user-rep
gather, and multi-PROCESS stage-2 sharding (2 ``jax.distributed`` workers,
subprocess) — sharded fp32 scores must be bit-identical to the local
single-device engine across vani/uoi/mari."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import policy
from repro.dist.compress import dequantize_int8, quantize_int8
from repro.dist.sharding import candidate_pspecs, dp_axes, named
from repro.dist.topology import (Topology, bucket_for, candidate_mesh,
                                 plan_buckets)

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# specs + policy + topology (pure / single-device)
# ---------------------------------------------------------------------------

class TestServingSpecs:
    def test_candidate_mesh_and_pspecs(self):
        mesh = candidate_mesh()
        assert mesh.axis_names == ("cand",)
        assert _pow2(int(mesh.devices.size))
        (p_params, p_table, p_uidx, p_cand), out = candidate_pspecs(mesh)
        assert p_params.spec == jax.sharding.PartitionSpec()
        assert p_table.spec == jax.sharding.PartitionSpec()
        assert p_uidx.spec == jax.sharding.PartitionSpec("cand")
        assert p_cand.spec == jax.sharding.PartitionSpec("cand")
        # single-process mesh: scores stay device-sharded...
        assert out.spec == jax.sharding.PartitionSpec("cand")
        # ...unless the cross-host form is forced
        _, out_repl = candidate_pspecs(mesh, replicate_out=True)
        assert out_repl.spec == jax.sharding.PartitionSpec()

    def test_candidate_mesh_rejects_non_pow2(self):
        with pytest.raises(ValueError, match="power of two"):
            candidate_mesh(3)

    def test_dp_axes_and_named(self):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((1, 1), ("data", "model"))
        assert dp_axes(mesh) == ("data",)
        pod = make_host_mesh((1, 1, 1), ("pod", "data", "model"))
        assert dp_axes(pod) == ("pod", "data")
        tree = {"a": jax.sharding.PartitionSpec(None, "model")}
        sh = named(mesh, tree)
        assert isinstance(sh["a"], jax.sharding.NamedSharding)

    def test_family_state_pspecs_cover_trees(self):
        """Every family's state-spec tree must mirror its state tree."""
        from repro import configs as cfgreg
        from repro.dist.sharding import (gnn_state_pspecs, lm_state_pspecs,
                                         recsys_state_pspecs)
        from repro.graph.executor import init_graph_params
        from repro.train.optim import adam

        cfg = cfgreg.get_config("qwen3-14b").CONFIG
        sp = lm_state_pspecs(cfg)
        assert set(sp) == {"params", "opt"}
        assert set(sp["opt"]) == {"mu", "nu", "master", "step"}

        graph, _ = cfgreg.get_config("deepfm").smoke_build()()
        params = jax.eval_shape(
            lambda: init_graph_params(graph, jax.random.PRNGKey(0)))
        opt_sds = jax.eval_shape(adam(1e-3).init, params)
        rp = recsys_state_pspecs(graph)
        jax.tree_util.tree_map(lambda a, b: None, params, rp["params"],
                               is_leaf=lambda x: not isinstance(x, dict))
        jax.tree_util.tree_map(lambda a, b: None, opt_sds, rp["opt"],
                               is_leaf=lambda x: not isinstance(x, dict))

        gp = gnn_state_pspecs({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)})
        assert list(gp["params"]["w"]) == [None, None]

    def test_boundary_pspecs_replicated(self):
        from repro.core.mari import mari_rewrite
        from repro.core.split import split_two_stage
        from repro.models.ranking import (PaperRankingConfig,
                                          build_paper_ranking_model)
        graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.03))
        split = split_two_stage(mari_rewrite(graph).graph)
        bp = split.boundary_pspecs()
        assert set(bp) == set(split.boundary_specs)
        for name, spec in bp.items():
            assert len(spec) == 1 + len(split.boundary_specs[name])
            assert all(p is None for p in spec)


class TestPolicy:
    def test_nesting_and_constrain(self):
        assert policy.get("k") is None
        with policy.use(k=1, other="x"):
            assert policy.get("k") == 1
            with policy.use(k=2):
                assert policy.get("k") == 2
                assert policy.get("other") == "x"
            assert policy.get("k") == 1
        assert policy.get("k") is None
        # constrain without a registered sharding is identity
        x = jnp.ones((3,))
        np.testing.assert_array_equal(policy.constrain(x, "residual"), x)

    def test_thread_isolation(self):
        import threading
        seen = {}

        def peek():
            seen["worker"] = policy.get("k")

        with policy.use(k=42):
            t = threading.Thread(target=peek)
            t.start()
            t.join()
        assert seen["worker"] is None


class TestBucketPlanner:
    def test_property_sweep(self):
        """Every shard receives equal, power-of-two-aligned work and
        padding never exceeds one bucket — for all (pool, shards)."""
        for shards in (1, 2, 4, 8, 16):
            for pool in (1, 2, 3, 7, 15, 16, 17, 100, 511, 512, 1000,
                         4096, 4097, 10000):
                plan = plan_buckets(pool, shards, min_bucket=32,
                                    max_batch=1024)
                assert plan, (pool, shards)
                for b in plan:
                    assert _pow2(b), (pool, shards, plan)
                    assert b % shards == 0, (pool, shards, plan)
                    assert _pow2(b // shards), (pool, shards, plan)
                total = sum(plan)
                assert total >= pool
                # padding fits inside the (one) tail bucket
                assert total - pool < plan[-1], (pool, shards, plan)
                # every bucket except the tail is full-sized
                assert all(b == 1024 for b in plan[:-1]), (pool, shards, plan)

    def test_bucket_for_invariants(self):
        assert bucket_for(1, 8, min_bucket=2, max_batch=64) == 8
        assert bucket_for(100, 4, min_bucket=16, max_batch=4096) == 128
        assert bucket_for(5000, 4, min_bucket=16, max_batch=1024) == 1024
        with pytest.raises(ValueError, match="power of two"):
            bucket_for(10, 3)

    def test_non_pow2_max_batch_cap_rounds_down_when_sharded(self):
        """A cap-sized bucket must divide over the mesh: shards > 1 round a
        non-pow2 max_batch down to a power of two; shards == 1 keep the
        seed's raw-cap behavior."""
        assert bucket_for(100, 8, min_bucket=16, max_batch=100) == 64
        assert bucket_for(100, 1, min_bucket=16, max_batch=100) == 100
        for b in plan_buckets(1000, 8, min_bucket=16, max_batch=100):
            assert _pow2(b) and b % 8 == 0
        # cap below the shard count still yields a shard-divisible bucket
        assert bucket_for(3, 8, min_bucket=2, max_batch=5) == 8

    def test_empty_pool(self):
        assert plan_buckets(0, 4) == []


class TestCompression:
    def test_int8_roundtrip_bound_fixed_vectors(self):
        for arr in ([0.0], [0.0, 0.0], [-1e3, 333.3, 0.1], [1e-6],
                    list(np.linspace(-1, 1, 64)), [127.0, -127.0]):
            x = jnp.asarray(arr, jnp.float32)
            q, scale = quantize_int8(x)
            assert q.dtype == jnp.int8
            err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
            assert err.max() <= float(scale) / 2 + 1e-6

    def test_compressed_psum_error_feedback_closes(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.compress import compressed_psum
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        g = {"w": jnp.asarray([-2.0, 0.5, 1.7], jnp.float32)}
        out, err = shard_map(lambda t: compressed_psum(t, "data"),
                             mesh=mesh, in_specs=(P(),),
                             out_specs=(P(), P()))(g)
        np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                                   g["w"], atol=1e-6)


# ---------------------------------------------------------------------------
# kernel-side gather: table indexed by user_index at accumulator-init load
# ---------------------------------------------------------------------------

class TestKernelGather:
    def _parts(self, key, B, Dr, d):
        ks = jax.random.split(key, 4)
        return ([(jax.random.normal(ks[0], (B, Dr)),
                  jax.random.normal(ks[1], (Dr, d)))],
                jax.random.normal(ks[2], (d,)))

    @pytest.mark.parametrize("B,U,Dr,d", [(32, 4, 24, 20), (7, 1, 5, 3),
                                          (64, 8, 130, 129)])
    def test_ops_bit_identical_to_explicit_gather(self, B, U, Dr, d):
        from repro.kernels.mari_matmul import mari_matmul_fused_groups
        key = jax.random.PRNGKey(B + U + d)
        parts, b = self._parts(key, B, Dr, d)
        table = jax.random.normal(jax.random.fold_in(key, 1), (U, d))
        idx = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, U)
        ref = mari_matmul_fused_groups(
            parts, b, acc0=jnp.take(table, idx, axis=0),
            activation="relu", interpret=True)
        out = mari_matmul_fused_groups(
            parts, b, acc0=table, user_index=idx,
            activation="relu", interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_no_batched_stream_epilogue_gather(self):
        """All-user parts: the gathered epilogue row block is still exact."""
        from repro.kernels.mari_matmul import mari_matmul_fused_groups
        key = jax.random.PRNGKey(0)
        parts = [(jax.random.normal(key, (1, 6)),
                  jax.random.normal(jax.random.fold_in(key, 1), (6, 5)))]
        table = jax.random.normal(jax.random.fold_in(key, 2), (4, 5))
        idx = jnp.asarray([3, 0, 0, 2, 1], jnp.int32)
        ref = mari_matmul_fused_groups(
            parts, None, acc0=jnp.take(table, idx, axis=0),
            activation="sigmoid", interpret=True)
        out = mari_matmul_fused_groups(
            parts, None, acc0=table, user_index=idx,
            activation="sigmoid", interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_engine_end_to_end_bit_identical(self):
        """ServingEngine(kernel_gather=True) == materialized-gather engine,
        coalesced multi-user, on the paper's ranking model."""
        from repro.data.features import make_recsys_feeds
        from repro.graph.executor import init_graph_params
        from repro.models.ranking import (PaperRankingConfig,
                                          build_paper_ranking_model)
        from repro.serve.engine import ServeRequest, ServingEngine
        graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.03))
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}

        def req(uid, n, seed):
            feeds = make_recsys_feeds(graph, n, jax.random.PRNGKey(seed))
            return ServeRequest(
                uid, {k: v for k, v in feeds.items() if k in user_in},
                {k: v for k, v in feeds.items() if k not in user_in})

        reqs = [req(0, 21, 1), req(1, 40, 2)]
        ref = ServingEngine(graph, params, mode="mari", max_batch=64,
                            min_bucket=16, use_pallas=True, hedging=False)
        lazy = ServingEngine(graph, params, mode="mari", max_batch=64,
                             min_bucket=16, use_pallas=True,
                             kernel_gather=True, hedging=False)
        # the paper model must actually exercise the lazy path — an empty
        # eligibility set would degrade this into ref-vs-ref
        assert lazy.kernel_gather and len(lazy.lazy_gather_inputs) > 0
        assert not ref.lazy_gather_inputs
        for a, b in zip(ref.score_coalesced(reqs),
                        lazy.score_coalesced(reqs)):
            np.testing.assert_array_equal(a.scores, b.scores)


# ---------------------------------------------------------------------------
# multi-process stage-2 sharding (the acceptance-criteria test)
# ---------------------------------------------------------------------------

class TestMultiProcessServing:
    def test_two_worker_bit_identity(self):
        """2 jax.distributed workers × 2 forced host devices: SPMD sharded
        stage-2 scores are bit-identical (fp32) to the local single-device
        engine across vani/uoi/mari, with collective-aware bucketing on."""
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.abspath(_SRC) + os.pathsep
                             + env.get("PYTHONPATH", ""))
        env.setdefault("JAX_PLATFORMS", "cpu")
        # --max-batch 100 is deliberately non-pow2: the sharded engines
        # normalize it to a shard-divisible pow2 cap while the local
        # reference keeps the raw cap — different packing, same rows, so
        # bit-identity here also proves packing independence
        p = subprocess.run(
            [sys.executable, "-m", "repro.dist.runner", "--spawn", "2",
             "--devices-per-process", "2", "--verify",
             "--max-batch", "100", "--modes", "vani,uoi,mari"],
            env=env, capture_output=True, text=True, timeout=570)
        assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-3000:])
        recs = [json.loads(line) for line in p.stdout.strip().splitlines()]
        done = [r for r in recs if r.get("bit_identical")]
        assert {r["mode"] for r in done} == {"vani", "uoi", "mari"}
        assert all(r["processes"] == 2 and r["shards"] == 4 for r in done)
        assert recs[-1] == {"ok": True, "records": 3}


class TestEngineShardingConfig:
    def test_compress_scores_requires_shard_candidates(self):
        from repro.models.recsys import build_din
        from repro.graph.executor import init_graph_params
        from repro.serve.engine import ServingEngine
        graph, _ = build_din(embed_dim=4, seq_len=6, attn_mlp=(8, 4),
                             mlp=(8,), item_vocab=32, user_profile_dim=6,
                             context_dim=3)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="shard_candidates"):
            ServingEngine(graph, params, compress_scores=True)

    def test_compress_scores_within_int8_bound(self):
        """End-to-end compress_scores path (quantize -> all-gather ->
        per-shard dequantize): scores stay within the int8 error bound of
        the exact engine. Single-device mesh — the quantized gather code
        path is identical at any shard count (multi-shard/multi-process
        forms run in the dist bench and runner CLI)."""
        from repro.data.features import make_recsys_feeds
        from repro.graph.executor import init_graph_params
        from repro.models.ranking import (PaperRankingConfig,
                                          build_paper_ranking_model)
        from repro.serve.engine import ServeRequest, ServingEngine
        graph, _ = build_paper_ranking_model(PaperRankingConfig().scaled(0.03))
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        user_in = {n.name for n in graph.input_nodes()
                   if n.attrs.get("domain") == "user"}
        feeds = make_recsys_feeds(graph, 30, jax.random.PRNGKey(1))
        req = ServeRequest(
            0, {k: v for k, v in feeds.items() if k in user_in},
            {k: v for k, v in feeds.items() if k not in user_in})
        ref = ServingEngine(graph, params, mode="mari", max_batch=64,
                            min_bucket=16, shard_candidates=True,
                            hedging=False)
        cmp_eng = ServingEngine(graph, params, mode="mari", max_batch=64,
                                min_bucket=16, shard_candidates=True,
                                compress_scores=True, hedging=False)
        assert cmp_eng._cgather is not None
        a = ref.score(req).scores
        b = cmp_eng.score(req).scores
        tol = float(np.abs(a).max()) / 127.0 / 2.0 + 1e-6
        np.testing.assert_allclose(b, a, atol=tol)
        # quantization is real: bit-identity should NOT generally hold
        assert b.dtype == a.dtype and b.shape == a.shape

    def test_batcher_rejects_multiprocess_engine(self):
        """Timing-dependent group formation would desynchronize the SPMD
        collective schedule — the batcher must refuse such engines."""
        import types
        from repro.serve.batcher import CoalescingBatcher
        fake = types.SimpleNamespace(_multiproc=True, max_batch=128)
        with pytest.raises(ValueError, match="multi-process"):
            CoalescingBatcher(fake)

    def test_non_pow2_max_batch_normalized_when_sharded(self):
        """On a 1-device mesh the cap keeps seed behavior; the planner
        invariant is exercised directly (multi-device normalization is
        covered by bucket_for + the forced-device subprocess paths)."""
        from repro.models.recsys import build_din
        from repro.graph.executor import init_graph_params
        from repro.serve.engine import ServingEngine
        graph, _ = build_din(embed_dim=4, seq_len=6, attn_mlp=(8, 4),
                             mlp=(8,), item_vocab=32, user_profile_dim=6,
                             context_dim=3)
        params = init_graph_params(graph, jax.random.PRNGKey(0))
        # shard count pinned to 1 so the assertion holds on any machine
        eng = ServingEngine(graph, params, max_batch=100, min_bucket=8,
                            shard_candidates=1, hedging=False)
        assert eng._n_shards == 1 and eng.max_batch == 100
        assert eng._bucket(100) == 100          # raw cap, seed behavior


class TestTopology:
    def test_single_process_topology_is_degenerate(self):
        topo = Topology()
        assert not topo.is_distributed
        topo.initialize()        # no coordinator handshake, no-op
        assert len(jax.devices()) >= 1

    def test_from_env_roundtrip(self, monkeypatch):
        monkeypatch.setenv("REPRO_NUM_PROCESSES", "4")
        monkeypatch.setenv("REPRO_PROCESS_ID", "2")
        monkeypatch.setenv("REPRO_COORDINATOR", "localhost:7777")
        topo = Topology.from_env()
        assert (topo.num_processes, topo.process_id) == (4, 2)
        assert topo.coordinator == "localhost:7777"
        assert topo.is_distributed
